"""Paper §IV-B-3: chunked evaluation under a memory budget.

Verifies the chunk-count formula's cost behavior: runtime vs number of
chunks for the same problem, plus the failure mode when not even one set
fits (the paper's "use lower precision" remediation, demonstrated).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import (ChunkingError, EvalConfig, bytes_per_set,
                        evaluate_multiset, pack_sets, plan_chunks)
from repro.core.precision import FP16_STRICT, FP32
from repro.data.synthetic import uniform_problem


def run(quick: bool = False):
    n, l, k, d = (2000, 256, 10, 100) if quick else (8000, 1024, 10, 100)
    V = jnp.asarray(uniform_problem(n, d, 1))
    rng = np.random.default_rng(2)
    sets = [np.asarray(V[rng.choice(n, size=k, replace=False)])
            for _ in range(l)]
    pk = pack_sets(sets)
    mu = bytes_per_set(n, k, d, FP32, "fused")

    rows = []
    for n_chunks in (1, 4, 16):
        budget = mu * (l // n_chunks)
        planned = len(plan_chunks(l, n, k, d, FP32, "fused", budget))
        cfg = EvalConfig(memory_budget_bytes=budget)
        t = time_call(lambda cfg=cfg: evaluate_multiset(V, pk, cfg))
        rows.append((f"chunking[{planned}chunks]", t, f"budget={budget}B"))

    # paper's remediation: a budget too small for fp32 still fits in the
    # all-FP16 path (the paper's native FP16 kernel = our fp16_strict)
    tiny = int(mu * 0.9)
    try:
        plan_chunks(l, n, k, d, FP32, "fused", tiny)
        fp32_fits = "unexpectedly-fit"
    except ChunkingError:
        fp32_fits = "fp32-fails"
    fp16_chunks = len(plan_chunks(l, n, k, d, FP16_STRICT, "fused", tiny))
    rows.append(("chunking_precision_remediation", 0.0,
                 f"{fp32_fits};fp16_chunks={fp16_chunks}"))
    emit(rows)
    return rows
