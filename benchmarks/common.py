"""Shared benchmark utilities. CSV rows: name,us_per_call,derived."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall-clock microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(rows: list[tuple]):
    """Print CSV rows. Rows are ``(name, us, derived)`` or, for entries that
    score through a non-default evaluation backend, ``(name, us, derived,
    backend)`` — the backend column feeds ``run.py --json`` attribution."""
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        backend = row[3] if len(row) > 3 else "jnp"
        print(f"{name},{us:.1f},{derived},{backend}")
