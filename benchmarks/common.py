"""Shared benchmark utilities. CSV rows: name,us_per_call,derived."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall-clock microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(rows: list[tuple[str, float, str]]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
