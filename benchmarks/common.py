"""Shared benchmark utilities. CSV rows:
name,us_per_call,derived,backend,peak_device_bytes,function,n_batch."""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall-clock microseconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def peak_device_bytes(device=None) -> Optional[int]:
    """Allocator high-water mark of ``device`` (default: device 0).

    This is a PROCESS-LIFETIME peak (``peak_bytes_in_use`` never resets),
    so within one benchmark run it reflects the largest-footprint plan
    executed so far, not the row it is attached to — a cross-PR trend line
    for the whole module, not a per-plan measurement. The per-plan O(n/p)
    certification is the analytic ``*_bytes_per_device`` model each sharded
    row carries in ``derived``. Backends without allocator stats (CPU)
    return None."""
    try:
        dev = device if device is not None else jax.local_devices()[0]
        stats = dev.memory_stats() or {}
    except Exception:
        return None
    v = stats.get("peak_bytes_in_use")
    return int(v) if v is not None else None


def emit(rows: list[tuple]):
    """Print CSV rows. Rows are ``(name, us, derived)`` plus up to four
    optional columns: ``backend`` (for entries scoring through a
    non-default evaluation backend), ``peak_device_bytes`` (an int from
    :func:`peak_device_bytes`, or None), ``function`` (the submodular
    objective the row scored, default "exemplar"), and ``n_batch`` (how
    many independent requests the row's dispatch carried, default 1 — the
    serving-throughput axis) — all feed ``run.py --json`` attribution."""
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        backend = row[3] if len(row) > 3 else "jnp"
        peak = row[4] if len(row) > 4 else None
        func = row[5] if len(row) > 5 else "exemplar"
        n_batch = row[6] if len(row) > 6 else 1
        peak_s = "" if peak is None else str(int(peak))
        print(f"{name},{us:.1f},{derived},{backend},{peak_s},{func},"
              f"{int(n_batch)}")
