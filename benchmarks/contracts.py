"""Contract-audit metrics as benchmark rows — the perf trajectory records
contract state alongside timings.

Each audited entry point emits one row: ``us_per_call`` is the mean
abstract-trace + lower time per signature (the compile-time cost the
recompilation-hazard sweep bounds), and ``derived`` carries the structural
numbers the contracts pin — traced-signature count, worst-case static
collective count, largest collective operand bytes, donated bytes, and the
violation count (0 on a green tree; a regression here fails CI via
``python -m repro.analysis.audit`` *and* shows up in BENCH_pr.json).
"""
from __future__ import annotations

import time


def run(quick: bool = False):
    from repro.analysis import report as rep
    from repro.analysis.registry import build_cases
    from repro.core import distributed, engine, service, streaming  # noqa: F401

    rows = []
    per: dict[str, dict] = {}
    t_all = time.perf_counter()
    for case in build_cases(quick=quick):
        t0 = time.perf_counter()
        result = rep.evaluate_case(case)
        dt_us = (time.perf_counter() - t0) * 1e6
        m = per.setdefault(case.contract, {
            "signatures": 0, "us": 0.0, "collectives": 0,
            "max_collective_bytes": 0, "donated_bytes": 0, "violations": 0})
        m["signatures"] += 1
        m["us"] += dt_us
        m["collectives"] = max(m["collectives"],
                               result.metrics.get("collective_total", 0))
        m["max_collective_bytes"] = max(
            m["max_collective_bytes"],
            result.metrics.get("max_collective_bytes", 0))
        m["donated_bytes"] = max(m["donated_bytes"], rep.donated_bytes(case))
        m["violations"] += len(result.violations)
    total_us = (time.perf_counter() - t_all) * 1e6

    for name in sorted(per):
        m = per[name]
        rows.append((
            f"audit/{name}",
            m["us"] / max(m["signatures"], 1),
            f"signatures={m['signatures']};collectives={m['collectives']};"
            f"max_collective_bytes={m['max_collective_bytes']};"
            f"donated_bytes={m['donated_bytes']};"
            f"violations={m['violations']}"))
    rows.append((
        "audit/all", total_us,
        f"contracts={len(per)};"
        f"signatures={sum(m['signatures'] for m in per.values())};"
        f"violations={sum(m['violations'] for m in per.values())}"))

    from benchmarks.common import emit

    emit(rows)
    return rows
