"""Function zoo under the shared selection engine: per-objective cost rows.

One engine, many objectives — the cache-semantics protocol means facility
location and graph cut run the SAME device selection scan as exemplar
clustering, differing only in the per-row gain formula (and, for graph cut,
one winner-indexed penalty riding the gains reduction). These rows track
the realized per-function cost of that generality at n ∈ {4k, 32k} so a
regression in the shared gain-kernel template (min↔max fold flip) or the
protocol dispatch shows up as a per-function slope change in the BENCH
trajectory, not a silent tax on every objective.

Rows carry the ``function`` column (6th field) that ``run.py --json``
surfaces for per-objective attribution.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, peak_device_bytes, time_call
from repro.core import EvalConfig
from repro.core.functions import FUNCTIONS
from repro.core.optimizers import stochastic_greedy
from repro.data.synthetic import blobs

#: the zoo entries certified through the shared min/max kernel template
ZOO = ("facility_location", "graph_cut")


def run(quick: bool = False):
    ns = (4096,) if quick else (4096, 32768)
    d, k = (16, 8) if quick else (32, 8)
    rows = []
    for n in ns:
        X, _ = blobs(n, d, centers=16, seed=21)
        # rbf on down-scaled blobs keeps the similarity dense (raw-scale
        # sqeuclidean saturates s = relu(1 − d/2) to 0 for these objectives)
        V = jnp.asarray(X) / 10.0
        cfg = EvalConfig(distance="rbf")
        base = None
        for fname in ("exemplar",) + ZOO:
            f = FUNCTIONS[fname](V, cfg)
            t = time_call(
                lambda f=f: stochastic_greedy(f, k, eps=0.1, seed=3,
                                              mode="device"),
                warmup=1, iters=1)
            res = stochastic_greedy(f, k, eps=0.1, seed=3, mode="device")
            base = t if base is None else base
            rows.append((
                f"{fname}_n{n}_device", t,
                f"k={k};evals={res.evaluations};vs_exemplar={t / base:.2f}x",
                "jnp", peak_device_bytes(), fname))
    emit(rows)
    return rows
