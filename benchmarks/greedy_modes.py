"""Beyond-paper: optimizer-aware incremental greedy vs the generic engine.

The paper evaluates Greedy by packing {S∪{c}} for every candidate — O(n·k·l)
per step. The min-distance cache collapses that to O(n·l·d) per step. This
benchmark measures the realized win and checks the selections agree, plus
compares the fused vs two-pass (paper-faithful W materialization) engines
and the Pallas kernel variants in interpret mode.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import (EvalConfig, ExemplarClustering, evaluate_multiset,
                        greedy, pack_sets)
from repro.data.synthetic import blobs


def run(quick: bool = False):
    n, d, kk = (1500, 64, 8) if quick else (3000, 100, 8)
    X, _ = blobs(n, d, centers=12, seed=5)
    V = jnp.asarray(X)
    f = ExemplarClustering(V)

    rows = []
    t_inc = time_call(lambda: greedy(f, kk, mode="mincache"), iters=1)
    t_ms = time_call(lambda: greedy(f, kk, mode="multiset"), iters=1)
    t_dev = time_call(lambda: greedy(f, kk, mode="device"), iters=1)
    r_inc = greedy(f, kk, mode="mincache")
    r_ms = greedy(f, kk, mode="multiset")
    r_dev = greedy(f, kk, mode="device")
    agree = r_inc.indices == r_ms.indices
    rows.append(("greedy_mincache", t_inc, f"agree={agree}"))
    rows.append(("greedy_multiset(paper)", t_ms,
                 f"speedup={t_ms / t_inc:.1f}x"))
    rows.append(("greedy_device", t_dev,
                 f"speedup_vs_mincache={t_inc / t_dev:.1f}x;"
                 f"agree={r_inc.indices == r_dev.indices}"))

    # engine modes on one multiset problem
    rng = np.random.default_rng(6)
    sets = [X[rng.choice(n, size=10, replace=False)] for _ in range(256)]
    pk = pack_sets(sets)
    for name, cfg in [
        ("engine_fused", EvalConfig(mode="fused")),
        ("engine_two_pass(paper)", EvalConfig(mode="two_pass")),
        ("engine_pallas_flat", EvalConfig(backend="pallas_interpret")),
        ("engine_pallas_loop", EvalConfig(backend="pallas_interpret",
                                          kernel_variant="loop")),
    ]:
        iters = 1 if "pallas" in name else 3
        t = time_call(lambda cfg=cfg: evaluate_multiset(V, pk, cfg),
                      iters=iters)
        rows.append((name, t, "", cfg.backend))
    emit(rows)
    return rows
