"""TPU roofline model of the exemplar-evaluation kernels (paper problem sizes).

No TPU is attached, so this derives the kernel's three roofline terms
analytically from the exact tile/grid configuration the Pallas wrapper picks
(the same numbers `ops.kernel_config` uses), for the paper's problem grid.
It quantifies the two TPU-side design decisions:

  * MXU reformulation: FLOPs = 2·n·l·k·d (Gram) vs scalar-loop FMA count —
    identical count but ~128× higher attainable throughput (MXU vs VPU);
    the term that matters is arithmetic intensity.
  * fused vs two-pass W: HBM bytes drop by l·n·4 (the work matrix) per
    evaluation — the dominant traffic term for large l·n.

Derived column: arithmetic intensity (FLOP/byte) and the bound.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.precision import FP32, BF16, FP16
from repro.kernels.ops import kernel_config

PEAK = 197e12
HBM = 819e9


def kernel_terms(n, l, k, d, policy, fused: bool, mode: str = "traffic_opt"):
    d_pad = ((d + 127) // 128) * 128
    cfgk = kernel_config(k, d_pad, policy, l, n, mode=mode)
    cs = policy.itemsize
    flops = 2.0 * n * l * k * d_pad              # Gram matmul (dominant)
    # HBM traffic: V read once per l-tile row; S read once per n-tile column
    grid_l = (l + cfgk.block_l - 1) // cfgk.block_l
    grid_n = (n + cfgk.block_n - 1) // cfgk.block_n
    bytes_v = n * d_pad * cs * grid_l            # V re-read per l tile
    bytes_s = l * k * d_pad * cs * grid_n        # S re-read per n tile
    bytes_out = l * 4
    if not fused:
        bytes_out += 2 * l * n * 4               # W write + read (paper mode)
    total_bytes = bytes_v + bytes_s + bytes_out
    return flops, total_bytes, cfgk


def run(quick: bool = False):
    rows = []
    grid = [(50_000, 5_000, 10, 100), (400_000, 5_000, 10, 100),
            (50_000, 40_000, 10, 100), (50_000, 5_000, 500, 100)]
    if quick:
        grid = grid[:2]
    for n, l, k, d in grid:
        for pol in (FP32, BF16, FP16):
            for fused in (True, False):
                for mode in ("paper", "traffic_opt"):
                    fl, by, cfgk = kernel_terms(n, l, k, d, pol, fused,
                                                mode=mode)
                    t_c = fl / PEAK
                    t_m = by / HBM
                    ai = fl / by
                    bound = "compute" if t_c > t_m else "memory"
                    tag = (f"kernel[n={n},l={l},k={k}]"
                           f"_{pol.name}_{'fused' if fused else 'two_pass'}"
                           f"_{mode}")
                    rows.append((tag, max(t_c, t_m) * 1e6,
                                 f"AI={ai:.0f};bound={bound};"
                                 f"Bl={cfgk.block_l};Bn={cfgk.block_n}"))
    emit(rows)
    return rows
