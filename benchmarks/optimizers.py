"""Optimizer-awareness (paper §IV-A): evaluation counts and achieved values.

The paper's design target is the *multiset* problem shape optimizers
generate. This benchmark records, per optimizer, the number of set-function
evaluations, wall time, and the achieved f-value relative to Greedy —
the end-to-end view of how the evaluation engine serves real maximizers.

It also measures the host-loop vs device-resident greedy stepping engine:
the host loop pays one dispatch + one device↔host round-trip per round,
the device engine runs all k rounds inside a single jitted ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, peak_device_bytes, time_call
from repro.core import EvalConfig, ExemplarClustering
from repro.core.optimizers import (OPTIMIZERS, greedy, lazy_greedy,
                                   stochastic_greedy)
from repro.data.synthetic import blobs


def run(quick: bool = False):
    n, d, k = (1200, 48, 8) if quick else (3000, 64, 12)
    X, _ = blobs(n, d, centers=12, seed=9)
    f = ExemplarClustering(jnp.asarray(X))
    base = OPTIMIZERS["greedy"](f, k)
    rows = []
    for name, opt in OPTIMIZERS.items():
        t = time_call(lambda opt=opt: opt(f, k), iters=1, warmup=0)
        res = opt(f, k)
        rows.append((f"opt_{name}", t,
                     f"evals={res.evaluations};"
                     f"value_ratio={res.value / base.value:.4f};"
                     f"picked={len(res.indices)}"))

    # host-loop vs device-resident stepping (one dispatch for all k rounds)
    sizes = [(1024, 32), (4096, 32)] if quick else [(4096, 32), (32768, 32)]
    kk = 8
    for nn, dd in sizes:
        Xs, _ = blobs(nn, dd, centers=16, seed=11)
        fs = ExemplarClustering(jnp.asarray(Xs))
        # first runs double as warmup (device: trace) and the parity check
        r_host = greedy(fs, kk, mode="host")
        r_dev = greedy(fs, kk, mode="device")
        agree = r_host.indices == r_dev.indices
        t_host = time_call(lambda fs=fs: greedy(fs, kk, mode="host"),
                           iters=1, warmup=0)
        t_dev = time_call(lambda fs=fs: greedy(fs, kk, mode="device"),
                          iters=1, warmup=0)
        rows.append((f"greedy_host_n{nn}", t_host, ""))
        rows.append((f"greedy_device_n{nn}", t_dev,
                     f"speedup={t_host / t_dev:.2f}x;agree={agree}"))
        t_sh = time_call(
            lambda fs=fs: stochastic_greedy(fs, kk, mode="host"),
            iters=1, warmup=1)
        t_sd = time_call(
            lambda fs=fs: stochastic_greedy(fs, kk, mode="device"),
            iters=1, warmup=1)
        rows.append((f"stochastic_host_n{nn}", t_sh, ""))
        rows.append((f"stochastic_device_n{nn}", t_sd,
                     f"speedup={t_sh / t_sd:.2f}x"))
        # CELF: host reference loop vs the same top-B re-scoring on device
        r_lh = lazy_greedy(fs, kk, mode="host")
        r_ld = lazy_greedy(fs, kk, mode="device")
        t_lh = time_call(lambda fs=fs: lazy_greedy(fs, kk, mode="host"),
                         iters=1, warmup=0)
        t_ld = time_call(lambda fs=fs: lazy_greedy(fs, kk, mode="device"),
                         iters=1, warmup=0)
        rows.append((f"lazy_host_n{nn}", t_lh, f"evals={r_lh.evaluations}"))
        rows.append((f"lazy_device_n{nn}", t_ld,
                     f"speedup={t_lh / t_ld:.2f}x;"
                     f"agree={r_lh.indices == r_ld.indices};"
                     f"evals={r_ld.evaluations}"))
        # mesh-sharded plan (only meaningful with >1 device, e.g. under
        # XLA_FLAGS=--xla_force_host_platform_device_count=N), measured on
        # BOTH evaluation backends: the sharded-kernel vs jnp-path rows are
        # the acceptance trajectory for Pallas-under-shard_map (at the full
        # run's n=32k the kernel path must be ≥ the jnp path on a real
        # accelerator; CPU-interpret rows document the parity cost instead)
        if jax.device_count() > 1:
            ndev = jax.device_count()
            r_sh = greedy(fs, kk, mode="device_sharded")
            t_shd = time_call(
                lambda fs=fs: greedy(fs, kk, mode="device_sharded"),
                iters=1, warmup=0)
            rows.append((f"greedy_sharded_n{nn}_d{ndev}", t_shd,
                         f"agree={r_sh.indices == r_dev.indices}"))
            kb = "pallas" if jax.default_backend() != "cpu" \
                else "pallas_interpret"
            fk = ExemplarClustering(fs.V, EvalConfig(backend=kb))
            r_shk = greedy(fk, kk, mode="device_sharded")
            t_shk = time_call(
                lambda fk=fk: greedy(fk, kk, mode="device_sharded"),
                iters=1, warmup=0)
            rows.append((f"greedy_sharded_kernel_n{nn}_d{ndev}", t_shk,
                         f"speedup_vs_jnp={t_shd / t_shk:.2f}x;"
                         f"agree={r_shk.indices == r_sh.indices}", kb))
            # fully-sharded memory plane: the candidate-pool bytes column
            # is the O(n/p) acceptance artifact — replicated-pool plans
            # pin n·d·itemsize per device, the sharded pool n_pad/p·d
            # (it *is* V's shard: zero extra resident bytes), greedi
            # n_pad/p·d + the gathered p·k·d merge pool
            item = jnp.asarray(Xs).dtype.itemsize
            n_loc = -(-nn // ndev)
            pool_repl = nn * dd * item
            pool_shard = n_loc * dd * item
            r_sp = greedy(fs, kk, mode="device_sharded_pool")
            t_sp = time_call(
                lambda fs=fs: greedy(fs, kk, mode="device_sharded_pool"),
                iters=1, warmup=0)
            rows.append((f"greedy_sharded_pool_n{nn}_d{ndev}", t_sp,
                         f"agree={r_sp.indices == r_dev.indices};"
                         f"pool_bytes_per_device={pool_shard};"
                         f"replicated_pool_bytes={pool_repl}",
                         "jnp", peak_device_bytes()))
            r_gd = greedy(fs, kk, mode="greedi")
            t_gd = time_call(lambda fs=fs: greedy(fs, kk, mode="greedi"),
                             iters=1, warmup=0)
            rows.append((f"greedy_greedi_n{nn}_d{ndev}", t_gd,
                         f"value_ratio={r_gd.value / r_dev.value:.4f};"
                         f"evals={r_gd.evaluations};"
                         f"pool_bytes_per_device="
                         f"{pool_shard + ndev * kk * dd * item}",
                         "jnp", peak_device_bytes()))
    emit(rows)
    return rows
