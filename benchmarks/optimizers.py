"""Optimizer-awareness (paper §IV-A): evaluation counts and achieved values.

The paper's design target is the *multiset* problem shape optimizers
generate. This benchmark records, per optimizer, the number of set-function
evaluations, wall time, and the achieved f-value relative to Greedy —
the end-to-end view of how the evaluation engine serves real maximizers.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import EvalConfig, ExemplarClustering
from repro.core.optimizers import OPTIMIZERS
from repro.data.synthetic import blobs


def run(quick: bool = False):
    n, d, k = (1200, 48, 8) if quick else (3000, 64, 12)
    X, _ = blobs(n, d, centers=12, seed=9)
    f = ExemplarClustering(jnp.asarray(X))
    base = OPTIMIZERS["greedy"](f, k)
    rows = []
    for name, opt in OPTIMIZERS.items():
        t = time_call(lambda opt=opt: opt(f, k), iters=1, warmup=0)
        res = opt(f, k)
        rows.append((f"opt_{name}", t,
                     f"evals={res.evaluations};"
                     f"value_ratio={res.value / base.value:.4f};"
                     f"picked={len(res.indices)}"))
    emit(rows)
    return rows
