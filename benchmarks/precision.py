"""Paper §V-B (Table I FP16 rows) + the paper's deferred question.

Runtime: fp32 vs bf16 vs fp16 evaluation of the same problem.
Quality (the paper's explicit future-work item): how far do low-precision
function values drift, and does Greedy select different exemplars / lose
function value when run entirely in low precision?
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import (EvalConfig, ExemplarClustering, evaluate_multiset,
                        greedy, pack_sets)
from repro.data.synthetic import blobs


def run(quick: bool = False):
    n, l, k, d = (2000, 200, 10, 100) if quick else (8000, 800, 10, 100)
    X, _ = blobs(n, d, centers=16, seed=3)
    V = jnp.asarray(X)
    rng = np.random.default_rng(4)
    sets = [X[rng.choice(n, size=k, replace=False)] for _ in range(l)]
    pk = pack_sets(sets)

    rows = []
    vals = {}
    for pol in ("fp32", "bf16", "fp16", "fp16_strict"):
        cfg = EvalConfig(policy=pol)
        t = time_call(lambda cfg=cfg: evaluate_multiset(V, pk, cfg))
        v = np.asarray(evaluate_multiset(V, pk, cfg))
        vals[pol] = v
        drift = (np.max(np.abs(v - vals["fp32"])
                        / np.maximum(np.abs(vals["fp32"]), 1e-9))
                 if pol != "fp32" else 0.0)
        rows.append((f"precision_{pol}", t, f"max_rel_drift={drift:.2e}"))

    # quality: full greedy runs per precision (paper future work)
    kk = 16 if quick else 24
    Vq = V[:4000] if not quick else V
    base = greedy(ExemplarClustering(Vq, EvalConfig(policy="fp32")), kk)
    f32 = ExemplarClustering(Vq, EvalConfig(policy="fp32"))
    for pol in ("bf16", "fp16", "fp16_strict"):
        res = greedy(ExemplarClustering(Vq, EvalConfig(policy=pol)), kk)
        # evaluate the low-precision selection under the fp32 objective
        v_under_fp32 = f32.value(Vq[np.asarray(res.indices)])
        overlap = len(set(res.indices) & set(base.indices)) / kk
        rows.append((f"greedy_quality_{pol}", 0.0,
                     f"value_ratio={v_under_fp32 / base.value:.6f};"
                     f"overlap={overlap:.2f}"))
    emit(rows)
    return rows
