"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only name]``

CSV rows: name,us_per_call,derived. Mapping to the paper:
  sweeps          — Fig. 3/4 + Table I (vary N / l / k; naive vs work-matrix)
  precision       — §V-B FP16 runtimes + the deferred quality question
  chunking        — §IV-B-3 memory-budgeted evaluation
  greedy_modes    — beyond-paper optimizer-aware greedy + engine modes
  kernel_roofline — TPU roofline of the Pallas kernels at paper sizes
  optimizers      — §IV-A optimizer evaluation-count profile
"""
from __future__ import annotations

import argparse
import importlib

MODULES = ["sweeps", "precision", "chunking", "greedy_modes",
           "kernel_roofline", "optimizers"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    for m in mods:
        mod = importlib.import_module(f"benchmarks.{m}")
        mod.run(quick=args.quick)


if __name__ == "__main__":
    main()
