"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only name] [--json out.json]``

CSV rows: name,us_per_call,derived. Mapping to the paper:
  sweeps          — Fig. 3/4 + Table I (vary N / l / k; naive vs work-matrix)
  precision       — §V-B FP16 runtimes + the deferred quality question
  chunking        — §IV-B-3 memory-budgeted evaluation
  greedy_modes    — beyond-paper optimizer-aware greedy + engine modes
  kernel_roofline — TPU roofline of the Pallas kernels at paper sizes
  optimizers      — §IV-A optimizer evaluation-count profile + engine plans
  streaming       — sieve family: per-element host loop vs device block offer
  functions       — zoo objectives through the shared engine at n ∈ {4k, 32k}
  contracts       — compiled-contract audit metrics (traced signatures,
                    collective census, donated bytes) per audited entry point

``--json`` additionally writes the rows as a machine-readable artifact
(``{module: [{name, us_per_call, derived, backend, peak_device_bytes,
function}, ...]}``) so CI can accumulate a perf trajectory across PRs;
``backend`` records the evaluation backend each entry scored through ("jnp"
unless the module tagged the row "pallas"/"pallas_interpret"), so
BENCH_*.json trajectories can attribute speedups to the kernel wiring;
``peak_device_bytes`` the device-0 allocator *process-lifetime* high-water
mark (None on backends without stats; a cross-PR trend line for the whole
module run, not a per-row measurement); and ``function`` the submodular
objective the row scored ("exemplar" unless the module tagged it), so the
function-zoo rows chart per-objective slopes. The sharded plans' O(n/p)
per-device memory claim is certified by the analytic
``*_bytes_per_device`` columns those rows carry in ``derived``. ``--only``
takes a comma-separated module list. A module that raises (or emits no
rows) is recorded under ``_errors`` in the JSON artifact and the run
exits non-zero — an errored benchmark must fail CI, not flatline the
trajectory.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import traceback

MODULES = ["sweeps", "precision", "chunking", "greedy_modes",
           "kernel_roofline", "optimizers", "streaming", "functions",
           "serving", "contracts"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of modules")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as JSON (CI artifact)")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived,backend,peak_device_bytes,function,"
          "n_batch")
    collected: dict[str, list[dict]] = {}
    errors: dict[str, str] = {}
    for m in mods:
        try:
            mod = importlib.import_module(f"benchmarks.{m}")
            rows = mod.run(quick=args.quick)
            if not rows:
                raise RuntimeError(f"module {m!r} emitted no rows")
        except Exception:
            # a failing module must fail the job — a silently-empty
            # BENCH_pr.json would read as a flat perf trajectory
            errors[m] = traceback.format_exc()
            collected[m] = []
            continue
        collected[m] = [
            {"name": row[0], "us_per_call": row[1], "derived": row[2],
             # 4th column = the evaluation backend the entry scored
             # through; 5th = device-0 peak allocator bytes (None on
             # backends without memory stats); 6th = the submodular
             # objective the row scored (the function-zoo axis); 7th =
             # requests per dispatch (the serving-throughput axis)
             "backend": row[3] if len(row) > 3 else "jnp",
             "peak_device_bytes": row[4] if len(row) > 4 else None,
             "function": row[5] if len(row) > 5 else "exemplar",
             "n_batch": row[6] if len(row) > 6 else 1}
            for row in (rows or [])
        ]
    if args.json:
        payload: dict = dict(collected)
        if errors:
            payload["_errors"] = errors
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {args.json}")
    if errors:
        for m, tb in errors.items():
            print(f"# benchmark module {m!r} FAILED:\n{tb}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
