"""Selection serving: batched multi-tenant dispatch vs sequential requests.

The Industry 4.0 deployment shape (arXiv:2105.12026) is many concurrent
per-tenant summarization requests, not one big problem. This benchmark
measures requests/sec and per-request latency when B same-signature tenants
(each its own (n, d) ground set and budget k) are solved by

* **sequential** — B warm-jit ``run_selection`` dispatches, one per tenant
  (the pre-batching engine shape: per-dispatch overhead paid B times), vs
* **batched** — ONE ``run_selection_batch`` dispatch over the stacked
  (B, n, d) payload (overhead paid once, compute vectorized), vs
* **served** — the full async :class:`~repro.core.service.SelectionService`
  path (queue → bucket → batched dispatch → demux) at B concurrent
  submitters, which adds the front-end overhead on top of the batched win.

Every batched/served row asserts per-request selections bit-identical to
the sequential baseline — batching changes throughput, not output. Rows
carry the ``n_batch`` column so BENCH_*.json charts a serving-throughput
trend line over PRs.
"""
from __future__ import annotations

import asyncio
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import SelectionService, run_selection, run_selection_batch
from repro.core.functions import ExemplarClustering
from repro.core.service import MultiStreamIngestionService
from repro.data.synthetic import blobs


def _tenants(b: int, n: int, d: int):
    """B independent per-tenant ground sets (distinct data, one signature)."""
    Xs = [blobs(n, d, centers=8, seed=100 + t)[0] for t in range(b)]
    return Xs, [ExemplarClustering(jnp.asarray(X)) for X in Xs]


def _sequential(fs, k, cand):
    return [run_selection(f, kind="dense", k=k, cand_rounds=cand,
                          counter_key="bench_serve_seq") for f in fs]


def _served(Xs, k, max_batch):
    async def go():
        async with SelectionService(max_batch=max_batch) as svc:
            t0 = time.perf_counter()
            res = await asyncio.gather(*[svc.submit(X, k=k) for X in Xs])
            dt = time.perf_counter() - t0
            return res, dt, dict(svc.stats)
    return asyncio.run(go())


def run(quick: bool = False):
    # the multi-tenant serving regime is many SMALL per-tenant problems —
    # per-dispatch overhead dominates, which is exactly what batching
    # amortizes (at large n the dispatch is compute-bound and the batch
    # axis only wins the overhead margin)
    n, d, k = 64, 8, 4
    levels = [1, 64] if quick else [1, 64, 1024]
    cand = np.arange(n, dtype=np.int32)[None, :]
    rows = []
    for b in levels:
        Xs, fs = _tenants(b, n, d)
        t_seq = time_call(_sequential, fs, k, cand,
                         warmup=1, iters=2 if b >= 1024 else 3)
        t_bat = time_call(run_selection_batch, fs, kind="dense", k=k,
                          counter_key="bench_serve_batched",
                          warmup=1, iters=2 if b >= 1024 else 3)
        r_seq = _sequential(fs, k, cand)
        r_bat = run_selection_batch(fs, kind="dense", k=k,
                                    counter_key="bench_serve_batched")
        identical = all(a.indices == c.indices and
                        a.evaluations == c.evaluations
                        for a, c in zip(r_seq, r_bat))
        rps_seq = b / (t_seq / 1e6)
        rps_bat = b / (t_bat / 1e6)
        rows.append((f"serve_sequential_b{b}", t_seq / b,
                     f"requests_per_sec={rps_seq:.0f}",
                     "jnp", None, "exemplar", b))
        rows.append((f"serve_batched_b{b}", t_bat / b,
                     f"requests_per_sec={rps_bat:.0f};"
                     f"speedup={rps_bat / rps_seq:.2f}x;"
                     f"identical={identical}",
                     "jnp", None, "exemplar", b))
        if b == 64:
            # full async front end at 64 concurrent submitters (warm jit:
            # the batched rows above traced this signature already)
            r_svc, dt_svc, stats = _served(Xs, k, max_batch=64)
            svc_identical = all(a.indices == c.indices
                                for a, c in zip(r_seq, r_svc))
            rows.append((f"serve_service_b{b}", dt_svc * 1e6 / b,
                         f"requests_per_sec={b / dt_svc:.0f};"
                         f"dispatches={stats['dispatches']};"
                         f"identical={svc_identical}",
                         "jnp", None, "exemplar", b))
    rows.extend(_batched_sharded_rows())
    rows.append(_multistream_service_row(quick))
    emit(rows)
    return rows


def _batched_sharded_rows():
    """Batched × sharded composition: B tenants laid out as (B, n/p) across
    the mesh, ONE shard_map dispatch (one O(B·m) psum per round) instead of
    B sequential sharded dispatches. Rows only materialize with >1 device
    (the bench-smoke CI job forces 2 host devices). ``peak_device_bytes``
    is the ANALYTIC per-device resident footprint certifying the (B·n/p)
    memory model: the f32 (B, n/p) V shard plus the row-sharded min-cache
    and aux planes — plus the replicated (B, n, d) candidate pool for the
    non-pooled plan, which is exactly the term ``device_sharded_pool``
    deletes."""
    import jax

    if jax.device_count() < 2:
        return []
    ndev = jax.device_count()
    n, d, k = 64, 8, 4
    n_loc = -(-n // ndev)
    cand = np.arange(n, dtype=np.int32)[None, :]
    rows = []
    for b in (8, 64):
        Xs, fs = _tenants(b, n, d)
        r_seq = _sequential(fs, k, cand)
        for plan in ("device_sharded", "device_sharded_pool"):
            key = f"bench_serve_{plan}"
            t_bs = time_call(run_selection_batch, fs, kind="dense", k=k,
                             plan=plan, counter_key=key, warmup=1, iters=3)
            r_bs = run_selection_batch(fs, kind="dense", k=k, plan=plan,
                                       counter_key=key)
            # bit-parity vs the sequential baseline: all tenants at B=8,
            # the first 4 at B=64 (sampled — the full matrix lives in
            # tests/test_plan_parity.py)
            check = b if b <= 8 else 4
            identical = all(a.indices == c.indices and
                            a.evaluations == c.evaluations
                            for a, c in zip(r_seq[:check], r_bs[:check]))
            dev_bytes = b * n_loc * (d + 2) * 4
            if plan == "device_sharded":
                dev_bytes += b * n * d * 4    # the replicated (B, n, d) pool
            rps = b / (t_bs / 1e6)
            rows.append((f"serve_{plan}_b{b}", t_bs / b,
                         f"requests_per_sec={rps:.0f};devices={ndev};"
                         f"identical={identical}",
                         "jnp", dev_bytes, "exemplar", b))
    return rows


def _multistream_service_row(quick: bool):
    """The streaming serving surface at many concurrent logical streams:
    P producers offer into one :class:`MultiStreamIngestionService` (one
    batched sieve dispatch per block across ALL partitions) and the row
    reports end-to-end elements/sec through the async path, snapshot
    (two-tier merge) included."""
    P, m = (16, 512) if quick else (64, 2048)
    n, d = 256, 16
    X, _ = blobs(n, d, centers=8, seed=200)
    f = ExemplarClustering(jnp.asarray(X))
    rng = np.random.default_rng(5)
    stream = rng.standard_normal((m, d)).astype(np.float32)

    async def go():
        async with MultiStreamIngestionService(
                f, k=6, n_streams=P, block_size=16) as svc:
            # warm the batched-scan trace before timing
            for x in stream[:P * 16]:
                await svc.offer(x)
            await svc.drain()
            t0 = time.perf_counter()
            for x in stream:
                await svc.offer(x)
            await svc.drain()
            snap = await svc.snapshot()
            return time.perf_counter() - t0, snap

    dt, snap = asyncio.run(go())
    return (f"serve_multistream_p{P}", dt * 1e6 / m,
            f"elements_per_sec={m / dt:.0f};streams={P};"
            f"certified={snap.certified}", "jnp", None, "exemplar", P)
