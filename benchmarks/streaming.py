"""Streaming sieve engine: per-element host loop vs device block offer.

The paper's streaming regime (and the companion Industry 4.0 deployment)
cares about sustained ingest rate. This benchmark measures elements/sec for
the sieve family under both execution plans — the host mirror pays one
dispatch round-trip per element, the device engine consumes each block of B
elements in one jitted ``lax.scan`` — and reports the realized speedup plus
a host/device agreement check (selections and evaluation counts must match).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import EvalConfig, ExemplarClustering
from repro.core.optimizers import salsa, sieve_streaming
from repro.data.synthetic import blobs


def _throughput(fn, n_elements: int, warmup: bool = True):
    """(us_per_call, elements/sec); first call doubles as trace warmup."""
    if warmup:
        fn()
    t0 = time.perf_counter()
    res = fn()
    dt = time.perf_counter() - t0
    return res, dt * 1e6, n_elements / dt


def run(quick: bool = False):
    n, d, k = (1024, 32, 8) if quick else (8192, 32, 8)
    X, _ = blobs(n, d, centers=16, seed=21)
    f = ExemplarClustering(jnp.asarray(X))
    rows = []
    for name, alg in (("sieve", sieve_streaming), ("salsa", salsa)):
        r_host, t_host, eps_host = _throughput(
            lambda alg=alg: alg(f, k, seed=5, mode="host"), n)
        r_dev, t_dev, eps_dev = _throughput(
            lambda alg=alg: alg(f, k, seed=5, mode="device", block_size=64),
            n)
        agree = (r_host.indices == r_dev.indices
                 and r_host.evaluations == r_dev.evaluations)
        rows.append((f"stream_{name}_host_n{n}", t_host,
                     f"elements_per_sec={eps_host:.0f}"))
        rows.append((f"stream_{name}_device_n{n}", t_dev,
                     f"elements_per_sec={eps_dev:.0f};"
                     f"speedup={eps_dev / eps_host:.2f}x;agree={agree}"))
    # device plan with the fused sieve-gain kernel in the scan body (the
    # (S_max, n) relu intermediate never reaches HBM); interpret on CPU
    kb = "pallas" if jax.default_backend() != "cpu" else "pallas_interpret"
    fk = ExemplarClustering(f.V, EvalConfig(backend=kb))
    r_k, t_k, eps_k = _throughput(
        lambda: sieve_streaming(fk, k, seed=5, mode="device", block_size=64),
        n)
    r_j = sieve_streaming(f, k, seed=5, mode="device", block_size=64)
    rows.append((f"stream_sieve_device_kernel_n{n}", t_k,
                 f"elements_per_sec={eps_k:.0f};"
                 f"agree={r_k.indices == r_j.indices}", kb))
    emit(rows)
    return rows
