"""Streaming sieve engine: per-element host loop vs device block offer.

The paper's streaming regime (and the companion Industry 4.0 deployment)
cares about sustained ingest rate. This benchmark measures elements/sec for
the sieve family under both execution plans — the host mirror pays one
dispatch round-trip per element, the device engine consumes each block of B
elements in one jitted ``lax.scan`` — and reports the realized speedup plus
a host/device agreement check (selections and evaluation counts must match).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import numpy as np

from benchmarks.common import emit, peak_device_bytes
from repro.core import EvalConfig, ExemplarClustering
from repro.core.optimizers import salsa, sieve_streaming
from repro.core.streaming import make_batched_sieve_engine, make_sieve_engine
from repro.data.synthetic import blobs


def _throughput(fn, n_elements: int, warmup: bool = True):
    """(us_per_call, elements/sec); first call doubles as trace warmup."""
    if warmup:
        fn()
    t0 = time.perf_counter()
    res = fn()
    dt = time.perf_counter() - t0
    return res, dt * 1e6, n_elements / dt


def run(quick: bool = False):
    n, d, k = (1024, 32, 8) if quick else (8192, 32, 8)
    X, _ = blobs(n, d, centers=16, seed=21)
    f = ExemplarClustering(jnp.asarray(X))
    rows = []
    for name, alg in (("sieve", sieve_streaming), ("salsa", salsa)):
        r_host, t_host, eps_host = _throughput(
            lambda alg=alg: alg(f, k, seed=5, mode="host"), n)
        r_dev, t_dev, eps_dev = _throughput(
            lambda alg=alg: alg(f, k, seed=5, mode="device", block_size=64),
            n)
        agree = (r_host.indices == r_dev.indices
                 and r_host.evaluations == r_dev.evaluations)
        rows.append((f"stream_{name}_host_n{n}", t_host,
                     f"elements_per_sec={eps_host:.0f}"))
        rows.append((f"stream_{name}_device_n{n}", t_dev,
                     f"elements_per_sec={eps_dev:.0f};"
                     f"speedup={eps_dev / eps_host:.2f}x;agree={agree}"))
    # device plan with the fused sieve-gain kernel in the scan body (the
    # (S_max, n) relu intermediate never reaches HBM); interpret on CPU
    kb = "pallas" if jax.default_backend() != "cpu" else "pallas_interpret"
    fk = ExemplarClustering(f.V, EvalConfig(backend=kb))
    r_k, t_k, eps_k = _throughput(
        lambda: sieve_streaming(fk, k, seed=5, mode="device", block_size=64),
        n)
    r_j = sieve_streaming(f, k, seed=5, mode="device", block_size=64)
    rows.append((f"stream_sieve_device_kernel_n{n}", t_k,
                 f"elements_per_sec={eps_k:.0f};"
                 f"agree={r_k.indices == r_j.indices}", kb))
    # mesh-sharded sieve table (only meaningful with >1 device, e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=N): the (S_max, n)
    # cache table column-shards — the table-bytes column is the O(n/p)
    # acceptance artifact for the streaming plane
    if jax.device_count() > 1:
        ndev = jax.device_count()
        from repro.core.streaming import make_spec

        s_max = make_spec(k, 0.1, "sieve").s_max
        n_loc = -(-n // ndev)
        r_sh, t_sh, eps_sh = _throughput(
            lambda: sieve_streaming(f, k, seed=5, mode="device_sharded",
                                    block_size=64), n)
        rows.append((f"stream_sieve_sharded_n{n}_d{ndev}", t_sh,
                     f"elements_per_sec={eps_sh:.0f};"
                     f"agree={r_sh.indices == r_j.indices};"
                     f"table_bytes_per_device={s_max * n_loc * 4};"
                     f"single_device_table_bytes={s_max * n * 4}",
                     "jnp", peak_device_bytes()))
    rows += _overlap_rows(quick)
    rows.append(_multistream_row(quick))
    emit(rows)
    return rows


def _engine_throughput(build, ids, stream):
    """Elements/sec of ``engine.offer`` over a host-resident stream; a
    first throwaway engine absorbs trace warmup (fresh engine per timing so
    the sieve state always starts empty)."""
    build().offer(ids, stream)
    eng = build()
    t0 = time.perf_counter()
    eng.offer(ids, stream)
    jax.block_until_ready(eng.state if hasattr(eng, "state") else eng.states)
    dt = time.perf_counter() - t0
    return dt * 1e6, len(ids) / dt


def _overlap_rows(quick: bool):
    """Overlapped vs serialized ingestion at a sync-dominated block size:
    small ground set + small blocks make the per-block host syncs (accept
    mask fetch + evals fold) and staging a large fraction of the block
    time — the regime the double-buffered pipeline exists for.
    ``staging_hidden`` is the fraction of the serialized block boundary the
    overlap hides (1 − t_on/t_off)."""
    n, bs, m = (256, 8, 1024) if quick else (256, 8, 4096)
    X, _ = blobs(n, 32, centers=8, seed=22)
    f = ExemplarClustering(jnp.asarray(X))
    rng = np.random.default_rng(3)
    stream = rng.standard_normal((m, 32)).astype(np.float32)
    ids = np.arange(m)
    ts = {}
    for overlap in (False, True):
        ts[overlap] = _engine_throughput(
            lambda overlap=overlap: make_sieve_engine(
                f, 8, 0.1, mode="device", block_size=bs, overlap=overlap),
            ids, stream)
    (t_off, eps_off), (t_on, eps_on) = ts[False], ts[True]
    hidden = max(0.0, 1.0 - t_on / t_off)
    return [
        (f"stream_sieve_overlap_off_n{n}_b{bs}", t_off,
         f"elements_per_sec={eps_off:.0f}"),
        (f"stream_sieve_overlap_on_n{n}_b{bs}", t_on,
         f"elements_per_sec={eps_on:.0f};speedup={eps_on / eps_off:.2f}x;"
         f"staging_hidden={hidden:.2f}"),
    ]


def _multistream_row(quick: bool):
    """Aggregate ingest rate across 64 simulated streams batched through
    ONE dispatch per block (the multi-tenant streaming row): elements/sec
    counts ALL partitions' elements, n_batch carries the partition count."""
    P = 64
    n, bs, per = (256, 8, 32) if quick else (1024, 16, 64)
    X, _ = blobs(n, 32, centers=8, seed=23)
    f = ExemplarClustering(jnp.asarray(X))
    rng = np.random.default_rng(4)
    streams = [rng.standard_normal((per, 32)).astype(np.float32)
               for _ in range(P)]
    idxs = [np.arange(p * per, (p + 1) * per) for p in range(P)]

    def build():
        return make_batched_sieve_engine(f, 8, 0.1, P, block_size=bs)

    build().offer(idxs, streams)        # trace warmup
    eng = build()
    t0 = time.perf_counter()
    eng.offer(idxs, streams)
    jax.block_until_ready(eng.states)
    dt = time.perf_counter() - t0
    total = P * per
    return (f"stream_sieve_multi{P}_n{n}_b{bs}", dt * 1e6,
            f"elements_per_sec={total / dt:.0f};streams={P};"
            f"per_stream={per}", "jnp", peak_device_bytes(), "exemplar", P)
