"""Paper Fig. 3/4 + Table I: runtime/speedup vs N, l, k.

The paper compares a single-thread CPU loop (their Algorithm 2), a
multi-thread CPU variant, and the GPU work-matrix kernel. The CPU-only
container maps those roles to:

  naive      — per-set evaluation loop (Algorithm 2; the ST baseline)
  workmatrix — the multiset-vectorized engine (XLA CPU; plays the role of
               the parallel evaluator the paper builds — same algorithm the
               Pallas TPU kernel implements)
  pallas-int — the actual TPU kernel in interpret mode (correctness-true,
               not perf-representative; timed for completeness)

Sizes default to a CPU-tractable scale-down of the paper grid (the paper's
own N=50000, l=5000 points are reachable with --paper-scale on real HW).
The derived column reports speedup of workmatrix over naive.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import EvalConfig, evaluate_multiset, pack_sets
from repro.data.synthetic import uniform_problem


def _problem(n, l, k, d, seed=0):
    V = jnp.asarray(uniform_problem(n, d, seed))
    rng = np.random.default_rng(seed + 1)
    sets = [np.asarray(V[rng.choice(n, size=k, replace=False)])
            for _ in range(l)]
    return V, pack_sets(sets)


def _bench_point(tag, n, l, k, d, include_naive=True, naive_cap=32):
    V, pk = _problem(n, l, k, d)
    rows = []
    t_wm = time_call(
        lambda: evaluate_multiset(V, pk, EvalConfig(mode="fused")))
    rows.append((f"{tag}_workmatrix", t_wm, f"n={n};l={l};k={k}"))
    if include_naive:
        sub = pk.slice_sets(0, min(naive_cap, l))  # naive is O(l) python calls
        t_nv = time_call(
            lambda: evaluate_multiset(V, sub, EvalConfig(backend="naive")),
            iters=1)
        t_nv_full = t_nv * (l / sub.num_sets)
        rows.append((f"{tag}_naive(est_full)", t_nv_full,
                     f"speedup={t_nv_full / t_wm:.1f}x"))
    return rows


def run(quick: bool = False):
    rows = []
    d = 100
    base_n, base_l, base_k = (2000, 200, 10) if quick else (8000, 800, 10)
    ns = [500, base_n // 2, base_n] if quick else [1000, 4000, 8000]
    ls = [50, base_l // 2, base_l] if quick else [100, 400, 800]
    ks = [5, 10, 20] if quick else [10, 50, 150]
    for n in ns:  # paper Fig 3/4 left column: vary N
        rows += _bench_point(f"varyN[{n}]", n, base_l, base_k, d)
    for l in ls:  # vary l
        rows += _bench_point(f"varyL[{l}]", base_n, l, base_k, d)
    for k in ks:  # vary k
        rows += _bench_point(f"varyK[{k}]", base_n, base_l, k, d,
                             include_naive=False)
    emit(rows)
    return rows
