"""Quickstart: exemplar-based clustering via submodular maximization.

Selects k exemplars from clustered data with the multiset evaluation engine
(paper's technique), assigns clusters, and compares optimizers.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EvalConfig, ExemplarClustering, fit_exemplar_clustering
from repro.core.optimizers import OPTIMIZERS
from repro.data.synthetic import blobs


def main():
    X, true_labels = blobs(n=2000, dim=32, centers=8, seed=0)
    print(f"data: {X.shape}, {len(set(true_labels))} true clusters")

    model = fit_exemplar_clustering(X, k=8, optimizer="greedy")
    labels = model.assign(X)
    print(f"greedy: f(S) = {model.value:.4f}, "
          f"cluster sizes = {np.bincount(labels).tolist()}")

    # purity vs ground truth
    purity = sum(np.bincount(true_labels[labels == c]).max()
                 for c in range(8)) / len(X)
    print(f"cluster purity vs ground truth: {purity:.2%}")

    # all optimizers, same engine
    import jax.numpy as jnp
    f = ExemplarClustering(jnp.asarray(X))
    base = None
    for name in ("greedy", "lazy_greedy", "stochastic_greedy",
                 "sieve_streaming", "sieve_streaming_pp", "three_sieves",
                 "salsa"):
        res = OPTIMIZERS[name](f, 8)
        base = base or res.value
        print(f"{name:20s} f = {res.value:.4f} ({res.value / base:6.1%} "
              f"of greedy)  evaluations = {res.evaluations}")

    # low-precision evaluation (paper §V-B / future-work question)
    for pol in ("fp32", "bf16", "fp16"):
        m = fit_exemplar_clustering(X, k=8, cfg=EvalConfig(policy=pol))
        print(f"precision {pol:6s}: f(S) = {m.value:.5f}")

    # the selection engine: all k rounds in one jitted dispatch — dense
    # greedy and CELF (stale bounds + top-B re-scoring) both run on device
    from repro.core import greedy, lazy_greedy
    host = greedy(f, 8, mode="host")
    dev = greedy(f, 8, mode="device")
    print(f"device greedy matches host: {host.indices == dev.indices} "
          f"(f = {dev.value:.4f})")
    lhost = lazy_greedy(f, 8, mode="host")
    ldev = lazy_greedy(f, 8, mode="device")
    print(f"device CELF matches host CELF: {lhost.indices == ldev.indices} "
          f"(evaluations: {ldev.evaluations} vs greedy's {dev.evaluations})")

    # mesh-sharded plan: V + min-cache row-shard over all local devices,
    # one O(m) psum per round (run under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 to see >1 shard)
    import jax
    sharded = greedy(f, 8, mode="device_sharded")
    print(f"sharded greedy over {jax.device_count()} device(s) matches: "
          f"{sharded.indices == dev.indices}")


if __name__ == "__main__":
    main()
