"""Batched serving demo: prefill a prompt batch, decode greedily.

Exercises the same prefill/serve steps the dry-run lowers for the 256/512-chip
meshes, here on one CPU device with a reduced model.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch hymba-1.5b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import init_model
from repro.train.step import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    B, P, N = args.batch, args.prompt_len, args.new_tokens
    cache_len = P + N + (cfg.frontend_len if cfg.family == "vlm" else 0)

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family in ("encdec", "vlm"):
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_len, cfg.d_model),
            jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, None, cache_len=cache_len))
    decode = jax.jit(make_serve_step(cfg, None))

    t0 = time.perf_counter()
    next_tok, caches = prefill(params, batch)
    jax.block_until_ready(next_tok)
    t_prefill = time.perf_counter() - t0

    out = [next_tok]
    offset = P + (cfg.frontend_len if cfg.family == "vlm" else 0)
    t0 = time.perf_counter()
    for i in range(N - 1):
        next_tok, caches = decode(
            params, {"tokens": next_tok, "caches": caches,
                     "pos": jnp.asarray(offset + i, jnp.int32)})
        out.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={args.arch} batch={B} prompt={P} new={N}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/max(N-1,1)*1e3:.1f} ms/token")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {prompts[b, -6:].tolist()} => "
              f"{gen[b, :10].tolist()}...")


if __name__ == "__main__":
    main()
