"""Streaming data summarization with sieve optimizers (paper §II use case).

Simulates a stream of observations; SieveStreaming / SieveStreaming++ /
ThreeSieves maintain exemplar summaries on the fly — every arriving element
is offered to all sieves at once, which is exactly the paper's
multiset-parallelized evaluation problem. The stream is consumed in blocks
of ``block_size`` elements: one engine dispatch fetches the whole block's
distances instead of one dispatch per arriving element.

Run: PYTHONPATH=src python examples/streaming_summarization.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import ExemplarClustering, greedy
from repro.core.optimizers import (sieve_streaming, sieve_streaming_pp,
                                   three_sieves)
from repro.data.synthetic import blobs


def main():
    X, _ = blobs(n=4000, dim=64, centers=12, seed=1)
    f = ExemplarClustering(jnp.asarray(X))
    k = 12

    t0 = time.perf_counter()
    offline = greedy(f, k)
    t_greedy = time.perf_counter() - t0
    print(f"offline greedy      f = {offline.value:.4f}  "
          f"({t_greedy:.1f}s, {offline.evaluations} evals)")

    block = 128
    for name, alg, kw in [
        ("sieve_streaming", sieve_streaming, dict(eps=0.1)),
        ("sieve_streaming++", sieve_streaming_pp, dict(eps=0.1)),
        ("three_sieves(T=100)", three_sieves, dict(eps=0.1, T=100)),
    ]:
        t0 = time.perf_counter()
        res = alg(f, k, block_size=block, **kw)
        dt = time.perf_counter() - t0
        # one distance dispatch per stream block; an upper bound because
        # three_sieves may exhaust its threshold grid and stop early
        dispatches = -(-f.n // block)
        print(f"{name:20s}f = {res.value:.4f}  ({dt:.1f}s, "
              f"{res.evaluations} evals, <={dispatches} engine dispatches, "
              f"{res.value/offline.value:.1%} of greedy)")


if __name__ == "__main__":
    main()
