"""Streaming data summarization with sieve optimizers (paper §II use case).

Simulates a stream of observations; SieveStreaming / SieveStreaming++ /
Salsa maintain exemplar summaries on the fly — every arriving element is
offered to all sieves at once, which is exactly the paper's
multiset-parallelized evaluation problem. With ``mode="device"`` the sieve
table lives on the accelerator and each stream block of ``block_size``
elements is consumed by ONE jitted scan dispatch; ``mode="host"`` is the
per-element array mirror it replaces. The ingestion service wraps the same
engine behind an async queue (backpressure + mid-stream snapshots).

Run: PYTHONPATH=src python examples/streaming_summarization.py
"""
import asyncio
import time

import jax.numpy as jnp
import numpy as np

from repro.core import ExemplarClustering, StreamIngestionService, greedy
from repro.core.optimizers import (salsa, sieve_streaming,
                                   sieve_streaming_pp, three_sieves)
from repro.data.synthetic import blobs


def main():
    X, _ = blobs(n=4000, dim=64, centers=12, seed=1)
    f = ExemplarClustering(jnp.asarray(X))
    k = 12

    t0 = time.perf_counter()
    offline = greedy(f, k)
    t_greedy = time.perf_counter() - t0
    print(f"offline greedy      f = {offline.value:.4f}  "
          f"({t_greedy:.1f}s, {offline.evaluations} evals)")

    block = 128
    for name, alg, kw in [
        ("sieve_streaming", sieve_streaming, dict(eps=0.1, mode="device")),
        ("sieve_streaming++", sieve_streaming_pp,
         dict(eps=0.1, mode="device")),
        ("salsa", salsa, dict(eps=0.1, mode="device")),
        ("three_sieves(T=100)", three_sieves, dict(eps=0.1, T=100)),
    ]:
        t0 = time.perf_counter()
        res = alg(f, k, block_size=block, **kw)
        dt = time.perf_counter() - t0
        # device modes: one scan dispatch per stream block (upper bound —
        # three_sieves runs on host and may stop early)
        dispatches = -(-f.n // block)
        print(f"{name:20s}f = {res.value:.4f}  ({dt:.1f}s, "
              f"{res.evaluations} evals, <={dispatches} engine dispatches, "
              f"{res.value/offline.value:.1%} of greedy)")

    # the same engine as a service: queue in, exemplars out
    async def serve():
        order = np.random.default_rng(0).permutation(f.n)
        async with StreamIngestionService(f, k=k, mode="device",
                                          block_size=block) as svc:
            await svc.offer_batch(np.asarray(X)[order])
            await svc.drain()
            return await svc.snapshot()

    snap = asyncio.run(serve())
    print(f"{'ingestion service':20s}f = {snap.value:.4f}  "
          f"({snap.n_ingested} ingested, {snap.n_accepted} accepted, "
          f"{snap.value/offline.value:.1%} of greedy)")


if __name__ == "__main__":
    main()
