"""End-to-end driver: train an LM with submodular data curation.

Trains a small qwen3-family model on a synthetic topic-skewed corpus twice —
once on raw (redundant) batches, once with the exemplar-coreset curation
pipeline selecting topic-diverse examples — and compares loss trajectories.
The curation selection runs through the paper's multiset evaluation engine.

CPU-scale by default (~10M params, 100 steps). ``--full`` requests the
~100M-param / 300-step configuration intended for real accelerators.

Run: PYTHONPATH=src python examples/train_lm_curated.py [--steps N] [--full]
"""
import argparse
import dataclasses

from repro.configs import get_reduced_config
from repro.data.pipeline import CurationConfig, token_batches
from repro.data.synthetic import TopicTokenStream
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true",
                    help="~100M params / 300 steps (accelerator scale)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_reduced_config("qwen3-0.6b")
    if args.full:
        cfg = dataclasses.replace(
            cfg, name="qwen3-100m", num_layers=12, d_model=640,
            num_heads=10, num_kv_heads=5, d_ff=1792, head_dim=64,
            vocab_size=50304, max_seq_len=1024)
        args.steps = max(args.steps, 300)
    else:
        cfg = dataclasses.replace(cfg, num_layers=4, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=384, vocab_size=2048)
    print(f"model: {cfg.name}  params ≈ {cfg.approx_params()/1e6:.1f}M")

    B, S = (8, 256) if args.full else (8, 64)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)

    results = {}
    for label, curation in [
        ("raw", None),
        ("curated", CurationConfig(window=4 * B, select=B)),
    ]:
        stream = TopicTokenStream(cfg.vocab_size, n_topics=12, seed=0)
        batches = token_batches(cfg.vocab_size, B, S, steps=args.steps,
                                seed=0, curation=curation, topic_skew=6.0,
                                stream=stream)
        tc = TrainConfig(steps=args.steps, log_every=10,
                         ckpt_every=max(args.steps // 2, 1),
                         ckpt_dir=(f"{args.ckpt_dir}/{label}"
                                   if args.ckpt_dir else None))
        _, hist = train(cfg, tc, opt, batches)
        results[label] = hist
        print(f"\n== {label} ==")
        for h in hist:
            print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
                  f"({h['step_time_s']:.2f}s/step)")

    raw_last = results["raw"][-1]["loss"]
    cur_last = results["curated"][-1]["loss"]
    print(f"\nfinal loss — raw: {raw_last:.4f}  curated: {cur_last:.4f}  "
          f"(Δ {raw_last - cur_last:+.4f}; curated batches are "
          f"topic-diverse exemplar coresets)")


if __name__ == "__main__":
    main()
