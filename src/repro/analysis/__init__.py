"""Static analysis of the engine's compiled artifacts and source tree.

Two layers:

* **Compiled-artifact audit** (:mod:`repro.analysis.jaxpr_audit`,
  :mod:`repro.analysis.registry`) — every jitted entry point carries a
  :func:`repro.analysis.contracts.contract` declaring its structural
  invariants (one-dispatch scan count, per-round collective budget,
  donation set, precision flow, analytic per-device byte bound). The
  auditor traces each entry point with abstract values over the documented
  signature grid, walks the jaxpr and the lowered StableHLO text, and
  proves the claims hold in the artifact XLA actually compiles.
* **Source lint** (:mod:`repro.analysis.lint`) — an AST pass over
  ``src/repro`` catching trace-unsafe idioms before they reach a tracer:
  Python branches on scan-body operands, host casts of tracers, float
  equality, ``np.`` compute inside jitted code, missing ``static_argnames``.

CLI: ``python -m repro.analysis.audit [--json OUT] [--lint-only]
[--audit-only]`` — exits non-zero on any violation. The negative-fixture
suite in ``tests/test_analysis.py`` proves each checker actually detects
the defect class it exists for.
"""
from repro.analysis.contracts import CONTRACTS, Contract, contract

__all__ = ["CONTRACTS", "Contract", "contract"]
