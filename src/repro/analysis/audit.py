"""CLI: prove the engine's compiled-artifact contracts and lint the tree.

    python -m repro.analysis.audit [--json OUT] [--lint-only]
                                   [--audit-only] [--quick]
                                   [--filter SUBSTR]

Exit status is non-zero on ANY violation: a traced case whose artifact
breaks its contract, a runtime check failure (retrace on a same-signature
call, donation mismatch), a lint finding, or a registered contract with no
audit coverage. CI runs this as a blocking job.

Run it with 2 forced host devices to exercise the sharded-plan contracts:

    JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        python -m repro.analysis.audit
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _load_contracts():
    """Import the core modules so their @contract decorators register."""
    from repro.core import distributed, engine, service, streaming  # noqa: F401
    from repro.analysis.contracts import CONTRACTS

    return CONTRACTS


def run_audit(quick: bool = False, case_filter: str = ""):
    from repro.analysis import report as rep
    from repro.analysis.registry import build_cases, runtime_checks

    contracts = _load_contracts()
    cases = build_cases(quick=quick)
    if case_filter:
        cases = [c for c in cases if case_filter in c.label]
    results = []
    t0 = time.perf_counter()
    for i, case in enumerate(cases):
        try:
            r = rep.evaluate_case(case)
        except Exception as e:  # a case that cannot even trace is a failure
            r = rep.CaseResult(
                label=case.label, contract=case.contract,
                violations=[rep.Violation("trace", f"{type(e).__name__}: {e}")],
                metrics={})
        results.append(r)
        if not r.ok:
            print(f"FAIL {r.label}", file=sys.stderr)
            for v in r.violations:
                print(f"     {v}", file=sys.stderr)
    elapsed = time.perf_counter() - t0

    covered = {c.contract for c in cases}
    uncovered = [] if case_filter else sorted(
        name for name, c in contracts.items()
        if name not in covered and not c.extra.get("runtime_only"))

    rt_results = []
    if not case_filter:
        for check in runtime_checks():
            try:
                ok, detail = check.run()
            except Exception as e:
                ok, detail = False, f"{type(e).__name__}: {e}"
            rt_results.append({"name": check.name, "ok": ok,
                               "detail": detail})
            if not ok:
                print(f"FAIL runtime {check.name}: {detail}",
                      file=sys.stderr)
    return results, rt_results, uncovered, elapsed


def run_lint():
    from repro.analysis.lint import lint_tree

    root = Path(__file__).resolve().parents[1]   # src/repro
    findings = lint_tree(root)
    for f in findings:
        print(f"LINT {f}", file=sys.stderr)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="compiled-contract audit + source lint")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write the machine-readable report to OUT")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--audit-only", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="one case per contract (smoke run)")
    ap.add_argument("--filter", default="",
                    help="only audit cases whose label contains SUBSTR")
    args = ap.parse_args(argv)

    results, rt_results, uncovered, elapsed = [], [], [], 0.0
    findings = []
    if not args.lint_only:
        results, rt_results, uncovered, elapsed = run_audit(
            quick=args.quick, case_filter=args.filter)
    if not args.audit_only:
        findings = run_lint()

    import jax

    from repro.analysis import report as rep

    payload = rep.build_report(results, rt_results, findings,
                               device_count=jax.device_count())
    payload["summary"]["uncovered_contracts"] = uncovered
    payload["summary"]["audit_seconds"] = round(elapsed, 2)
    if args.json:
        Path(args.json).write_text(json.dumps(payload, indent=2))

    s = payload["summary"]
    ok = s["ok"] and not uncovered
    print(f"contracts audited : {s['contracts']}")
    print(f"cases traced      : {s['cases']} "
          f"({s['cases_failed']} failed, {elapsed:.1f}s)")
    print(f"runtime checks    : {s['runtime_checks']} "
          f"({s['runtime_failed']} failed)")
    print(f"lint findings     : {s['lint_findings']}")
    if uncovered:
        print(f"UNCOVERED contracts (registered, no audit case): "
              f"{uncovered}", file=sys.stderr)
    print("AUDIT " + ("OK" if ok else "FAILED"))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
