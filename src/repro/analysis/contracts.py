"""Performance-contract declarations for jitted entry points.

A :class:`Contract` is the machine-readable form of the claims CHANGES.md
states in prose — "all k rounds in ONE dispatch", "ONE psum of O(m) bytes
per scored batch", "the cache seed is donated", "gains stay in the compute
dtype". The :func:`contract` decorator registers one against a jitted entry
point (or a factory that builds one); the audit registry
(:mod:`repro.analysis.registry`) turns each into concrete traced cases and
:mod:`repro.analysis.jaxpr_audit` proves the invariants against the jaxpr
and the lowered StableHLO.

This module is imported by ``repro.core.*`` at definition time, so it must
stay dependency-free: no jax, no numpy, no core imports — just the registry
dict and the dataclass.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

#: Global contract registry, keyed by contract name. Populated at import
#: time by the ``@contract`` decorators on the core entry points; the audit
#: imports the core modules and reads this.
CONTRACTS: Dict[str, "Contract"] = {}


@dataclasses.dataclass(frozen=True)
class Contract:
    """Declared structural invariants of one jitted entry point.

    The fields are the *vocabulary*; exact per-case expected numbers (a
    graph-cut round carries one extra owner-gather psum, a sharded-pool
    round streams one take per block, ...) are derived by the audit
    registry from the case's (plan, strategy, function, backend) — the
    contract pins the shape of the claim, the registry pins the arithmetic.
    """

    name: str
    #: the registered callable: the jitted entry point itself, or — when
    #: ``factory`` — a builder returning one (mesh-sharded scans are built
    #: per (mesh, statics) and cached; the audit calls the real factory so
    #: it audits the exact executable production code runs).
    fn: Callable = dataclasses.field(compare=False, repr=False)
    factory: bool = False
    #: number of jitted *dispatches* one logical call costs. Always 1 here —
    #: the whole point of the engine — and the audit additionally proves the
    #: inside: the k rounds (or the B stream elements) drive exactly
    #: ``driving_scans`` top-level ``lax.scan``s whose length is the
    #: case's round/block count, never an unrolled or re-dispatched loop.
    dispatches: int = 1
    #: expected number of top-level scans driven by the round/element axis.
    #: GreeDi's two-phase dispatch legitimately drives three (partition
    #: greedy, the p-solution evaluation map, merge greedy).
    driving_scans: int = 1
    #: collective kinds allowed anywhere in the artifact. Empty = the
    #: artifact must be collective-free (single-device plans); the audit
    #: checks *exact* per-case counts for the allowed kinds, so both a
    #: sneaked-in extra collective and a silently-dropped one fail.
    collective_kinds: Tuple[str, ...] = ()
    #: names of donated arguments. The audit asserts the lowered module
    #: aliases exactly this many inputs onto outputs and — the silent
    #: failure mode — that NO donated buffer is left un-aliased (XLA only
    #: warns; ``jax.buffer_donor`` without ``tf.aliasing_output`` in the
    #: StableHLO is the dropped-donation signature).
    donate: Tuple[str, ...] = ()
    #: apply the precision-flow rule: under a half-precision policy no
    #: ``convert_element_type`` may widen a distance-tile-sized half value
    #: to fp32 — only the declared O(n)-and-smaller accumulators (cache
    #: rows, psum payloads, trajectory scalars) may widen.
    precision: bool = True
    #: check the compiled executable's ``memory_analysis()`` temp bytes
    #: against the case's analytic per-device working-set bound (where the
    #: backend reports one) — the machine-checked half of ROADMAP item 5.
    memory: bool = False
    #: short human description for the README table / report.
    claim: str = ""
    extra: Mapping[str, Any] = dataclasses.field(default_factory=dict)


def contract(
    name: str,
    *,
    factory: bool = False,
    dispatches: int = 1,
    driving_scans: int = 1,
    collective_kinds: Tuple[str, ...] = (),
    donate: Tuple[str, ...] = (),
    precision: bool = True,
    memory: bool = False,
    claim: str = "",
    **extra: Any,
) -> Callable:
    """Register a performance contract against the decorated entry point.

    Stack it *above* ``@jax.jit`` so the registered object is the jitted
    callable (jit wrappers reject attribute writes, so the registry holds
    the reference — the decorator returns its target untouched).
    """

    def register(fn: Callable) -> Callable:
        if name in CONTRACTS:
            raise ValueError(f"duplicate contract {name!r}")
        CONTRACTS[name] = Contract(
            name=name, fn=fn, factory=factory, dispatches=dispatches,
            driving_scans=driving_scans,
            collective_kinds=tuple(collective_kinds), donate=tuple(donate),
            precision=precision, memory=memory, claim=claim, extra=extra)
        return fn

    return register
