"""Jaxpr / StableHLO walkers behind the compiled-artifact audit.

Everything here operates on *traced* artifacts — ``jitted.trace(*abstract)``
jaxprs and their lowered StableHLO text — never on live arrays, so the
whole audit runs with abstract values (ShapeDtypeStructs) and costs traces,
not executions.

The checks map one-to-one onto the engine's prose claims:

* :func:`scan_structure` — "all k rounds in ONE dispatch": the artifact
  drives exactly the declared number of top-level ``lax.scan``s whose
  static ``length`` equals the round/element count; CELF's top-B loop is
  exactly one ``while`` nested in that scan.
* :func:`collective_census` — "ONE psum of O(m) bytes per scored batch":
  exact static collective counts, per region (whole artifact vs the
  driving scan's body) and kind, plus the byte size of the largest
  collective operand. Exact equality catches a sneaked-in extra collective
  AND a silently dropped one.
* :func:`donation_audit` — "the cache seed is donated": the lowered
  module's entry signature must alias exactly the declared number of
  inputs onto outputs (``tf.aliasing_output``), and no donated buffer may
  be left un-aliased. A bare ``jax.buffer_donor`` marker is ambiguous:
  on a multi-device mesh jax defers the aliasing decision to XLA's SPMD
  partitioner, so :func:`resolve_deferred_donations` re-judges those
  markers against the compiled ``input_output_alias`` table — only a
  donor the compiled executable does not alias counts as silently
  dropped (XLA's run-time-warning-only failure mode).
* :func:`precision_flow` — "gains stay in the compute dtype": under a
  half-precision policy no ``convert_element_type`` may widen a
  distance-tile-sized half tensor to fp32 (widening rides the matmul's
  ``preferred_element_type`` instead); at least one ``dot_general`` must
  consume half-dtype operands, proving the payload actually went through
  the unit in half precision.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Any, Iterator, Optional

import numpy as np

try:  # jax.core keeps these public names; fall back for renamed internals
    from jax.core import ClosedJaxpr, Jaxpr, JaxprEqn
except ImportError:  # pragma: no cover
    from jax._src.core import ClosedJaxpr, Jaxpr, JaxprEqn

#: Cross-device communication primitives. ``axis_index`` is free (no data
#: movement) and deliberately excluded.
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pmax", "pmin", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "reduce_scatter", "pgather",
})

#: Primitives that merely wrap an inner jaxpr in the *same* iteration space
#: — a scan inside these is still a top-level scan of the artifact.
_WRAPPER_PRIMS = frozenset({
    "pjit", "jit", "closed_call", "core_call", "xla_call", "shard_map",
    "remat", "checkpoint", "remat2", "custom_jvp_call", "custom_vjp_call",
    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
    "custom_vjp_call_custom_transpose",
})

#: Control-flow primitives that repeat or branch their body — a scan inside
#: them runs per iteration, not once per dispatch.
_LOOP_PRIMS = frozenset({"scan", "while", "cond"})


def _param_jaxprs(eqn: JaxprEqn) -> Iterator[Jaxpr]:
    """All sub-jaxprs an equation carries (scan/while/cond/pjit/...)."""
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for x in items:
            if isinstance(x, ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, Jaxpr):
                yield x


def iter_eqns(jaxpr: Jaxpr, *, into_loops: bool = True) -> Iterator[JaxprEqn]:
    """Depth-first equation walk; ``into_loops=False`` stops at scan/while/
    cond bodies (but still descends pjit/shard_map wrappers)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if not into_loops and eqn.primitive.name in _LOOP_PRIMS:
            continue
        for sub in _param_jaxprs(eqn):
            yield from iter_eqns(sub, into_loops=into_loops)


def _as_jaxpr(x) -> Jaxpr:
    return x.jaxpr if isinstance(x, ClosedJaxpr) else x


def top_level_scans(jaxpr) -> list[JaxprEqn]:
    """Scan equations that execute exactly once per dispatch (descending
    through pjit/shard_map wrappers, stopping at loop bodies)."""
    return [e for e in iter_eqns(_as_jaxpr(jaxpr), into_loops=False)
            if e.primitive.name == "scan"]


def scan_length(eqn: JaxprEqn) -> Optional[int]:
    return eqn.params.get("length")


def driving_scans(jaxpr, length: int) -> list[JaxprEqn]:
    """Top-level scans whose trip count is the round/element count — the
    one-dispatch claim's "the k rounds ARE the scan" half."""
    return [e for e in top_level_scans(jaxpr) if scan_length(eqn=e) == length]


def count_whiles(jaxpr) -> int:
    return sum(1 for e in iter_eqns(_as_jaxpr(jaxpr))
               if e.primitive.name == "while")


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    except Exception:  # pragma: no cover — non-array avals
        return 0


@dataclasses.dataclass
class CollectiveCensus:
    """Static collective counts for one region of the artifact."""

    counts: Counter               # primitive name -> static eqn count
    max_operand_bytes: int        # largest single collective operand

    @property
    def total(self) -> int:
        return sum(self.counts.values())


def collective_census(jaxpr) -> CollectiveCensus:
    counts: Counter = Counter()
    max_bytes = 0
    for eqn in iter_eqns(_as_jaxpr(jaxpr)):
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            counts[eqn.primitive.name] += 1
            for v in eqn.invars:
                max_bytes = max(max_bytes, _aval_bytes(v.aval))
    return CollectiveCensus(counts, max_bytes)


def scan_body(eqn: JaxprEqn) -> Jaxpr:
    return _as_jaxpr(eqn.params["jaxpr"])


@dataclasses.dataclass
class ScanStructure:
    top_scans: int                #: top-level scan count
    driving: int                  #: of those, trip count == rounds
    whiles: int                   #: while loops anywhere
    driving_body: Optional[Jaxpr]  #: first driving scan's body (census target)


def scan_structure(jaxpr, rounds: int) -> ScanStructure:
    tops = top_level_scans(jaxpr)
    driving = [e for e in tops if scan_length(e) == rounds]
    return ScanStructure(
        top_scans=len(tops), driving=len(driving), whiles=count_whiles(jaxpr),
        driving_body=scan_body(driving[0]) if driving else None)


# ---------------------------------------------------------------------------
# Donation — parsed from the lowered StableHLO entry signature.
# ---------------------------------------------------------------------------

_MAIN_SIG = re.compile(
    r"func\.func\s+(?:public\s+)?@main\((?P<args>.*?)\)\s*->", re.S)


@dataclasses.dataclass
class DonationTable:
    aliased: int        #: inputs carrying ``tf.aliasing_output`` (donated AND aliased)
    dropped: int        #: inputs carrying ``jax.buffer_donor`` (donated, NOT aliased)

    def ok(self, expected_aliased: int) -> bool:
        return self.aliased == expected_aliased and self.dropped == 0


def donation_audit(hlo_text: str) -> DonationTable:
    """Count donated-and-aliased vs donated-but-dropped entry arguments.

    jax marks an argument it could alias onto an output with
    ``tf.aliasing_output = N`` and one it was *asked* to donate but could
    not alias with ``jax.buffer_donor = true`` — the latter is the silent
    dropped-donation signature (XLA only warns at execution time).
    """
    m = _MAIN_SIG.search(hlo_text)
    if m is None:  # pragma: no cover — lowering always emits @main
        raise ValueError("no @main entry function in lowered module")
    sig = m.group("args")
    return DonationTable(
        aliased=len(re.findall(r"tf\.aliasing_output", sig)),
        dropped=len(re.findall(r"jax\.buffer_donor", sig)))


_ALIAS_ENTRY = re.compile(r"\{\d+[^}]*\}:\s*\(\d+,")


def resolve_deferred_donations(table: DonationTable,
                               lowered) -> DonationTable:
    """Re-judge ``jax.buffer_donor`` markers against the compiled executable.

    Single-device lowering decides aliasing up front (``tf.aliasing_output``
    in the entry signature). Under a multi-device mesh jax *defers* the
    decision instead: the StableHLO carries only ``jax.buffer_donor`` and
    XLA picks the aliasing after SPMD partitioning, so the marker alone is
    ambiguous — it reads identically for "aliased at compile time" and
    "dropped". Disambiguate by parsing the compiled module's
    ``input_output_alias`` table: every donor that landed there is a real
    alias; whatever the table does not cover stays dropped. Costs one
    compile, so callers should only reach for this when the cheap static
    pass reports deferred donors.
    """
    if table.dropped == 0:
        return table
    try:
        text = lowered.compile().as_text()
    except Exception:  # pragma: no cover — backend can't print: stay strict
        return table
    # the table lives on the HLO header line: ``input_output_alias={ {0}:
    # (0, {}, may-alias), … }``; entry keys ``{N}: (M,`` are unambiguous on
    # that line (layout suffixes like ``f32[8]{0}`` are never followed by a
    # colon), so count keys rather than brace-balance the nested braces
    m = re.search(r"input_output_alias=\{(?P<line>[^\n]*)", text)
    if m is None:
        return table
    entries = len(_ALIAS_ENTRY.findall(m.group("line")))
    # the compiled table covers tf.aliasing_output params too; only the
    # surplus beyond the statically-aliased count vouches for donors
    resolved = min(table.dropped, max(0, entries - table.aliased))
    return DonationTable(aliased=table.aliased + resolved,
                         dropped=table.dropped - resolved)


# ---------------------------------------------------------------------------
# Precision flow
# ---------------------------------------------------------------------------

_HALF = ("bfloat16", "float16")


@dataclasses.dataclass
class PrecisionReport:
    widens: list[tuple[str, int]]   #: (shape str, elems) of each flagged widen
    half_dots: int                  #: dot_generals consuming half operands

    def ok(self, *, require_half_dot: bool) -> bool:
        return not self.widens and (self.half_dots > 0
                                    or not require_half_dot)


def precision_flow(jaxpr, *, min_widen_elems: int) -> PrecisionReport:
    """Flag half→fp32 ``convert_element_type`` on big tensors.

    Widening an O(n)-sized accumulator (cache rows, psum payloads,
    trajectory scalars) is the declared exception; widening anything of
    distance-tile size means the artifact materialized a half tile and
    up-converted it — the exact traffic the compute/accum dtype split
    exists to avoid (the matmul widens for free via
    ``preferred_element_type``).

    Converts *inside* ``pallas_call`` kernel bodies are exempt: a kernel
    widens VMEM-resident tiles at register level (ordinary mixed-precision
    practice — the f32 tile never reaches HBM), so the rule governs only
    the artifact-level dataflow around kernels. Half-dtype ``dot_general``
    counting still descends into kernels — the proof that the payload
    reached the unit in half precision lives wherever the matmul does.
    """
    widens: list[tuple[str, int]] = []
    half_dots = 0

    def walk(j: Jaxpr, in_kernel: bool):
        nonlocal half_dots
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "convert_element_type" and not in_kernel:
                src = eqn.invars[0].aval
                dst = str(eqn.params.get("new_dtype"))
                elems = int(np.prod(src.shape, dtype=np.int64)) \
                    if src.shape else 1
                if (str(src.dtype) in _HALF and dst == "float32"
                        and elems >= min_widen_elems):
                    widens.append((f"{src.dtype}{list(src.shape)}", elems))
            elif name == "dot_general":
                if any(str(v.aval.dtype) in _HALF for v in eqn.invars[:2]):
                    half_dots += 1
            for sub in _param_jaxprs(eqn):
                walk(sub, in_kernel or name == "pallas_call")

    walk(_as_jaxpr(jaxpr), False)
    return PrecisionReport(widens=widens, half_dots=half_dots)


# ---------------------------------------------------------------------------
# Trace + lower helper
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TracedArtifact:
    jaxpr: Any            # ClosedJaxpr
    hlo: str              # StableHLO text
    lowered: Any          # jax.stages.Lowered (for optional compile)
    #: donations stripped at lowering. A donated buffer XLA cannot alias is
    #: dropped with only a UserWarning ("Some donated buffers were not
    #: usable") on backends without buffer-donor support — the audit
    #: captures the warning so the silent path is machine-checked too.
    dropped_donations: int = 0


def trace_artifact(fn, args, kwargs) -> TracedArtifact:
    """Trace a jitted callable with abstract values and lower it once."""
    import warnings

    traced = fn.trace(*args, **kwargs)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        lowered = traced.lower()
    dropped = sum(1 for w in caught
                  if "donated buffers were not usable" in str(w.message))
    return TracedArtifact(jaxpr=traced.jaxpr, hlo=lowered.as_text(),
                          lowered=lowered, dropped_donations=dropped)


def memory_temp_bytes(lowered) -> Optional[int]:
    """Compiled temp-buffer bytes, or None where the backend reports none.

    This is the per-device *working set* beyond arguments/outputs — the
    number the analytic byte bound constrains: an artifact that
    materializes the full (n, m) distance matrix shows up here no matter
    how honest its jaxpr looks.
    """
    try:
        ma = lowered.compile().memory_analysis()
        return int(ma.temp_size_in_bytes) if ma is not None else None
    except Exception:
        return None
