"""Source lint: AST pass over ``src/repro`` for trace-unsafe idioms.

A traced function sees :class:`~jax.core.Tracer` values, not numbers, so a
class of perfectly ordinary Python is silently wrong (or a loud
``TracerBoolConversionError``) once it reaches a scan body or a jitted
function. These rules catch the idioms *before* a trace does:

``tracer-branch``
    Python ``if``/``while`` on a parameter of a function handed to
    ``lax.scan`` / ``while_loop`` / ``fori_loop`` / ``cond`` / ``map`` /
    ``switch``. The body runs ONCE at trace time — branching on a traced
    operand either crashes or, worse, bakes one branch into every
    iteration. Use ``jnp.where`` / ``lax.cond``.
``tracer-cast``
    ``float()`` / ``int()`` / ``bool()`` on such a parameter — a host
    round-trip that cannot exist inside a traced loop body.
``float-eq``
    ``==`` / ``!=`` against a float literal. Threshold grids and gain
    comparisons must use tolerance or integer exponents (the exact bug
    class behind the sieve threshold-grid fix).
``np-in-jit``
    ``np.`` calls fed a *parameter* of a jitted function. NumPy on a
    tracer forces a concretization error at best; at worst it constant-
    folds a value that should be data. (``np`` used for static shape
    arithmetic on non-parameters is fine and not flagged.)
``missing-static``
    A ``str``- or ``bool``-defaulted parameter of a jitted function that
    is not listed in ``static_argnames`` — it would be traced as data and
    fail on the first call (or silently retrace per value if hashable).

Suppress a finding with a trailing ``# lint: allow(<rule>)`` comment on
the offending line.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterator, Optional

#: control-flow entry points whose function operands become traced bodies
_TRACE_CALLERS = frozenset(
    {"scan", "while_loop", "fori_loop", "cond", "map", "switch"})

_ALLOW = re.compile(r"#\s*lint:\s*allow\(([\w\-,\s]+)\)")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> str:
    """'jax.lax.scan' for an Attribute/Name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _param_names(fn: ast.AST) -> set[str]:
    a = fn.args
    params = [*a.posonlyargs, *a.args, *a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg)
    if a.kwarg:
        params.append(a.kwarg)
    return {p.arg for p in params}


def _jit_static_argnames(dec: ast.expr) -> Optional[set[str]]:
    """static_argnames if ``dec`` is a jit decorator, else None.

    Recognizes ``@jax.jit``, ``@jit``, ``@partial(jax.jit, ...)`` and the
    direct-call form ``@jax.jit(...)``.
    """
    if _dotted(dec) in ("jax.jit", "jit"):
        return set()
    if not isinstance(dec, ast.Call):
        return None
    head = _dotted(dec.func)
    if head in ("jax.jit", "jit"):
        call = dec
    elif head in ("partial", "functools.partial") and dec.args \
            and _dotted(dec.args[0]) in ("jax.jit", "jit"):
        call = dec
    else:
        return None
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            names: set[str] = set()
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
            return names
    return set()


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[LintFinding] = []
        #: names of defs handed to lax control flow, + inline lambdas
        self.trace_called: set[str] = set()
        self.trace_lambdas: list[ast.Lambda] = []
        self._defs: list[ast.AST] = []

    # -- pass 1: collect ----------------------------------------------------

    def visit_Call(self, node: ast.Call):
        head = _dotted(node.func)
        parts = head.split(".")
        # only lax control flow traces its operand (jax.tree.map does not)
        if parts[-1] in _TRACE_CALLERS and \
                parts[:-1] in (["lax"], ["jax", "lax"]):
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    self.trace_called.add(arg.id)
                elif isinstance(arg, ast.Lambda):
                    self.trace_lambdas.append(arg)
                elif isinstance(arg, ast.Call) and \
                        _dotted(arg.func) in ("partial", "functools.partial"):
                    for inner in arg.args[:1]:
                        if isinstance(inner, ast.Name):
                            self.trace_called.add(inner.id)
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        self._defs.append(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- findings -----------------------------------------------------------

    def _allowed(self, line: int, rule: str) -> bool:
        if 1 <= line <= len(self.lines):
            m = _ALLOW.search(self.lines[line - 1])
            if m and rule in {s.strip() for s in m.group(1).split(",")}:
                return True
        return False

    def _emit(self, node: ast.AST, rule: str, message: str):
        if not self._allowed(node.lineno, rule):
            self.findings.append(
                LintFinding(self.path, node.lineno, rule, message))

    def _check_traced_body(self, fn: ast.AST, label: str):
        params = _param_names(fn)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                # nested defs get their own pass iff also trace-called
                if isinstance(node, (ast.If, ast.While)):
                    hot = _names_in(node.test) & params
                    if hot:
                        self._emit(
                            node, "tracer-branch",
                            f"Python {'if' if isinstance(node, ast.If) else 'while'}"
                            f" on traced operand(s) {sorted(hot)} in scan body"
                            f" {label!r} — use jnp.where / lax.cond")
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool"):
                    hot = params & set().union(
                        *(_names_in(a) for a in node.args)) if node.args \
                        else set()
                    if hot:
                        self._emit(
                            node, "tracer-cast",
                            f"{node.func.id}() on traced operand(s) "
                            f"{sorted(hot)} in scan body {label!r} — a host "
                            f"round-trip cannot run inside a traced loop")

    def _check_float_eq(self, tree: ast.AST):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Compare):
                continue
            operands = [node.left, *node.comparators]
            if any(isinstance(o, ast.Constant) and isinstance(o.value, float)
                   for o in operands) and \
                    any(isinstance(op, (ast.Eq, ast.NotEq)) for op in node.ops):
                self._emit(node, "float-eq",
                           "exact ==/!= against a float literal — compare "
                           "with a tolerance or an integer exponent")

    def _check_jitted(self, fn: ast.AST):
        statics: Optional[set[str]] = None
        for dec in fn.decorator_list:
            s = _jit_static_argnames(dec)
            if s is not None:
                statics = s
        if statics is None:
            return
        params = _param_names(fn)
        traced = params - statics
        # str/bool defaults are config, not data: they must be static
        a = fn.args
        pos = [*a.posonlyargs, *a.args]
        for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
            self._flag_config_default(fn, p, d, statics)
        for p, d in zip(a.kwonlyargs, a.kw_defaults):
            if d is not None:
                self._flag_config_default(fn, p, d, statics)
        for node in ast.walk(ast.Module(body=fn.body, type_ignores=[])):
            if isinstance(node, ast.Call):
                head = _dotted(node.func)
                if head.startswith("np.") or head.startswith("numpy."):
                    hot = traced & set().union(
                        set(), *(_names_in(arg) for arg in node.args))
                    if hot:
                        self._emit(
                            node, "np-in-jit",
                            f"np call {head!r} on traced argument(s) "
                            f"{sorted(hot)} inside jitted "
                            f"{getattr(fn, 'name', '<fn>')!r} — use jnp")

    def _flag_config_default(self, fn, param, default, statics):
        if isinstance(default, ast.Constant) and \
                isinstance(default.value, (str, bool)) and \
                param.arg not in statics:
            self._emit(
                param, "missing-static",
                f"parameter {param.arg!r} of jitted "
                f"{getattr(fn, 'name', '<fn>')!r} defaults to "
                f"{default.value!r} but is not in static_argnames — it "
                f"would be traced as data")

    # -- driver -------------------------------------------------------------

    def run(self, tree: ast.AST) -> list[LintFinding]:
        self.visit(tree)
        for fn in self._defs:
            if fn.name in self.trace_called:
                self._check_traced_body(fn, fn.name)
            self._check_jitted(fn)
        for lam in self.trace_lambdas:
            self._check_traced_body(lam, "<lambda>")
        self._check_float_eq(tree)
        self.findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return self.findings


def lint_source(source: str, path: str = "<string>") -> list[LintFinding]:
    return _Linter(path, source).run(ast.parse(source))


def lint_tree(root) -> list[LintFinding]:
    """Lint every ``.py`` under ``root`` (the audit runs it on src/repro)."""
    root = Path(root)
    findings: list[LintFinding] = []
    for p in sorted(root.rglob("*.py")):
        findings.extend(lint_source(p.read_text(), str(p)))
    return findings
