"""Audit-case grid: every contract × its documented signature space.

The registry turns each :mod:`repro.analysis.contracts` declaration into
concrete traceable cases over the grid the engine documents — execution
plans × round strategies × the device-eligible function zoo × scoring
backends × precision policies × batch sizes — and computes the *exact*
expected numbers (scan counts, static collective counts, donation arity,
precision thresholds) the contract's prose implies for that case.

The arithmetic lives here, next to its derivation comments, so a reviewer
can trace every expected count back to the code path that issues it; the
auditor (:mod:`repro.analysis.report`) only compares.

Cases trace with abstract values (ShapeDtypeStructs): nothing here
allocates a ground set or dispatches a kernel. The separate
:func:`runtime_checks` list executes a handful of tiny concrete problems
for the claims tracing cannot see — jit-cache stability (zero retraces on
a same-signature second call) and live donation (``seed.is_deleted()``
matching the executable's aliasing table).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from functools import partial
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import functions as fx
from repro.core.functions import FnSpec

# --- grid shapes -----------------------------------------------------------
# Chosen so every structural number is distinctive: the rounds-scan length
# (k) differs from the blocked-scoring map length (m/block) and the stream
# block size, so a scan found with length k IS the rounds scan.
N = 48          #: ground-set rows
D = 8           #: feature dim
K = 5           #: selection rounds (the driving-scan length)
M_STOCH = 16    #: stochastic per-round sample width
BLOCK_M = 16    #: jnp streaming block (dense: 48/16 = 3 map steps ≠ K)
TOP_B = 8       #: CELF re-score width
B_BLOCK = 6     #: stream block (the streaming driving-scan length)
SIEVE_K = 4
SIEVE_EPS = 0.2

#: The device-plan function zoo (DEVICE_PLAN_ELIGIBLE), as static FnSpecs.
SPECS = {
    "exemplar": FnSpec(),
    "facility_location": FnSpec("facility_location"),
    "graph_cut": FnSpec("graph_cut", lam=0.5),
    "saturated_coverage": FnSpec("saturated_coverage", sat=0.25),
}

BACKENDS = ("jnp", "pallas_interpret")
POLICIES = ("fp32", "bf16")
KINDS = ("dense", "stochastic", "lazy")


@dataclasses.dataclass
class Expect:
    """Exact expected numbers for one traced case."""

    rounds: int                       #: driving-scan trip count
    top_scans: int                    #: top-level scan eqns
    driving: int                      #: of those, trip count == rounds
    whiles: int                       #: while eqns anywhere
    collectives: Counter              #: exact static counts by primitive
    body_psums: Optional[int]         #: psums inside the driving scan body
    max_collective_bytes: Optional[int]  #: bound on any collective operand
    donated: int                      #: inputs that must alias an output
    min_widen_elems: Optional[int]    #: precision check threshold (None=skip)
    require_half_dot: bool = False
    memory_bound: Optional[int] = None  #: compiled temp-bytes bound


@dataclasses.dataclass
class AuditCase:
    contract: str
    label: str
    build: Callable[[], tuple]        #: () -> (jitted_fn, args, kwargs)
    expect: Expect


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _eff_backend(spec: FnSpec, backend: str) -> str:
    # mirror of run_selection's normalization: a function with no kernel
    # template (saturated coverage) scores through jnp on any backend
    return backend if fx.kernel_template(spec) is not None else "jnp"


def _m_eff(kind: str) -> int:
    return {"dense": N, "stochastic": M_STOCH, "lazy": 0}[kind]


def _m_scored_max(kind: str) -> int:
    # widest single scored batch: dense scores all n every round, stochastic
    # its m-row sample, lazy seeds bounds over all n then re-scores top_b
    return {"dense": N, "stochastic": M_STOCH, "lazy": N}[kind]


def _cand_shape(kind: str, batch: Optional[int] = None):
    rows = {"dense": (1, N), "stochastic": (K, M_STOCH), "lazy": (1, 0)}[kind]
    return rows if batch is None else (batch,) + rows


def _precision_fields(policy: str, batch: int = 1):
    if policy != "bf16":
        return None, False
    # allowed widens are the O(n) accumulators: the winner's (B, n) distance
    # column folding into the f32 cache, gains payloads, trajectory scalars.
    # A distance *tile* is (B·n, block) with block ≥ 8 — safely above this.
    return 2 * batch * N + 16, True


# --- single-device selection scans -----------------------------------------


def _device_case(kind, fname, backend, policy, batch=None):
    from repro.core import engine as eng

    spec = SPECS[fname]
    be = _eff_backend(spec, backend)
    nb = batch or 1
    bm = min(BLOCK_M, max(_m_eff(kind), 1))

    def build():
        if batch is None:
            fn = eng._select_scan
            args = (_sds((N, D), np.float32), _sds((N,), np.float32),
                    _sds((N,), np.float32),
                    _sds(_cand_shape(kind), np.int32),
                    _sds((D,), np.float32))
            kwargs = {}
        else:
            fn = eng._select_scan_batched
            args = (_sds((batch, N, D), np.float32),
                    _sds((batch, N), np.float32),
                    _sds((batch, N), np.float32),
                    _sds(_cand_shape(kind, batch), np.int32),
                    _sds((batch, D), np.float32),
                    _sds((batch,), np.int32))
            kwargs = {}
        kwargs.update(fn=spec, kind=kind, k=K, top_b=TOP_B,
                      distance="sqeuclidean", policy_name=policy,
                      block_m=bm, backend=be, rbf_gamma=None,
                      counter_key=f"audit_device_{batch or 1}")
        return fn, args, kwargs

    # lazy's ub0 bound seeding scores all n candidates OUTSIDE the rounds
    # scan; on the jnp backend that is _score_blocked's lax.map — one extra
    # top-level scan. Kernel backends score it in one pallas_call.
    extra_scans = 1 if (kind == "lazy" and be == "jnp") else 0
    widen, half_dot = _precision_fields(policy, nb)
    name = "engine.select_scan" if batch is None \
        else "engine.select_scan_batched"
    return AuditCase(
        contract=name,
        label=f"{'device' if batch is None else f'batched[B={batch}]'}"
              f".{kind}.{fname}.{be}.{policy}",
        build=build,
        expect=Expect(
            rounds=K, top_scans=1 + extra_scans, driving=1,
            whiles=1 if kind == "lazy" else 0,
            collectives=Counter(),          # single device: collective-free
            body_psums=None, max_collective_bytes=None,
            donated=1,                      # the cache seed
            min_widen_elems=widen, require_half_dot=half_dot))


# --- mesh-sharded selection scans ------------------------------------------


def audit_mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


def _sharded_case(kind, fname, backend, policy, pool_plan):
    from repro.core import distributed as dist

    spec = SPECS[fname]
    be = _eff_backend(spec, backend)
    gc = 1 if fname == "graph_cut" else 0
    plan = "device_sharded" if pool_plan == "replicated" \
        else "device_sharded_pool"

    def build():
        mesh = audit_mesh()
        run = dist.make_selection_scan(
            mesh, ("data",), fn=spec, kind=kind, k=K, top_b=TOP_B,
            n_total=N, block_m=BLOCK_M, distance="sqeuclidean",
            policy_name=policy, counter_key=f"audit_{plan}",
            backend=be, rbf_gamma=None, pool_plan=pool_plan)
        args = (_sds((N, D), np.float32), _sds((N, D), np.float32),
                _sds((N,), np.float32), _sds((N,), np.float32),
                _sds(_cand_shape(kind), np.int32), _sds((D,), np.float32))
        return run, args, {}

    # Static psum census, from make_selection_scan's body:
    #   every case: v0 seeding (1) + the final trajectory value (1)
    #   dense/stoch round body: ONE gains+stat psum; graph cut's fold_aux
    #     owner-gather adds one (executed unconditionally), and the final
    #     fold adds it once more
    #   lazy: + the ub0 seeding batch (1); the round body's psum sits in the
    #     while loop (one per re-score iteration at run time, one statically)
    # The sharded pool adds the take collectives: one blocked-take psum
    # inside the streamed scoring map, and the winner-column take per round.
    if pool_plan == "replicated":
        total = (4 if kind == "lazy" else 3) + 2 * gc
        body = 1 + gc
        max_bytes = (_m_scored_max(kind) + 1) * 4
        extra_scans = 1 if (kind == "lazy" and be == "jnp") else 0
    else:
        total = (7 if kind == "lazy" else 5) + 2 * gc
        body = 3 + gc
        bm = min(BLOCK_M, max(8, N))    # run_sharded_selection's pool cap
        max_bytes = max((_m_scored_max(kind) + 1) * 4, bm * D * 4)
        # the lazy seeding pass streams blocked takes through ONE top-level
        # lax.map (jnp sub-blocking nests inside it)
        extra_scans = 1 if kind == "lazy" else 0
    widen, half_dot = _precision_fields(policy)
    return AuditCase(
        contract=f"distributed.selection_scan[{pool_plan}]",
        label=f"{plan}.{kind}.{fname}.{be}.{policy}",
        build=build,
        expect=Expect(
            rounds=K, top_scans=1 + extra_scans, driving=1,
            whiles=1 if kind == "lazy" else 0,
            collectives=Counter({"psum": total}),
            body_psums=body, max_collective_bytes=max_bytes,
            donated=0, min_widen_elems=widen, require_half_dot=half_dot))


def _batched_sharded_case(kind, fname, backend, policy, pool_plan, batch):
    from repro.core import distributed as dist

    spec = SPECS[fname]
    be = _eff_backend(spec, backend)
    gc = 1 if fname == "graph_cut" else 0
    nb = batch
    plan = "device_sharded" if pool_plan == "replicated" \
        else "device_sharded_pool"

    def build():
        mesh = audit_mesh()
        run = dist.make_selection_scan_batched(
            mesh, ("data",), fn=spec, kind=kind, k=K, top_b=TOP_B,
            n_total=N, block_m=BLOCK_M, distance="sqeuclidean",
            policy_name=policy, counter_key=f"audit_b{batch}_{plan}",
            backend=be, rbf_gamma=None, pool_plan=pool_plan)
        args = (_sds((nb, N, D), np.float32), _sds((nb, N, D), np.float32),
                _sds((nb, N), np.float32), _sds((nb, N), np.float32),
                _sds(_cand_shape(kind, nb), np.int32),
                _sds((nb, D), np.float32), _sds((nb,), np.int32))
        return run, args, {}

    # The batched factory's psum census is STRUCTURALLY IDENTICAL to the
    # unbatched one (_sharded_case): every per-request collective batches
    # its OPERAND to (B, …) — the vmapped fold_aux gather, the stacked
    # gains+stat payload, the (B, bm, d) take slabs — so the batch axis
    # multiplies collective BYTES, never collective COUNT. That equality is
    # exactly the tentpole claim ("one psum of O(B·m), not B collectives");
    # a per-tenant psum migration would show up here as count × B.
    if pool_plan == "replicated":
        total = (4 if kind == "lazy" else 3) + 2 * gc
        body = 1 + gc
        max_bytes = nb * (_m_scored_max(kind) + 1) * 4
        extra_scans = 1 if (kind == "lazy" and be == "jnp") else 0
    else:
        total = (7 if kind == "lazy" else 5) + 2 * gc
        body = 3 + gc
        bm = min(BLOCK_M, max(8, N))    # run_sharded_selection_batch's cap
        max_bytes = max(nb * (_m_scored_max(kind) + 1) * 4, nb * bm * D * 4)
        extra_scans = 1 if kind == "lazy" else 0
    widen, half_dot = _precision_fields(policy, nb)
    return AuditCase(
        contract=f"distributed.selection_scan_batched[{pool_plan}]",
        label=f"{plan}.batched[B={batch}].{kind}.{fname}.{be}.{policy}",
        build=build,
        expect=Expect(
            rounds=K, top_scans=1 + extra_scans, driving=1,
            whiles=1 if kind == "lazy" else 0,
            collectives=Counter({"psum": total}),
            body_psums=body, max_collective_bytes=max_bytes,
            donated=1,                  # the (B, n/p) cache seed
            min_widen_elems=widen, require_half_dot=half_dot))


def _greedi_case(fname, backend, policy):
    from repro.core import distributed as dist

    spec = SPECS[fname]
    be = _eff_backend(spec, backend)
    gc = 1 if fname == "graph_cut" else 0

    def build():
        mesh = audit_mesh()
        run = dist.make_greedi_scan(
            mesh, ("data",), fn=spec, k=K, n_total=N, block_m=BLOCK_M,
            distance="sqeuclidean", policy_name=policy,
            counter_key="audit_greedi", backend=be, rbf_gamma=None)
        args = (_sds((N, D), np.float32), _sds((N,), np.float32),
                _sds((N,), np.float32), _sds((D,), np.float32))
        return run, args, {}

    p = jax.device_count()
    # Two driving (length-k) scans — the phase-1 partition greedy and the
    # phase-2 merge greedy — plus the p-solution global-evaluation map.
    # Psums: the 3 all-gathers (solution rows, indices, n_scored) + v0g +
    # the eval-map body's trajectory value + the merge body's gains+stat +
    # the final trajectory value = 7; graph cut's fold_aux gather fires in
    # the eval body, the merge body, and the final fold (+3).
    widen, half_dot = _precision_fields(policy)
    return AuditCase(
        contract="distributed.greedi_scan",
        label=f"greedi.dense.{fname}.{be}.{policy}",
        build=build,
        expect=Expect(
            rounds=K, top_scans=3, driving=2, whiles=0,
            collectives=Counter({"psum": 7 + 3 * gc}),
            body_psums=None,        # phase-1 partition greedy: local-only
            max_collective_bytes=max(p * K * D * 4, (p * K + 1) * 4),
            donated=0, min_widen_elems=widen, require_half_dot=half_dot))


# --- streaming sieve scans -------------------------------------------------


def _sieve_state_structs(spec, n):
    from repro.core import streaming as st

    S, k = spec.s_max, spec.k
    return st.SieveState(
        caches=_sds((S, n), np.float32), slot_exp=_sds((S,), np.int32),
        active=_sds((S,), np.bool_), sizes=_sds((S,), np.int32),
        members=_sds((S, k), np.int32), m_seen=_sds((), np.float32),
        lb=_sds((), np.float32), evals=_sds((), np.int32))


def _sieve_psum_body(variant: str, use_kernel: bool) -> int:
    # _element_step's ground-set reductions, per element (statically once in
    # the scan body): jnp path = singleton gain + per-sieve gains (+ the
    # values_of reduce feeding the sieve/pp accept threshold; salsa's
    # rate-schedule threshold needs no values) (+ pp's post-accept LB
    # update). Kernel path scores seed+table rows in ONE fused psum'd pass.
    if use_kernel:
        return {"sieve": 2, "pp": 3, "salsa": 1}[variant]
    return {"sieve": 3, "pp": 4, "salsa": 2}[variant]


def _stream_case(variant, fname, backend, sharded):
    from repro.core import streaming as st

    fspec = SPECS[fname]
    spec = st.make_spec(SIEVE_K, SIEVE_EPS, variant, backend=backend,
                        fn=fspec)
    use_kernel = spec.backend != "jnp"   # make_spec normalizes no-template

    def build():
        state = _sieve_state_structs(spec, N)
        if not sharded:
            args = (state, _sds((N,), np.float32), _sds((N,), np.float32),
                    _sds((B_BLOCK,), np.int32),
                    _sds((B_BLOCK, N), np.float32),
                    _sds((B_BLOCK,), np.bool_))
            return st._offer_block_scan, args, dict(
                spec=spec, counter_key="audit_sieve")
        mesh = audit_mesh()
        run = st.make_sharded_offer_scan(
            mesh, ("data",), spec=spec, n_total=N, distance="sqeuclidean",
            policy_name="fp32", counter_key="audit_sieve_sharded")
        args = (state, _sds((N, D), np.float32), _sds((N,), np.float32),
                _sds((N,), np.float32), _sds((B_BLOCK, D), np.float32),
                _sds((B_BLOCK,), np.int32), _sds((B_BLOCK,), np.bool_))
        return run, args, {}

    if sharded:
        body = _sieve_psum_body(variant, use_kernel)
        collectives = Counter({"psum": body + 1})   # + the v0 seeding psum
        max_bytes = (spec.s_max + 1) * 4            # seed row + table rows
    else:
        body, collectives, max_bytes = None, Counter(), None
    plan = "sharded" if sharded else "device"
    return AuditCase(
        contract="streaming.offer_scan" + ("[sharded]" if sharded else ""),
        label=f"sieve_{variant}.{plan}.{fname}.{spec.backend}",
        build=build,
        expect=Expect(
            rounds=B_BLOCK, top_scans=1, driving=1, whiles=0,
            collectives=collectives, body_psums=body,
            max_collective_bytes=max_bytes,
            donated=_sieve_donated(),   # every SieveState carry leaf
            min_widen_elems=None))


def _sieve_donated() -> int:
    """The donated streaming carry is the whole SieveState — one aliased
    input per leaf, so the expected arity tracks the NamedTuple."""
    from repro.core import streaming as st

    return len(st.SieveState._fields)


SIEVE_P = 3     #: stream partitions in the batched audit case


def _stream_batched_case(variant, fname, backend):
    from repro.core import streaming as st

    fspec = SPECS[fname]
    spec = st.make_spec(SIEVE_K, SIEVE_EPS, variant, backend=backend,
                        fn=fspec)

    def build():
        base = _sieve_state_structs(spec, N)
        states = type(base)(*[
            _sds((SIEVE_P,) + leaf.shape, leaf.dtype) for leaf in base])
        args = (states, _sds((N,), np.float32), _sds((N,), np.float32),
                _sds((B_BLOCK, SIEVE_P), np.int32),
                _sds((B_BLOCK, SIEVE_P, N), np.float32),
                _sds((B_BLOCK, SIEVE_P), np.bool_))
        return st._offer_block_scan_batched, args, dict(
            spec=spec, counter_key="audit_sieve_batched")

    return AuditCase(
        contract="streaming.offer_scan_batched",
        label=f"sieve_{variant}.batched[P={SIEVE_P}].{fname}.{spec.backend}",
        build=build,
        expect=Expect(
            rounds=B_BLOCK, top_scans=1, driving=1, whiles=0,
            collectives=Counter(), body_psums=None,
            max_collective_bytes=None,
            donated=_sieve_donated(),
            min_widen_elems=None))


# --- memory-bounded compile cases ------------------------------------------

#: Shapes for the analytic-byte check: big enough that the full (n, m)
#: distance matrix (4 MiB) is an order of magnitude above the blocked
#: working set, so the bound genuinely discriminates.
MEM_N, MEM_D, MEM_BM = 1024, 8, 64


def _memory_case(batch=None):
    from repro.core import engine as eng

    nb = batch or 1

    def build():
        if batch is None:
            fn = eng._select_scan
            args = (_sds((MEM_N, MEM_D), np.float32),
                    _sds((MEM_N,), np.float32), _sds((MEM_N,), np.float32),
                    _sds((1, MEM_N), np.int32), _sds((MEM_D,), np.float32))
        else:
            fn = eng._select_scan_batched
            args = (_sds((batch, MEM_N, MEM_D), np.float32),
                    _sds((batch, MEM_N), np.float32),
                    _sds((batch, MEM_N), np.float32),
                    _sds((batch, 1, MEM_N), np.int32),
                    _sds((batch, MEM_D), np.float32),
                    _sds((batch,), np.int32))
        kwargs = dict(fn=FnSpec(), kind="dense", k=K, top_b=0,
                      distance="sqeuclidean", policy_name="fp32",
                      block_m=MEM_BM, backend="jnp", rbf_gamma=None,
                      counter_key=f"audit_mem_{nb}")
        return fn, args, kwargs

    # Working set: the streamed (B·n, block_m) distance tile plus O(B·n)
    # carries — NEVER the full (B·n, m) matrix. Bound: 6 tiles of headroom
    # (scan double-buffering, gather scratch) + 1 MiB slack; a full-matrix
    # regression costs B·n·m·4 = 4B MiB and trips it immediately.
    tile = nb * MEM_N * MEM_BM * 4
    return AuditCase(
        contract="engine.select_scan" if batch is None
        else "engine.select_scan_batched",
        label=f"memory.{'device' if batch is None else f'batched[B={batch}]'}"
              f".dense.exemplar.jnp.fp32",
        build=build,
        expect=Expect(
            rounds=K, top_scans=1, driving=1, whiles=0,
            collectives=Counter(), body_psums=None,
            max_collective_bytes=None, donated=1, min_widen_elems=None,
            memory_bound=6 * tile + (1 << 20)))


# --- the full grid ---------------------------------------------------------


def build_cases(quick: bool = False) -> list[AuditCase]:
    """The audit grid. ``quick`` keeps one exemplar case per contract (for
    smoke runs); the full grid is what CI proves green.
    """
    cases: list[AuditCase] = []
    fnames = list(SPECS)
    for kind in KINDS:
        for fname in fnames:
            for backend in BACKENDS:
                for policy in POLICIES:
                    if _eff_backend(SPECS[fname], backend) != backend:
                        continue    # satcov normalizes to jnp: skip the dup
                    cases.append(_device_case(kind, fname, backend, policy))
                    for batch in (1, 64):
                        cases.append(_device_case(kind, fname, backend,
                                                  policy, batch=batch))
                    for pool_plan in ("replicated", "sharded"):
                        cases.append(_sharded_case(kind, fname, backend,
                                                   policy, pool_plan))
                        for batch in (1, 4):
                            cases.append(_batched_sharded_case(
                                kind, fname, backend, policy, pool_plan,
                                batch))
    for fname in fnames:
        for backend in BACKENDS:
            for policy in POLICIES:
                if _eff_backend(SPECS[fname], backend) != backend:
                    continue
                cases.append(_greedi_case(fname, backend, policy))
    for variant in ("sieve", "pp", "salsa"):
        for fname in sorted(fx.SIEVE_ELIGIBLE):
            for backend in BACKENDS:
                fspec = SPECS[fname]
                if backend != "jnp" and fx.kernel_template(fspec) is None:
                    continue
                for sharded in (False, True):
                    cases.append(_stream_case(variant, fname, backend,
                                              sharded))
                cases.append(_stream_batched_case(variant, fname, backend))
    cases.append(_memory_case())
    cases.append(_memory_case(batch=4))
    if quick:
        seen: dict[str, AuditCase] = {}
        for c in cases:
            seen.setdefault(c.contract, c)
        return list(seen.values())
    return cases


# --- runtime checks: retrace stability + live donation ---------------------


@dataclasses.dataclass
class RuntimeCheck:
    name: str
    run: Callable[[], tuple[bool, str]]


def _rt_retrace_device() -> tuple[bool, str]:
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering

    key = "audit_rt_device"
    rng = np.random.default_rng(0)
    V = rng.standard_normal((32, 4)).astype(np.float32)
    for _ in range(2):   # fresh function instance = fresh same-shape arrays
        f = ExemplarClustering(jnp.asarray(V), EvalConfig())
        eng.run_selection(f, kind="dense", k=3,
                          cand_rounds=np.arange(32, dtype=np.int32)[None, :],
                          plan="device", counter_key=key)
    n = eng.DEVICE_TRACE_COUNTS[key]
    return n == 1, f"traces for two same-signature calls: {n} (want 1)"


def _rt_retrace_batched() -> tuple[bool, str]:
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering

    key = "audit_rt_batched"
    rng = np.random.default_rng(1)
    V = rng.standard_normal((4, 32, 4)).astype(np.float32)
    for _ in range(2):
        fs = [ExemplarClustering(jnp.asarray(v), EvalConfig()) for v in V]
        eng.run_selection_batch(fs, kind="dense", k=3, counter_key=key)
    n = eng.DEVICE_TRACE_COUNTS[key]
    return n == 1, f"traces for two same-signature batches: {n} (want 1)"


def _rt_retrace_sharded() -> tuple[bool, str]:
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering

    key = "audit_rt_sharded"
    rng = np.random.default_rng(2)
    V = rng.standard_normal((32, 4)).astype(np.float32)
    for _ in range(2):
        f = ExemplarClustering(jnp.asarray(V), EvalConfig())
        eng.run_selection(f, kind="dense", k=3,
                          cand_rounds=np.arange(32, dtype=np.int32)[None, :],
                          plan="device_sharded", counter_key=key)
    n = eng.DEVICE_TRACE_COUNTS[key]
    return n == 1, f"traces for two same-signature calls: {n} (want 1)"


def _rt_retrace_sieve() -> tuple[bool, str]:
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering
    from repro.core.streaming import make_sieve_engine

    rng = np.random.default_rng(3)
    V = rng.standard_normal((32, 4)).astype(np.float32)
    f = ExemplarClustering(jnp.asarray(V), EvalConfig())
    engine = make_sieve_engine(f, 3, 0.2, variant="sieve", mode="device",
                               block_size=8)
    before = eng.DEVICE_TRACE_COUNTS["sieve_sieve"]
    engine.offer(np.arange(8), rng.standard_normal((8, 4)))
    engine.offer(np.arange(8, 16), rng.standard_normal((8, 4)))
    n = eng.DEVICE_TRACE_COUNTS["sieve_sieve"] - before
    return n == 1, f"traces for two same-shape stream blocks: {n} (want 1)"


def _rt_donation_live() -> tuple[bool, str]:
    """The executable's aliasing table must match live behavior: the donated
    seed buffer is consumed by the dispatch (``is_deleted``), and the engine
    wrapper passes a *copy* so the function's resident seed survives."""
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering

    rng = np.random.default_rng(4)
    V = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
    f = ExemplarClustering(V, EvalConfig())
    seed = jnp.array(f.cache_seed)
    out = eng._select_scan(
        f.V, seed, f.row_aux, jnp.arange(32, dtype=jnp.int32)[None, :],
        jnp.zeros((4,), jnp.float32), fn=f.spec, kind="dense", k=3,
        top_b=0, distance="sqeuclidean", policy_name="fp32", block_m=32,
        backend="jnp", rbf_gamma=None, counter_key="audit_rt_donate")
    jax.block_until_ready(out)
    if not seed.is_deleted():
        return False, "donated seed buffer survived the dispatch"
    if f.cache_seed.is_deleted():
        return False, "the function's resident cache seed was consumed"
    return True, "seed donated and consumed; resident seed intact"


def _rt_donation_sieve() -> tuple[bool, str]:
    """The streaming carry's aliasing table must match live behavior: after
    a block dispatch the engine's PRE-call state buffers are consumed
    (``is_deleted``) and the rebound state is alive — the table aliased in
    place instead of copying."""
    import jax.numpy as jnp
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering
    from repro.core.streaming import make_sieve_engine

    rng = np.random.default_rng(6)
    V = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
    f = ExemplarClustering(V, EvalConfig())
    engine = make_sieve_engine(f, 3, 0.2, variant="sieve", mode="device",
                               block_size=8)
    old = engine.state
    engine.offer(np.arange(8), rng.standard_normal((8, 4)))
    jax.block_until_ready(engine.state)
    if not old.caches.is_deleted():
        return False, "pre-call cache table survived the dispatch (copied)"
    if engine.state.caches.is_deleted():
        return False, "the rebound cache table was consumed"
    return True, "sieve carry donated and consumed; rebound table alive"


def _rt_overlap_sieve() -> tuple[bool, str]:
    """The overlapped offer pipeline must be free lunch: zero extra traces
    versus the serialized baseline AND identical members/value/evals."""
    import jax.numpy as jnp
    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.functions import ExemplarClustering
    from repro.core.streaming import make_sieve_engine

    rng = np.random.default_rng(7)
    V = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
    stream = rng.standard_normal((40, 4)).astype(np.float32)
    results = []
    before = eng.DEVICE_TRACE_COUNTS["sieve_sieve"]
    for overlap in (False, True):
        f = ExemplarClustering(jnp.asarray(V), EvalConfig())
        engine = make_sieve_engine(f, 3, 0.2, variant="sieve",
                                   mode="device", block_size=8,
                                   overlap=overlap, max_in_flight=2)
        acc = engine.offer(np.arange(len(stream)), stream)
        results.append((engine.best(), engine.evaluations(), acc.tolist()))
    traces = eng.DEVICE_TRACE_COUNTS["sieve_sieve"] - before
    if traces > 1:
        return False, f"overlap pipeline retraced: {traces} traces (want ≤1)"
    if results[0] != results[1]:
        return False, "overlap-on diverged from the serialized baseline"
    return True, "overlap-on == overlap-off (members/value/evals), ≤1 trace"


def _rt_service_bucket() -> tuple[bool, str]:
    """One service round trip: concurrent same-signature tenants must ride
    ONE batched dispatch (and a second burst must not retrace)."""
    import asyncio

    from repro.core import engine as eng
    from repro.core.evaluator import EvalConfig
    from repro.core.service import SelectionService

    rng = np.random.default_rng(5)

    async def serve():
        # linger lets each 3-request burst coalesce into ONE pow2 bucket
        async with SelectionService(EvalConfig(), max_batch=8,
                                    linger_s=0.05) as svc:
            for _ in range(2):
                await asyncio.gather(*[
                    svc.submit(rng.standard_normal((32, 4)), k=3)
                    for _ in range(3)])
            return svc.stats

    before = eng.DEVICE_TRACE_COUNTS["serve_dense"]
    stats = asyncio.run(serve())
    traces = eng.DEVICE_TRACE_COUNTS["serve_dense"] - before
    if traces != 1:
        return False, f"two same-signature bursts traced {traces}x (want 1)"
    if stats["dispatches"] != 2:
        return False, f"6 requests cost {stats['dispatches']} dispatches"
    return True, (f"{stats['batched_requests']} requests in "
                  f"{stats['dispatches']} dispatches, 1 trace")


def runtime_checks() -> list[RuntimeCheck]:
    return [
        RuntimeCheck("retrace.device", _rt_retrace_device),
        RuntimeCheck("retrace.batched", _rt_retrace_batched),
        RuntimeCheck("retrace.sharded", _rt_retrace_sharded),
        RuntimeCheck("retrace.sieve", _rt_retrace_sieve),
        RuntimeCheck("donation.live", _rt_donation_live),
        RuntimeCheck("donation.sieve", _rt_donation_sieve),
        RuntimeCheck("overlap.sieve", _rt_overlap_sieve),
        RuntimeCheck("service.bucket", _rt_service_bucket),
    ]
