"""Case evaluation + machine-readable audit report.

:func:`evaluate_case` traces one :class:`~repro.analysis.registry.AuditCase`
and compares the artifact against its :class:`~repro.analysis.registry.\
Expect` — every mismatch becomes a :class:`Violation` string pair. The
report collects per-case results, runtime-check outcomes, and lint
findings into one JSON-serializable dict (the CLI's ``--json`` payload and
the CI job's artifact).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Optional

from repro.analysis import jaxpr_audit as ja
from repro.analysis.registry import AuditCase


@dataclasses.dataclass
class Violation:
    check: str       #: which claim failed (scans/collectives/donation/...)
    detail: str

    def __str__(self) -> str:
        return f"[{self.check}] {self.detail}"


@dataclasses.dataclass
class CaseResult:
    label: str
    contract: str
    violations: list[Violation]
    metrics: dict[str, Any]

    @property
    def ok(self) -> bool:
        return not self.violations


def evaluate_case(case: AuditCase) -> CaseResult:
    e = case.expect
    fn, args, kwargs = case.build()
    art = ja.trace_artifact(fn, args, kwargs)
    v: list[Violation] = []

    ss = ja.scan_structure(art.jaxpr, e.rounds)
    if ss.top_scans != e.top_scans:
        v.append(Violation(
            "scans", f"top-level scans: {ss.top_scans} (want {e.top_scans}) "
            f"— the one-dispatch loop structure changed"))
    if ss.driving != e.driving:
        v.append(Violation(
            "scans", f"driving (length-{e.rounds}) scans: {ss.driving} "
            f"(want {e.driving})"))
    if ss.whiles != e.whiles:
        v.append(Violation(
            "scans", f"while loops: {ss.whiles} (want {e.whiles})"))

    census = ja.collective_census(art.jaxpr)
    if census.counts != e.collectives:
        v.append(Violation(
            "collectives", f"static collective census "
            f"{dict(census.counts)} != declared {dict(e.collectives)}"))
    if e.max_collective_bytes is not None and \
            census.max_operand_bytes > e.max_collective_bytes:
        v.append(Violation(
            "collectives", f"largest collective operand "
            f"{census.max_operand_bytes} B exceeds the O(m) bound "
            f"{e.max_collective_bytes} B — an O(n·d) or O(n·m) payload is "
            f"riding a collective"))
    body_psums: Optional[int] = None
    if e.body_psums is not None:
        if ss.driving_body is None:
            v.append(Violation(
                "collectives", "no driving scan body to census"))
        else:
            body = ja.collective_census(ss.driving_body)
            body_psums = body.total
            if body.total != e.body_psums:
                v.append(Violation(
                    "collectives", f"driving-scan body carries {body.total} "
                    f"collectives (want {e.body_psums} per round)"))

    don = ja.donation_audit(art.hlo)
    if don.dropped:
        # multi-device lowering defers aliasing to XLA (jax.buffer_donor in
        # the StableHLO, decided after SPMD partitioning) — consult the
        # compiled alias table before calling the donation dropped
        don = ja.resolve_deferred_donations(don, art.lowered)
    if don.aliased != e.donated:
        v.append(Violation(
            "donation", f"{don.aliased} input(s) aliased onto outputs "
            f"(want {e.donated})"))
    dropped = don.dropped + art.dropped_donations
    if dropped:
        v.append(Violation(
            "donation", f"{dropped} donated input(s) silently dropped "
            f"(jax.buffer_donor without tf.aliasing_output, or stripped "
            f"at lowering with only a warning)"))

    prec = None
    if e.min_widen_elems is not None:
        prec = ja.precision_flow(art.jaxpr,
                                 min_widen_elems=e.min_widen_elems)
        for shape, elems in prec.widens:
            v.append(Violation(
                "precision", f"half→fp32 convert_element_type on {shape} "
                f"({elems} elems ≥ tile threshold {e.min_widen_elems}) — "
                f"the payload widened outside the declared accumulators"))
        if e.require_half_dot and prec.half_dots == 0:
            v.append(Violation(
                "precision", "no dot_general consumes half-dtype operands "
                f"— the half-precision policy never reached the matmul"))

    temp_bytes = None
    if e.memory_bound is not None:
        temp_bytes = ja.memory_temp_bytes(art.lowered)
        if temp_bytes is not None and temp_bytes > e.memory_bound:
            v.append(Violation(
                "memory", f"compiled temp buffers {temp_bytes} B exceed the "
                f"analytic working-set bound {e.memory_bound} B — the "
                f"artifact materializes more than the blocked tile"))

    return CaseResult(
        label=case.label, contract=case.contract, violations=v,
        metrics={
            "top_scans": ss.top_scans, "driving_scans": ss.driving,
            "whiles": ss.whiles, "collectives": dict(census.counts),
            "collective_total": census.total,
            "max_collective_bytes": census.max_operand_bytes,
            "body_psums": body_psums,
            "donated_aliased": don.aliased, "donated_dropped": don.dropped,
            "half_dots": prec.half_dots if prec else None,
            "temp_bytes": temp_bytes,
        })


def donated_bytes(case: AuditCase) -> int:
    """Bytes of the case's donated inputs (metrics row material)."""
    import jax

    _, args, _ = case.build()
    if not case.expect.donated:
        return 0
    # selection contracts donate the cache seed (args[1]); streaming
    # contracts donate the whole SieveState carry (args[0]) — a pytree, so
    # sum its leaves
    pos = 0 if case.contract.startswith("streaming.") else 1
    return sum(int(a.size) * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(args[pos]))


def build_report(case_results, runtime_results, lint_findings,
                 *, device_count: int) -> dict:
    """One JSON-serializable dict for --json / CI artifacts."""
    failed = [c for c in case_results if not c.ok]
    rt_failed = [r for r in runtime_results if not r["ok"]]
    contracts = sorted({c.contract for c in case_results})
    return {
        "device_count": device_count,
        "cases": [
            {"label": c.label, "contract": c.contract, "ok": c.ok,
             "violations": [str(x) for x in c.violations],
             "metrics": c.metrics}
            for c in case_results],
        "runtime": runtime_results,
        "lint": [dataclasses.asdict(f) for f in lint_findings],
        "summary": {
            "contracts": len(contracts),
            "cases": len(case_results),
            "cases_failed": len(failed),
            "runtime_checks": len(runtime_results),
            "runtime_failed": len(rt_failed),
            "lint_findings": len(lint_findings),
            "ok": not failed and not rt_failed and not lint_findings,
        },
    }


def contract_metrics(case_results) -> dict[str, dict]:
    """Per-contract aggregates for the benchmark emitter."""
    per: dict[str, dict] = {}
    for c in case_results:
        m = per.setdefault(c.contract, Counter(
            traced_signatures=0, collectives=0, max_collective_bytes=0,
            failed=0))
        m["traced_signatures"] += 1
        m["collectives"] = max(m["collectives"], c.metrics["collective_total"])
        m["max_collective_bytes"] = max(
            m["max_collective_bytes"], c.metrics["max_collective_bytes"])
        m["failed"] += 0 if c.ok else 1
    return {k: dict(v) for k, v in per.items()}
