"""Assigned-architecture registry: ``--arch <id>`` resolution.

One module per architecture (exact public-literature configs) plus reduced
variants for CPU smoke tests. See DESIGN.md §4 for adaptation notes.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "whisper-small": "repro.configs.whisper_small",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    # the paper's own workload is not an LM; see repro.configs.paper
    "paper-exemplar": "repro.configs.paper",
}

ARCH_IDS = [k for k in _MODULES if k != "paper-exemplar"]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    """Small same-family variant for one-CPU smoke tests."""
    mod = importlib.import_module(_MODULES[arch])
    return mod.REDUCED


def replace(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)
