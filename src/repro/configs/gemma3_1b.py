"""gemma3-1b — 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt].

26L, d_model=1152, 4H GQA kv=1, d_ff=6912, vocab=262144, head_dim=256,
sliding_window=512, every 6th layer global (rope theta 1M), qk_norm, tied
embeddings. Long-context decode runs (5/6 of layers are O(window); global
layers decode O(L) per token) — long_500k included per DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    sliding_window=512, local_global_period=6,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    qk_norm=True, tie_embeddings=True,
    subquadratic=True, max_seq_len=524_288, act="gelu",
)

REDUCED = ModelConfig(
    name="gemma3-1b-reduced", family="dense",
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=1,
    d_ff=128, vocab_size=256, head_dim=32,
    sliding_window=16, local_global_period=2,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    qk_norm=True, tie_embeddings=True,
    subquadratic=True, max_seq_len=512, act="gelu", dtype="float32",
)
