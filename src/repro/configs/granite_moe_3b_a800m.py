"""granite-moe-3b-a800m — 40 experts top-8 [hf:ibm-granite; hf].

32L, d_model=1536, 24H GQA kv=8, per-expert d_ff=512, vocab=49155.
40 experts padded to 48 for 16-way EP divisibility (17% expert padding,
zero-routed; DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    num_experts=40, experts_per_tok=8, expert_pad_to=48,
    max_seq_len=131_072,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=32, vocab_size=256, head_dim=16,
    num_experts=5, experts_per_tok=2, expert_pad_to=6, moe_capacity=8.0,
    max_seq_len=512, dtype="float32",
)
