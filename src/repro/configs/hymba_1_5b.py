"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L, d_model=1600, 25H GQA kv=5, d_ff=5504, ssm_state=16, vocab=32001,
head_dim=64. Full attention at layers {0, 15, 31}, sliding window 1024
elsewhere (per the paper); meta-tokens stubbed off (DESIGN.md §4).
Sub-quadratic in the long regime → long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_conv=4,
    sliding_window=1024, full_attn_layers=(0, 15, 31),
    subquadratic=True, max_seq_len=524_288,
)

REDUCED = ModelConfig(
    name="hymba-1.5b-reduced", family="hybrid",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    ssm_state=8, ssm_expand=2, ssm_conv=4,
    sliding_window=16, full_attn_layers=(0, 2),
    subquadratic=True, max_seq_len=512, dtype="float32",
)
