"""The paper's own workload: exemplar clustering evaluation problem sizes.

Paper §V-A: N=50000, l=5000, k=10, dim=100; N ∈ [1000, 400000],
l ∈ [1000, 40000], k ∈ [10, 500].
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperProblem:
    n: int = 50_000
    l: int = 5_000
    k: int = 10
    dim: int = 100


CONFIG = PaperProblem()
SWEEPS = {
    "N": [int(x) for x in range(1000, 400001, 28500)],   # 15 values
    "l": [int(x) for x in range(1000, 40001, 2785)],
    "k": [int(x) for x in range(10, 501, 35)],
}
