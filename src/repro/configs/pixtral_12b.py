"""pixtral-12b — pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral].

40L, d_model=5120, 32H GQA kv=8, d_ff=14336, vocab=131072, head_dim=128.
ViT frontend is a STUB: input_specs() provides precomputed patch embeddings
(B, 1024, 5120) consumed as a prefix. Full attention → long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    frontend="vision_stub", frontend_len=1024,
    rope_theta=1_000_000.0, max_seq_len=131_072,
)

REDUCED = ModelConfig(
    name="pixtral-12b-reduced", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    frontend="vision_stub", frontend_len=8,
    max_seq_len=512, dtype="float32",
)
