"""qwen3-0.6b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

28L, d_model=1024, 16H GQA kv=8, d_ff=3072, vocab=151936, head_dim=128
(explicit), tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128,
    qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
    max_seq_len=131_072,
)

REDUCED = ModelConfig(
    name="qwen3-0.6b-reduced", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    qk_norm=True, tie_embeddings=True,
    max_seq_len=512, dtype="float32",
)
