"""qwen3-32b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

64L, d_model=5120, 64H GQA kv=8, d_ff=25600, vocab=151936, head_dim=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0, max_seq_len=131_072,
)

REDUCED = ModelConfig(
    name="qwen3-32b-reduced", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=16,
    qk_norm=True, max_seq_len=512, dtype="float32",
)
