"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L, d_model=2048, 32H GQA kv=4, per-expert d_ff=768, vocab=151936,
head_dim=128 (explicit per HF config), qk_norm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    num_experts=128, experts_per_tok=8,
    qk_norm=True, rope_theta=1_000_000.0, max_seq_len=131_072,
)

REDUCED = ModelConfig(
    name="qwen3-moe-30b-a3b-reduced", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=32, vocab_size=256, head_dim=16,
    num_experts=8, experts_per_tok=2, moe_capacity=8.0,
    qk_norm=True, max_seq_len=512, dtype="float32",
)
