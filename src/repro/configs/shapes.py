"""Assigned input shapes and abstract input specs for the dry-run.

LM transformer shapes are seq_len × global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a seq_len KV cache), not
``train_step``. ``long_500k`` runs only for sub-quadratic architectures
(cfg.subquadratic) per the task rule — skips are recorded, not silent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import cache_specs


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch × shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k skipped (task rule)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """Abstract (ShapeDtypeStruct) inputs for the step function of the cell.

    train  → {tokens, labels[, frontend]}
    prefill→ {tokens[, frontend]}
    decode → {tokens(B,1), caches, pos} with a seq_len-long cache
    """
    B = shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {}
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.frontend_len
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), dtype)
        if cfg.family == "encdec":
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), dtype)
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return specs
    if shape.kind == "prefill":
        specs = {}
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.frontend_len
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), dtype)
        if cfg.family == "encdec":
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), dtype)
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return specs
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "caches": cache_specs(cfg, B, S),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(shape.kind)
