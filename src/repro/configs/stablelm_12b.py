"""stablelm-12b [hf:stabilityai/stablelm-2-1_6b; hf].

40L, d_model=5120, 32H GQA kv=8, d_ff=13824, vocab=100352, head_dim=160
(d_model/heads; not 128-aligned — MXU pad waste noted in the roofline).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=13824, vocab_size=100352, head_dim=160,
    max_seq_len=131_072,
)

REDUCED = ModelConfig(
    name="stablelm-12b-reduced", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=20,
    max_seq_len=512, dtype="float32",
)
