"""whisper-small — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

12 encoder + 12 decoder layers, d_model=768, 12 heads (MHA), d_ff=3072,
vocab=51865. input_specs() feeds precomputed (B, 1500, 768) frame embeddings
(the conv frontend is a stub per the task spec). Full-attention decoder →
long_500k skipped (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, encoder_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    frontend="audio_stub", frontend_len=1500,
    act="gelu", max_seq_len=32_768,
)

REDUCED = ModelConfig(
    name="whisper-small-reduced", family="encdec",
    num_layers=2, encoder_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    frontend="audio_stub", frontend_len=16,
    act="gelu", max_seq_len=512, dtype="float32",
)
