"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L, d_model=2048, 4 heads, no separate FFN (d_ff=0: mLSTM carries a 2x
up-projection, sLSTM a 4/3 GeGLU — see DESIGN.md §4). 7:1 mLSTM:sLSTM
(sLSTM every 8th layer). Sub-quadratic: long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    head_dim=512,
    slstm_period=8, ssm_expand=2, ssm_conv=4,
    subquadratic=True, max_seq_len=524_288,
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced", family="ssm",
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=256, head_dim=32,
    slstm_period=2, ssm_expand=2, ssm_conv=4,
    subquadratic=True, max_seq_len=512, dtype="float32",
)
