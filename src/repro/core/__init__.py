"""Core submodular exemplar-clustering library (the paper's contribution)."""
from repro.core.evaluator import (
    ChunkingError,
    EvalConfig,
    bytes_per_set,
    evaluate_multiset,
    plan_chunks,
    work_matrix,
)
from repro.core.engine import (
    DEVICE_TRACE_COUNTS,
    run_selection,
    run_selection_batch,
    validate_candidates,
)
from repro.core.functions import (
    FUNCTIONS,
    ExemplarClustering,
    FacilityLocation,
    FeatureBased,
    FnSpec,
    GraphCut,
    SaturatedCoverage,
    SubmodularFunction,
)
from repro.core.multiset import PackedMultiset, pack_base_plus_candidates, pack_sets
from repro.core.optimizers import (
    OPTIMIZERS,
    OptResult,
    greedy,
    lazy_greedy,
    salsa,
    sieve_streaming,
    sieve_streaming_pp,
    stochastic_greedy,
    three_sieves,
)
from repro.core.streaming import (
    BatchedSieveEngine,
    DeviceSieveEngine,
    HostSieveMirror,
    SieveSpec,
    SieveState,
    make_batched_sieve_engine,
    make_sieve_engine,
)
from repro.core.service import (
    MultiStreamIngestionService,
    MultiStreamSnapshot,
    SelectionService,
    SieveSnapshot,
    StreamIngestionService,
)
from repro.core.clustering import ExemplarModel, fit_exemplar_clustering
from repro.core.precision import BF16, FP16, FP16_STRICT, FP32, PrecisionPolicy

__all__ = [
    "BF16", "FP16", "FP16_STRICT", "FP32", "PrecisionPolicy",
    "ChunkingError", "DEVICE_TRACE_COUNTS", "EvalConfig", "bytes_per_set",
    "evaluate_multiset", "run_selection", "run_selection_batch",
    "validate_candidates",
    "plan_chunks", "work_matrix", "ExemplarClustering", "FacilityLocation",
    "FeatureBased", "FnSpec", "FUNCTIONS", "GraphCut", "SaturatedCoverage",
    "SubmodularFunction", "PackedMultiset",
    "pack_base_plus_candidates", "pack_sets", "OPTIMIZERS", "OptResult",
    "greedy", "lazy_greedy", "salsa", "sieve_streaming", "sieve_streaming_pp",
    "stochastic_greedy", "three_sieves", "ExemplarModel",
    "fit_exemplar_clustering", "BatchedSieveEngine", "DeviceSieveEngine",
    "HostSieveMirror", "SieveSpec", "SieveState",
    "make_batched_sieve_engine", "make_sieve_engine",
    "MultiStreamIngestionService", "MultiStreamSnapshot", "SelectionService",
    "SieveSnapshot", "StreamIngestionService",
]
