"""Clustering front-end: exemplar selection → cluster assignment.

The paper frames exemplar clustering as "select S, then partition the data
space by nearest exemplar". This module is the user-facing API.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_mod
from repro.core.evaluator import EvalConfig
from repro.core.functions import ExemplarClustering
from repro.core.optimizers import OPTIMIZERS, OptResult
from repro.core.precision import resolve as resolve_policy


@dataclasses.dataclass
class ExemplarModel:
    """Fitted exemplar clustering model."""

    exemplar_indices: list[int]
    exemplars: np.ndarray
    value: float
    result: OptResult
    cfg: EvalConfig

    def assign(self, X: jax.Array) -> np.ndarray:
        """Nearest-exemplar label for each row of X."""
        pair = dist_mod.resolve_pairwise(self.cfg.distance)
        D = pair(jnp.asarray(X), jnp.asarray(self.exemplars),
                 resolve_policy(self.cfg.policy))
        return np.asarray(jnp.argmin(D, axis=1))


def fit_exemplar_clustering(
    X: jax.Array,
    k: int,
    optimizer: str = "greedy",
    cfg: EvalConfig = EvalConfig(),
    e0: Optional[jax.Array] = None,
    **opt_kwargs,
) -> ExemplarModel:
    """Select k exemplars from X by submodular maximization and return a model."""
    f = ExemplarClustering(jnp.asarray(X), cfg, e0=e0)
    try:
        opt = OPTIMIZERS[optimizer]
    except KeyError as e:
        raise ValueError(f"unknown optimizer {optimizer!r}; "
                         f"options {sorted(OPTIMIZERS)}") from e
    res = opt(f, k, **opt_kwargs)
    ex = np.asarray(jax.device_get(f.V))[res.indices]
    return ExemplarModel(res.indices, ex, res.value, res, cfg)
