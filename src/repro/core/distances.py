"""Dissimilarity functions for exemplar-based clustering.

Exemplar clustering only requires non-negativity of ``d`` (paper §IV), not the
triangle inequality. All functions here are exposed in two forms:

* ``pairwise(X, Y) -> (n, m)`` — the full cross matrix, used by the work-matrix
  evaluator. For inner-product-expressible distances (squared Euclidean,
  cosine, RBF) this routes the heavy term through a single matmul so the TPU
  MXU does the work (see DESIGN.md §2).
* ``point(x, y) -> scalar`` — the direct definition, used by oracles/tests.

Gram-based distances clamp at zero: the expansion ``‖x‖²+‖y‖²−2⟨x,y⟩`` can go
slightly negative in floating point.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy, FP32


def _dot(X: jax.Array, Y: jax.Array, accum_dtype) -> jax.Array:
    """(n,d)·(m,d)ᵀ with explicit accumulation dtype (MXU-friendly)."""
    return jax.lax.dot_general(
        X,
        Y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=accum_dtype,
    )


def sq_norms(X: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """Row-wise ‖x‖² with explicit accumulation dtype.

    A half-precision payload must NOT be up-cast wholesale (that
    materializes a payload-sized fp32 copy — the exact traffic the
    compute/accum split avoids); the self-inner-product routes through
    ``dot_general`` so the widening rides ``preferred_element_type``
    inside the unit, like the Gram matmul's. (Audit fixture:
    ``precision.sq-norms-upcast`` in tests/test_analysis.py.)
    """
    if X.dtype == jnp.dtype(accum_dtype):
        return jnp.sum(X * X, axis=-1)
    contract = (X.ndim - 1,)
    batch = tuple(range(X.ndim - 1))
    return jax.lax.dot_general(
        X, X,
        dimension_numbers=((contract, contract), (batch, batch)),
        preferred_element_type=accum_dtype,
    )


def sqeuclidean_pairwise(
    X: jax.Array, Y: jax.Array, policy: PrecisionPolicy = FP32
) -> jax.Array:
    """‖x−y‖² for all pairs via the Gram expansion (one MXU matmul)."""
    Xc = X.astype(policy.compute_dtype)
    Yc = Y.astype(policy.compute_dtype)
    g = _dot(Xc, Yc, policy.accum_dtype)
    xn = sq_norms(Xc, policy.accum_dtype)
    yn = sq_norms(Yc, policy.accum_dtype)
    d2 = xn[:, None] + yn[None, :] - 2.0 * g
    return jnp.maximum(d2, 0.0)


def sqeuclidean_point(x: jax.Array, y: jax.Array) -> jax.Array:
    diff = x.astype(jnp.float32) - y.astype(jnp.float32)
    return jnp.sum(diff * diff)


def manhattan_pairwise(
    X: jax.Array, Y: jax.Array, policy: PrecisionPolicy = FP32
) -> jax.Array:
    """Σ|x−y| — not inner-product-expressible; direct broadcast (VPU path)."""
    Xc = X.astype(policy.accum_dtype)
    Yc = Y.astype(policy.accum_dtype)
    return jnp.sum(jnp.abs(Xc[:, None, :] - Yc[None, :, :]), axis=-1)


def manhattan_point(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sum(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)))


def cosine_pairwise(
    X: jax.Array, Y: jax.Array, policy: PrecisionPolicy = FP32
) -> jax.Array:
    """1 − cos(x, y) ∈ [0, 2]; Gram-based. Zero vectors map to dissimilarity 1."""
    Xc = X.astype(policy.compute_dtype)
    Yc = Y.astype(policy.compute_dtype)
    g = _dot(Xc, Yc, policy.accum_dtype)
    xn = jnp.sqrt(sq_norms(Xc, policy.accum_dtype))
    yn = jnp.sqrt(sq_norms(Yc, policy.accum_dtype))
    denom = jnp.maximum(xn[:, None] * yn[None, :], 1e-30)
    return jnp.maximum(1.0 - g / denom, 0.0)


def cosine_point(x: jax.Array, y: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    denom = jnp.maximum(jnp.linalg.norm(x) * jnp.linalg.norm(y), 1e-30)
    return jnp.maximum(1.0 - jnp.dot(x, y) / denom, 0.0)


#: Default RBF bandwidth — the single source of truth shared by the jnp
#: pairwise form and the Pallas kernel paths (host/device parity depends on
#: both sides using the same gamma).
RBF_GAMMA = 1.0


def rbf_pairwise(
    X: jax.Array, Y: jax.Array, policy: PrecisionPolicy = FP32,
    gamma: float = RBF_GAMMA,
) -> jax.Array:
    """Kernel-induced dissimilarity d(x,y) = 2·(1 − exp(−γ‖x−y‖²)) ≥ 0.

    The paper notes dissimilarities may be constructed from Mercer kernels;
    this is the RBF instance: d = k(x,x) + k(y,y) − 2k(x,y) with k RBF.
    """
    d2 = sqeuclidean_pairwise(X, Y, policy)
    return 2.0 * (1.0 - jnp.exp(-gamma * d2))


def rbf_point(x: jax.Array, y: jax.Array, gamma: float = RBF_GAMMA) -> jax.Array:
    return 2.0 * (1.0 - jnp.exp(-gamma * sqeuclidean_point(x, y)))


PAIRWISE: dict[str, Callable] = {
    "sqeuclidean": sqeuclidean_pairwise,
    "manhattan": manhattan_pairwise,
    "cosine": cosine_pairwise,
    "rbf": rbf_pairwise,
}

POINT: dict[str, Callable] = {
    "sqeuclidean": sqeuclidean_point,
    "manhattan": manhattan_point,
    "cosine": cosine_point,
    "rbf": rbf_point,
}

#: Distances whose pairwise form routes the dominant term through a matmul and
#: therefore through the fused Pallas kernels (kernels assume sqeuclidean).
MXU_ELIGIBLE = frozenset({"sqeuclidean", "rbf"})


def resolve_pairwise(name: str) -> Callable:
    try:
        return PAIRWISE[name]
    except KeyError as e:
        raise ValueError(f"unknown distance {name!r}; options {sorted(PAIRWISE)}") from e
