"""Distributed submodular evaluation over a device mesh (shard_map).

The paper's decomposition L(S) = Σ_i L_{v_i}(S) (eq. 5/6) is *exactly* a
data-parallel sum over the ground set: shard V's rows over the mesh's data
axes, evaluate partial work-matrix column blocks locally, ``psum`` the row
sums. This scales the technique from one GPU to a pod: each chip holds
n/|data| ground vectors of the *working* distance/cache state, the multiset
payload is replicated (it is l·k·d ≪ n·d), and the only communication is one
(l,)-sized all-reduce per evaluation — the technique is embarrassingly
scalable along exactly the axis that grows with corpus size. (The selection
engine's dense strategy additionally replicates its candidate pool — all of
V — per device for now; sharding the pool is a ROADMAP item.)

This module is the **sharded backend of the selection engine**
(:mod:`repro.core.engine`, plan ``device_sharded``): the whole k-round greedy
scan runs *inside* ``shard_map``, with V's rows and the min-distance cache
sharded over the mesh's data axes and the candidate payload replicated. Each
scored candidate batch reduces its (m,) per-shard gain partials with ONE
``psum`` of O(m) bytes (the trajectory scalar rides in the same collective):
dense/stochastic rounds issue exactly one; a CELF round issues one per top-B
re-scoring iteration (typically one, ⌈n/B⌉ in the degenerate full-re-score
case). The argmax — and for CELF the stale-bound state — stays replicated.
The standalone ``make_distributed_*`` evaluators remain as the
one-collective-per-call building blocks for external drivers.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import distances as dist_mod
from repro.core.engine import (DEVICE_TRACE_COUNTS, _device_block_m,
                               _score_blocked, drive_selection_scan)
from repro.core.evaluator import EvalConfig
from repro.core.functions import gains_formula
from repro.core.multiset import PackedMultiset
from repro.core.precision import resolve as resolve_policy


def shard_ground_set(V: jax.Array, mesh: Mesh,
                     data_axes: Sequence[str] = ("data",)) -> jax.Array:
    """Place V row-sharded over the mesh's data axes (replicated over model)."""
    spec = P(tuple(data_axes), None)
    return jax.device_put(V, NamedSharding(mesh, spec))


def make_distributed_eval(mesh: Mesh, cfg: EvalConfig,
                          data_axes: Sequence[str] = ("data",)):
    """Build a jitted distributed L(S_j ∪ {e0}) evaluator.

    Returns fn(V_sharded, data, lengths, d_e0_sharded) -> (l,) float32,
    where V is row-sharded over ``data_axes`` and the multiset is replicated.
    """
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_eval(V_loc, data, lengths, d_e0_loc, n_global):
        l, k, d = data.shape
        D = pair(V_loc, data.reshape(l * k, d), policy).reshape(V_loc.shape[0], l, k)
        mask = jnp.arange(k)[None, :] < lengths[:, None]
        big = jnp.asarray(jnp.finfo(D.dtype).max, D.dtype)
        D = jnp.where(mask[None, :, :], D, big)
        dmin = jnp.minimum(jnp.min(D, axis=-1), d_e0_loc[:, None].astype(D.dtype))
        partial_sum = jnp.sum(dmin, axis=0).astype(jnp.float32)  # (l,)
        total = jax.lax.psum(partial_sum, axes)
        return total / n_global

    smapped = shard_map(
        local_eval,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None, None), P(None), P(axes), P()),
        out_specs=P(None),
        check_rep=False,
    )

    @jax.jit
    def run(V_sharded, data, lengths, d_e0_sharded):
        n_global = jnp.asarray(V_sharded.shape[0], jnp.float32)
        return smapped(V_sharded, data, lengths, d_e0_sharded, n_global)

    return run


def make_distributed_gains(mesh: Mesh, cfg: EvalConfig,
                           data_axes: Sequence[str] = ("data",)):
    """Distributed marginal gains Δ(c_j | S) against a sharded min-cache."""
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_gains(V_loc, cands, cache_loc, n_global):
        # the engine's shared gain reduction with the global-n normalizer:
        # per-shard partials psum to the exact global gains
        g = gains_formula(V_loc, cands, cache_loc, pair, policy,
                          n_total=n_global)
        return jax.lax.psum(g.astype(jnp.float32), axes)

    smapped = shard_map(
        local_gains,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(axes), P()),
        out_specs=P(None),
        check_rep=False,
    )

    @jax.jit
    def run(V_sharded, cands, cache_sharded):
        n_global = jnp.asarray(V_sharded.shape[0], jnp.float32)
        return smapped(V_sharded, cands, cache_sharded, n_global)

    return run


def make_distributed_cache_update(mesh: Mesh, cfg: EvalConfig,
                                  data_axes: Sequence[str] = ("data",)):
    """min-cache update m ← min(m, d(V, x)) with both sharded the same way."""
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_update(V_loc, x, cache_loc):
        D = pair(V_loc, x[None, :], policy)[:, 0]
        return jnp.minimum(cache_loc, D.astype(cache_loc.dtype))

    smapped = shard_map(
        local_update,
        mesh=mesh,
        in_specs=(P(axes, None), P(None), P(axes)),
        out_specs=P(axes),
        check_rep=False,
    )
    return jax.jit(smapped)


# ---------------------------------------------------------------------------
# Mesh-sharded selection scan — the engine's device_sharded execution plan.
# All k rounds run in ONE dispatch inside shard_map; each scored candidate
# batch crosses the mesh as exactly one psum of O(m) bytes (one per
# dense/stochastic round, one per CELF re-scoring iteration).
# ---------------------------------------------------------------------------

_SELECTION_SCAN_CACHE: dict = {}


def make_selection_scan(
    mesh: Mesh,
    data_axes: Sequence[str],
    *,
    kind: str,               # "dense" | "stochastic" | "lazy"
    k: int,                  # selection rounds
    top_b: int,              # CELF re-score width (lazy only)
    n_total: int,            # global ground-set size (the gain normalizer)
    block_m: int,            # per-shard candidate block (bounds the tile)
    distance: str,
    policy_name: str,
    counter_key: str,
    backend: str = "jnp",    # "jnp" | "pallas" | "pallas_interpret"
    rbf_gamma: Optional[float] = None,
):
    """Build (and cache) the jitted mesh-sharded k-round selection scan.

    Returns ``fn(V_sh, pool, d_e0_sh, cand_rounds, w0) -> (sel, traj,
    n_scored)`` where ``V_sh``/``d_e0_sh`` are row-sharded over
    ``data_axes``, ``pool`` is the replicated candidate payload (rows indexed
    by ``cand_rounds`` — and by the CELF top-B gather), and ``cand_rounds``
    is (k, m) int32 for stochastic, ONE (1, m) row for dense (closed over by
    every round, never replicated k times), (1, 0) for lazy. The builder is
    cached per (mesh, statics) so repeat runs reuse one traced executable.

    On ``backend="pallas"``/``"pallas_interpret"`` each shard scores its
    local (n_loc, m) tile through the fused Pallas gain kernels
    (:func:`repro.kernels.ops.fused_gain_update` for dense/stochastic
    rounds — the winner fold rides in-tile — and ``marginal_gain`` for CELF
    re-scoring). The kernels already normalize by the *global* ``n_total``,
    so the per-shard outputs are exact gain partials and the one-psum-per-
    batch collective pattern is byte-identical to the jnp path. Shard-tile
    blocking note: ``block_m`` bounds the *jnp* path's streamed HBM tile
    only; the kernels tile their own VMEM blocks from the local shard height
    (padding n_loc/m to block multiples in-wrapper), so the MXU tiling is
    per-shard and never sees mesh topology.
    """
    axes = tuple(data_axes)
    key = (mesh, axes, kind, k, top_b, n_total, block_m, distance,
           policy_name, counter_key, backend, rbf_gamma)
    if key in _SELECTION_SCAN_CACHE:
        return _SELECTION_SCAN_CACHE[key]
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    use_kernel = backend in ("pallas", "pallas_interpret")
    if use_kernel:
        from repro.kernels import ops as kops

    def local_scan(V_loc, pool, d_e0_loc, cand_rounds, w0):
        cache0 = d_e0_loc.astype(jnp.float32)
        L0 = jax.lax.psum(jnp.sum(cache0), axes) / n_total

        def fold(cache, w):
            dw = pair(V_loc, w[None, :], policy)[:, 0]
            return jnp.minimum(cache, dw.astype(jnp.float32))

        def psum_gains_mean(g_part, cache):
            """ONE O(m)-byte collective per scored batch: the (m,) per-shard
            gain partials plus the shard's cache row-sum ride one psum."""
            payload = jnp.concatenate(
                [g_part.astype(jnp.float32),
                 (jnp.sum(cache) / n_total)[None]])
            out = jax.lax.psum(payload, axes)
            return out[:-1], out[-1]

        def score_part(cache, C):
            # per-shard gain partials: the kernel path tiles VMEM blocks
            # itself, the jnp path streams (n_loc, block_m) tiles — neither
            # materializes an (n_loc, m) distance block on any shard
            if use_kernel:
                return kops.marginal_gain(
                    V_loc, C, cache, policy=policy, rbf_gamma=rbf_gamma,
                    interpret=(backend != "pallas"), n_total=n_total)
            return _score_blocked(V_loc, C, cache, pair, policy, block_m,
                                  n_total=n_total)

        def score_mean(cache, C):
            # CELF re-scoring: every shard agrees on the while-loop's
            # iteration count because the bound state is replicated
            # (post-psum gains), so the per-iteration collectives line up
            return psum_gains_mean(score_part(cache, C), cache)

        if use_kernel:

            def fold_score_mean(cache, w_prev, C):
                # fused dense/stochastic round: the winner fold happens
                # inside the kernel on the local shard tile
                g_part, cache = kops.fused_gain_update(
                    V_loc, C, cache, w_prev, policy=policy,
                    rbf_gamma=rbf_gamma, interpret=(backend != "pallas"),
                    n_total=n_total)
                gains, mean_c = psum_gains_mean(g_part, cache)
                return gains, cache, mean_c
        else:

            def fold_score_mean(cache, w_prev, C):
                cache = fold(cache, w_prev)
                gains, mean_c = score_mean(cache, C)
                return gains, cache, mean_c

        def mean_of(cache):
            return jax.lax.psum(jnp.sum(cache) / n_total, axes)

        return drive_selection_scan(
            kind=kind, k=k, top_b=top_b, n_global=n_total, pool=pool,
            cand_rounds=cand_rounds, cache0=cache0, w0=w0, L0=L0, fold=fold,
            score_mean=score_mean, fold_score_mean=fold_score_mean,
            mean_of=mean_of)

    smapped = shard_map(
        local_scan,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(axes), P(None, None),
                  P(None)),
        out_specs=(P(None), P(None), P(None)),
        check_rep=False,
    )

    @jax.jit
    def run(V_sh, pool, d_e0_sh, cand_rounds, w0):
        DEVICE_TRACE_COUNTS[counter_key] += 1
        return smapped(V_sh, pool, d_e0_sh, cand_rounds, w0)

    _SELECTION_SCAN_CACHE[key] = run
    return run


def run_sharded_selection(
    f,                       # ExemplarClustering (untyped: avoids circularity)
    cand_rounds: jax.Array,  # (k, m) int32 global candidate indices
    w0: jax.Array,
    *,
    kind: str,
    k: int,
    top_b: int,
    counter_key: str,
    m_widest: int,
    block_m: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
    backend: str = "jnp",
    rbf_gamma: Optional[float] = None,
):
    """Place operands on the mesh and run the sharded selection scan.

    V's rows (padded to a shard multiple with zero rows — their cache
    entries are 0, so they contribute nothing to gains or sums) and the
    min-distance cache seed shard over ``data_axes``; the candidate pool
    **replicates** — O(n·d) resident bytes per device for the dense
    strategy (the distance/cache *work* is what shards; see the "sharded
    candidate pool" ROADMAP item for the O(n/p) follow-up). The placement
    is cached on ``f`` (most recent mesh only) so repeat runs pay no
    transfer; delete ``f._sharded_placement_cache`` to release the device
    memory. The per-shard gain tile is bounded by ``block_m`` (autotuned
    from the *local* shard height and the widest candidate round
    ``m_widest`` when not given). Returns ``(sel, traj, n_scored)`` device
    arrays.
    """
    if mesh is None:
        if len(data_axes) != 1:
            raise ValueError(
                "the default mesh is 1-D; pass an explicit mesh to shard "
                f"over multiple axes {tuple(data_axes)}")
        mesh = jax.make_mesh((jax.device_count(),), tuple(data_axes))
    axes = tuple(data_axes)
    ndev = 1
    for a in axes:
        ndev *= mesh.shape[a]
    n = f.n
    n_pad = ((n + ndev - 1) // ndev) * ndev
    bm = block_m if block_m is not None \
        else _device_block_m(n_pad // ndev, m_widest)
    # pad + placement cached on the function instance (V is immutable): a
    # repeat run reuses the resident shards, paying no per-call transfer.
    # Only the MOST RECENT (mesh, axes) is kept — the replicated pool is
    # O(n·d) per device (a documented ROADMAP tradeoff), so accumulating
    # one resident copy per mesh ever used would pin unbounded memory.
    placed = getattr(f, "_sharded_placement_cache", None)
    if placed is None or placed[0] != (mesh, axes):
        Vp = jnp.pad(f.V, ((0, n_pad - n), (0, 0)))
        d_e0p = jnp.pad(f.d_e0.astype(jnp.float32), (0, n_pad - n))
        placed = f._sharded_placement_cache = ((mesh, axes), (
            jax.device_put(Vp, NamedSharding(mesh, P(axes, None))),
            jax.device_put(d_e0p, NamedSharding(mesh, P(axes))),
            jax.device_put(f.V, NamedSharding(mesh, P(None, None))),
        ))
    V_sh, d_e0_sh, pool = placed[1]
    fn = make_selection_scan(
        mesh, axes, kind=kind, k=k, top_b=top_b, n_total=n, block_m=bm,
        distance=f.cfg.distance, policy_name=f.cfg.resolved_policy().name,
        counter_key=counter_key, backend=backend, rbf_gamma=rbf_gamma)
    return fn(V_sh, pool, d_e0_sh, cand_rounds, w0)


def distributed_greedy(
    mesh: Mesh,
    V: jax.Array,
    k: int,
    cfg: EvalConfig = EvalConfig(),
    data_axes: Sequence[str] = ("data",),
    candidate_batch: Optional[int] = None,
) -> tuple[list[int], float]:
    """Pod-scale greedy: V sharded over data axes, one psum per step.

    A thin wrapper over the selection engine's ``device_sharded`` plan (all
    k rounds in one dispatch). ``candidate_batch`` bounds the per-shard
    candidate *compute tile* (default: autotuned from the probed gain-tile
    cap) — candidates stream through (n_loc, batch) tiles, so no shard
    materializes an (n_loc, n) distance block. Note the engine replicates
    the candidate pool (all of V) per device, so resident memory is
    O(n·d) + O(n/p·d) per chip — unlike the pre-engine host-streamed loop;
    see the "sharded candidate pool" ROADMAP item. Returns
    (indices, f value).

    Like the original implementation, scoring always runs the jnp pairwise
    path regardless of ``cfg.backend`` (kernel backends are normalized away
    rather than rejected).
    """
    import dataclasses

    from repro.core.functions import ExemplarClustering
    from repro.core.optimizers import greedy

    if cfg.backend in ("pallas", "pallas_interpret"):
        cfg = dataclasses.replace(cfg, backend="jnp")
    f = ExemplarClustering(jnp.asarray(V), cfg)
    res = greedy(f, k, mode="device_sharded", mesh=mesh, data_axes=data_axes,
                 block_m=candidate_batch)
    return res.indices, res.value
