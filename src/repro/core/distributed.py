"""Distributed submodular evaluation over a device mesh (shard_map).

The paper's decomposition L(S) = Σ_i L_{v_i}(S) (eq. 5/6) is *exactly* a
data-parallel sum over the ground set: shard V's rows over the mesh's data
axes, evaluate partial work-matrix column blocks locally, ``psum`` the row
sums. This scales the technique from one GPU to a pod: each chip holds
n/|data| ground vectors of the *working* distance/cache state, the multiset
payload is replicated (it is l·k·d ≪ n·d), and the only communication is one
(l,)-sized all-reduce per evaluation — the technique is embarrassingly
scalable along exactly the axis that grows with corpus size.

This module is the **sharded backend of the selection engine**
(:mod:`repro.core.engine`): the whole k-round greedy scan runs *inside*
``shard_map``, with V's rows and the min-distance cache sharded over the
mesh's data axes. Three execution plans live here:

* ``device_sharded`` — the candidate payload replicates (O(n·d) resident per
  device; fine for sampled/lazy candidate sets, the documented tradeoff for
  dense greedy). Each scored candidate batch reduces its (m,) per-shard gain
  partials with ONE ``psum`` of O(m) bytes (the trajectory scalar rides in
  the same collective): dense/stochastic rounds issue exactly one; a CELF
  round issues one per top-B re-scoring iteration.
* ``device_sharded_pool`` — **no O(n·d) array is ever replicated**: the
  candidate payload row-shards exactly like V (for the selection engine it
  *is* V's shard — zero extra resident bytes), taking per-device memory to
  O(n/p·d). Candidate scoring blocks psum-materialize transiently from
  their owning shards (one O(Bm·d) collective per block), and the round
  winner's column is all-gathered by the same ``take`` (one O(d) psum per
  round) instead of riding a resident replica — the CELF top-B re-score and
  its ub0 seeding pass stream through the identical blocked takes. Only the
  O(n) *scalar* CELF bound state (and the argmax) stays replicated.
* ``greedi`` — Mirzasoleiman et al.'s distributed partition-then-merge for
  dense greedy: each shard greedily solves its own V-partition in-place (no
  collectives), the p·k partial solutions all-gather in one O(p·k·d) psum,
  and a merge greedy over that small replicated pool runs under the
  sharded-cache callbacks. Selections carry the GreeDi constant-factor
  guarantee rather than matching centralized greedy exactly.

The argmax — and for CELF the stale-bound state — stays replicated in every
plan. The standalone ``make_distributed_*`` evaluators remain as the
one-collective-per-call building blocks for external drivers.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.contracts import contract
from repro.core import distances as dist_mod
from repro.core import functions as fx
from repro.core.engine import (DEVICE_TRACE_COUNTS, _device_block_m,
                               _score_blocked, drive_selection_scan,
                               drive_selection_scan_batched,
                               mesh_tiles_per_memory)
from repro.core.evaluator import EvalConfig
from repro.core.functions import FnSpec, gains_formula
from repro.core.multiset import PackedMultiset
from repro.core.precision import resolve as resolve_policy


def shard_ground_set(V: jax.Array, mesh: Mesh,
                     data_axes: Sequence[str] = ("data",)) -> jax.Array:
    """Place V row-sharded over the mesh's data axes (replicated over model)."""
    spec = P(tuple(data_axes), None)
    return jax.device_put(V, NamedSharding(mesh, spec))


def make_distributed_eval(mesh: Mesh, cfg: EvalConfig,
                          data_axes: Sequence[str] = ("data",)):
    """Build a jitted distributed L(S_j ∪ {e0}) evaluator.

    Returns fn(V_sharded, data, lengths, d_e0_sharded) -> (l,) float32,
    where V is row-sharded over ``data_axes`` and the multiset is replicated.
    """
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_eval(V_loc, data, lengths, d_e0_loc, n_global):
        l, k, d = data.shape
        D = pair(V_loc, data.reshape(l * k, d), policy).reshape(V_loc.shape[0], l, k)
        mask = jnp.arange(k)[None, :] < lengths[:, None]
        big = jnp.asarray(jnp.finfo(D.dtype).max, D.dtype)
        D = jnp.where(mask[None, :, :], D, big)
        dmin = jnp.minimum(jnp.min(D, axis=-1), d_e0_loc[:, None].astype(D.dtype))
        partial_sum = jnp.sum(dmin, axis=0).astype(jnp.float32)  # (l,)
        total = jax.lax.psum(partial_sum, axes)
        return total / n_global

    smapped = shard_map(
        local_eval,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None, None), P(None), P(axes), P()),
        out_specs=P(None),
        check_rep=False,
    )

    @jax.jit
    def run(V_sharded, data, lengths, d_e0_sharded):
        n_global = jnp.asarray(V_sharded.shape[0], jnp.float32)
        return smapped(V_sharded, data, lengths, d_e0_sharded, n_global)

    return run


def make_distributed_gains(mesh: Mesh, cfg: EvalConfig,
                           data_axes: Sequence[str] = ("data",)):
    """Distributed marginal gains Δ(c_j | S) against a sharded min-cache."""
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_gains(V_loc, cands, cache_loc, n_global):
        # the engine's shared gain reduction with the global-n normalizer:
        # per-shard partials psum to the exact global gains
        g = gains_formula(V_loc, cands, cache_loc, pair, policy,
                          n_total=n_global)
        return jax.lax.psum(g.astype(jnp.float32), axes)

    smapped = shard_map(
        local_gains,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(axes), P()),
        out_specs=P(None),
        check_rep=False,
    )

    @jax.jit
    def run(V_sharded, cands, cache_sharded):
        n_global = jnp.asarray(V_sharded.shape[0], jnp.float32)
        return smapped(V_sharded, cands, cache_sharded, n_global)

    return run


def make_distributed_cache_update(mesh: Mesh, cfg: EvalConfig,
                                  data_axes: Sequence[str] = ("data",)):
    """min-cache update m ← min(m, d(V, x)) with both sharded the same way."""
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_update(V_loc, x, cache_loc):
        D = pair(V_loc, x[None, :], policy)[:, 0]
        return jnp.minimum(cache_loc, D.astype(cache_loc.dtype))

    smapped = shard_map(
        local_update,
        mesh=mesh,
        in_specs=(P(axes, None), P(None), P(axes)),
        out_specs=P(axes),
        check_rep=False,
    )
    return jax.jit(smapped)


# ---------------------------------------------------------------------------
# Mesh-sharded selection scan — the engine's device_sharded execution plan.
# All k rounds run in ONE dispatch inside shard_map; each scored candidate
# batch crosses the mesh as exactly one psum of O(m) bytes (one per
# dense/stochastic round, one per CELF re-scoring iteration).
# ---------------------------------------------------------------------------

_SELECTION_SCAN_CACHE: dict = {}


@contract(
    "distributed.selection_scan[sharded]",
    factory=True,
    collective_kinds=("psum",),
    claim="one dispatch; the round body streams blocked O(Bm·d) takes and "
          "ONE O(m) gains psum — no collective ever carries O(n·d) or "
          "O(n·m) bytes; candidate payload resident O(n/p·d) per device")
@contract(
    "distributed.selection_scan[replicated]",
    factory=True,
    collective_kinds=("psum",),
    claim="one dispatch; ONE O(m) gains psum per scored batch (plus graph "
          "cut's owner-gather fold); v0 seeding and the final trajectory "
          "value are the only round-independent collectives")
def make_selection_scan(
    mesh: Mesh,
    data_axes: Sequence[str],
    *,
    fn: FnSpec = FnSpec(),   # the function's static identity
    kind: str,               # "dense" | "stochastic" | "lazy"
    k: int,                  # selection rounds
    top_b: int,              # CELF re-score width (lazy only)
    n_total: int,            # global ground-set size (the gain normalizer)
    block_m: int,            # per-shard candidate block (bounds the tile)
    distance: str,
    policy_name: str,
    counter_key: str,
    backend: str = "jnp",    # "jnp" | "pallas" | "pallas_interpret"
    rbf_gamma: Optional[float] = None,
    pool_plan: str = "replicated",  # "replicated" | "sharded"
):
    """Build (and cache) the jitted mesh-sharded k-round selection scan.

    Returns ``run(V_sh, pool, seed_sh, aux_sh, cand_rounds, w0) -> (sel,
    traj, n_scored)`` where ``V_sh``/``seed_sh``/``aux_sh`` are row-sharded
    over ``data_axes`` (the function's cache seed and static per-row
    auxiliary, padded with its sentinel values — see
    :func:`functions.pad_seed` / :func:`functions.pad_row_aux`) and
    ``cand_rounds`` is (k, m) int32 for stochastic, ONE (1, m) row for dense
    (closed over by every round, never replicated k times), (1, 0) for lazy.
    The builder is cached per (mesh, fn, statics) so repeat runs reuse one
    traced executable; ``fn`` rides the cache key exactly like a jit static.

    The cache is the function's ``(vec, aux)`` pytree: the vec row-shards
    with V, the scalar aux (graph cut's pairwise penalty) stays replicated —
    its winner-indexed update is an owner-shard gather psum'd in
    :func:`functions.fold_aux` (executed unconditionally so the collective
    pattern is uniform across shards, then gated on winner validity). Graph
    cut's index-addressed gain extra is a per-shard partial by construction
    (the owner contributes the one real term, every other shard 0), so it
    rides the existing per-batch gains psum with no extra collective.

    ``pool_plan`` picks the candidate-payload memory plan:

    * ``"replicated"`` — ``pool`` is the full candidate payload, resident
      on every device; candidate rows gather locally and each scored batch
      costs one O(m) psum.
    * ``"sharded"`` — ``pool`` row-shards over ``data_axes`` exactly like V
      (callers pass V's own shard — zero extra resident bytes, O(n/p·d) per
      device). Candidate *indices* resolve through a ``take`` that
      psum-materializes only the requested columns from their owning shards
      (zero-padded rows elsewhere make the psum an exact gather): scoring
      streams ⌈m/block_m⌉ such O(Bm·d) collectives per batch and the round
      winner's (d,) column is all-gathered the same way, so no shard ever
      holds more than one candidate block. The CELF ub0 seeding pass and
      top-B re-scores run through the identical blocked takes; the O(n)
      scalar bound state stays replicated (it is the documented exception —
      bounds are per-candidate scalars, not payload).

    On ``backend="pallas"``/``"pallas_interpret"`` each shard scores its
    local (n_loc, m) tile through the shared min/max Pallas kernel template
    (:func:`repro.kernels.ops.fused_gain_update` for dense/stochastic
    rounds of fused-eligible functions — the winner fold rides in-tile —
    and ``marginal_gain`` for CELF re-scoring and graph cut's add-fold
    rounds; the sharded pool streams take-blocks through ``marginal_gain``
    with an explicit fold, since a block materializes only after the fold's
    winner column is gathered). The kernels already normalize by the
    *global* ``n_total``, so the per-shard outputs are exact gain partials
    and the one-psum-per-batch collective pattern is byte-identical to the
    jnp path. Shard-tile blocking note: ``block_m`` bounds the *jnp* path's
    streamed HBM tile (and the sharded pool's take-block width) only; the
    kernels tile their own VMEM blocks from the local shard height (padding
    n_loc/m to block multiples in-wrapper), so the MXU tiling is per-shard
    and never sees mesh topology.
    """
    axes = tuple(data_axes)
    key = (mesh, axes, fn, kind, k, top_b, n_total, block_m, distance,
           policy_name, counter_key, backend, rbf_gamma, pool_plan)
    if key in _SELECTION_SCAN_CACHE:
        return _SELECTION_SCAN_CACHE[key]
    if pool_plan not in ("replicated", "sharded"):
        raise ValueError(f"unknown pool_plan {pool_plan!r}")
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    tmpl = fx.kernel_template(fn)
    use_kernel = backend in ("pallas", "pallas_interpret") and tmpl is not None
    sharded_pool = pool_plan == "sharded"
    if use_kernel:
        from repro.kernels import ops as kops

    def local_scan(V_loc, pool, seed_loc, aux_loc, cand_rounds, w0):
        n_loc = V_loc.shape[0]
        off = jax.lax.axis_index(axes) * n_loc
        seedf = seed_loc.astype(jnp.float32)
        v0 = jax.lax.psum(
            jnp.sum(fx.stat_rows(fn, seedf, aux_loc)), axes) / n_total
        psum_ = lambda x: jax.lax.psum(x, axes)  # noqa: E731

        def value_of(cache):
            vec, aux = cache
            mean_stat = jax.lax.psum(
                jnp.sum(fx.stat_rows(fn, vec, aux_loc)) / n_total, axes)
            return fx.value_from_stat(fn, v0, mean_stat, aux, n_total)

        def fold(cache, w):
            vec, aux = cache
            row, gidx = w
            dw = pair(V_loc, row[None, :], policy)[:, 0]
            folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
            # aux advances from the PRE-fold vec; its psum (graph cut's
            # owner gather) executes unconditionally so every shard issues
            # the same collectives, and the where gates after
            new_aux = fx.fold_aux(fn, vec, aux, gidx, off, n_loc, psum=psum_)
            ok = gidx >= 0
            return (jnp.where(ok, folded, vec), jnp.where(ok, new_aux, aux))

        def psum_gains_val(g_part, cache):
            """ONE O(m)-byte collective per scored batch: the (m,) per-shard
            gain partials plus the shard's stat row-sum ride one psum."""
            vec, aux = cache
            payload = jnp.concatenate(
                [g_part.astype(jnp.float32),
                 (jnp.sum(fx.stat_rows(fn, vec, aux_loc)) / n_total)[None]])
            out = jax.lax.psum(payload, axes)
            return out[:-1], fx.value_from_stat(fn, v0, out[-1], aux, n_total)

        def score_part(vec, C):
            # per-shard gain partials: the kernel path tiles VMEM blocks
            # itself, the jnp path streams (n_loc, block_m) tiles — neither
            # materializes an (n_loc, m) distance block on any shard
            sc = fx.score_cache_rows(fn, vec, aux_loc)
            if use_kernel:
                return kops.marginal_gain(
                    V_loc, C, sc, policy=policy, rbf_gamma=rbf_gamma,
                    fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"), n_total=n_total)
            return _score_blocked(V_loc, C, sc, pair, policy, block_m,
                                  n_total=n_total, fn=fn, row_aux=aux_loc)

        cache0 = (seedf, jnp.float32(0.0))
        w0c = (w0.astype(pool.dtype), jnp.asarray(-1, jnp.int32))

        if sharded_pool:
            n_loc_pool = pool.shape[0]
            off_pool = jax.lax.axis_index(axes) * n_loc_pool

            def take_rows(idxv):
                """Materialize pool rows for *global* indices: one psum of
                the owner's rows against everyone else's zeros (exact — the
                psum adds one real row and p−1 zero rows)."""
                rel = idxv - off_pool
                own = (rel >= 0) & (rel < n_loc_pool)
                rows = pool[jnp.clip(rel, 0, n_loc_pool - 1)]
                return jax.lax.psum(
                    jnp.where(own[:, None], rows, jnp.zeros_like(rows)),
                    axes)

            def take(j):
                return take_rows(jnp.atleast_1d(j))[0], j

            def score_idx(cache, idx):
                # stream index blocks: take-materialize (block_m, d), score
                # the local tile, never hold two blocks at once
                vec, _aux = cache
                m = idx.shape[0]
                bm = min(block_m, m)
                m_pad = -(-m // bm) * bm
                idx_p = jnp.pad(idx, (0, m_pad - m))
                parts = jax.lax.map(
                    lambda ib: score_part(vec, take_rows(ib)),
                    idx_p.reshape(-1, bm)).reshape(-1)[:m]
                extra = fx.gains_index_extra(fn, vec, idx, off, n_loc,
                                             n_total)
                return parts if extra is None else parts + extra

            def score_idx_val(cache, idx):
                return psum_gains_val(score_idx(cache, idx), cache)

            def fold_score_val(cache, w_prev, cand_t):
                # the fold stays explicit: the winner column was already
                # gathered last round, and candidate blocks only
                # materialize inside the streamed scoring below
                cache = fold(cache, w_prev)
                gains, val = score_idx_val(cache, cand_t)
                return gains, cache, val

            def seed_val(cache):
                return score_idx_val(
                    cache, jnp.arange(n_total, dtype=jnp.int32))

            return drive_selection_scan(
                kind=kind, k=k, top_b=top_b, n_global=n_total, take=take,
                n_pool=n_total, seed_val=seed_val,
                score_idx_val=score_idx_val, cand_rounds=cand_rounds,
                cache0=cache0, w0=w0c, fold=fold,
                fold_score_val=fold_score_val, value_of=value_of)

        def score_idx_val(cache, idx):
            vec, _aux = cache
            g = score_part(vec, pool[idx])
            extra = fx.gains_index_extra(fn, vec, idx, off, n_loc, n_total)
            return psum_gains_val(g if extra is None else g + extra, cache)

        if use_kernel and fx.kernel_fused_ok(fn):

            def fold_score_val(cache, w_prev, cand_t):
                # fused dense/stochastic round: the winner fold happens
                # inside the kernel on the local shard tile (fused-eligible
                # functions carry no aux and no index extra)
                vec, aux = cache
                row, gidx = w_prev
                g_part, vec2 = kops.fused_gain_update(
                    V_loc, pool[cand_t], vec, row, policy=policy,
                    rbf_gamma=rbf_gamma, fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"), n_total=n_total,
                    w_valid=(gidx >= 0).astype(jnp.float32))
                cache2 = (vec2, aux)
                gains, val = psum_gains_val(g_part, cache2)
                return gains, cache2, val
        else:

            def fold_score_val(cache, w_prev, cand_t):
                cache2 = fold(cache, w_prev)
                gains, val = score_idx_val(cache2, cand_t)
                return gains, cache2, val

        return drive_selection_scan(
            kind=kind, k=k, top_b=top_b, n_global=n_total, pool=pool,
            cand_rounds=cand_rounds, cache0=cache0, w0=w0c, fold=fold,
            score_idx_val=score_idx_val, fold_score_val=fold_score_val,
            value_of=value_of)

    smapped = shard_map(
        local_scan,
        mesh=mesh,
        in_specs=(P(axes, None),
                  P(axes, None) if sharded_pool else P(None, None),
                  P(axes), P(axes), P(None, None), P(None)),
        out_specs=(P(None), P(None), P(None)),
        check_rep=False,
    )

    @jax.jit
    def run(V_sh, pool, seed_sh, aux_sh, cand_rounds, w0):
        DEVICE_TRACE_COUNTS[counter_key] += 1
        return smapped(V_sh, pool, seed_sh, aux_sh, cand_rounds, w0)

    _SELECTION_SCAN_CACHE[key] = run
    return run


def _resolve_mesh(mesh: Optional[Mesh], data_axes: Sequence[str]) -> Mesh:
    if mesh is None:
        if len(data_axes) != 1:
            raise ValueError(
                "the default mesh is 1-D; pass an explicit mesh to shard "
                f"over multiple axes {tuple(data_axes)}")
        mesh = jax.make_mesh((jax.device_count(),), tuple(data_axes))
    return mesh


def _mesh_extent(mesh: Mesh, axes: Sequence[str]) -> int:
    ndev = 1
    for a in axes:
        ndev *= mesh.shape[a]
    return ndev


def _placed_sharded(f, mesh: Mesh, axes: tuple, replicated_pool: bool):
    """Shard-place (and cache on ``f``) V's padded rows plus the function's
    cache seed and per-row auxiliary.

    V pads with zero rows; the seed and row_aux pad with the function's
    sentinel values (:func:`functions.pad_seed` / ``pad_row_aux``) so pad
    rows contribute nothing to gains or stat sums — 0 for the min/additive
    caches, +inf dead-row markers for the max-cache functions (a zero V row
    is a *real-looking* point whose similarity to candidates is positive,
    so only the sentinel makes it inert). The placement is cached on the
    function instance (V, seed and aux are immutable) so repeat runs pay no
    transfer; delete ``f._sharded_placement_cache`` to release the device
    memory. Only the MOST RECENT (mesh, axes) is kept, and the
    **replicated** candidate pool — O(n·d) resident per device, the
    ``device_sharded`` plan's documented tradeoff — is built lazily, only
    when that plan actually runs: the sharded-pool and greedi plans never
    pin it.
    """
    n = f.n
    ndev = _mesh_extent(mesh, axes)
    n_pad = ((n + ndev - 1) // ndev) * ndev
    placed = getattr(f, "_sharded_placement_cache", None)
    if placed is None or placed[0] != (mesh, axes):
        Vp = jnp.pad(f.V, ((0, n_pad - n), (0, 0)))
        seedp = jnp.pad(f.cache_seed, (0, n_pad - n),
                        constant_values=fx.pad_seed(f.spec))
        auxp = jnp.pad(f.row_aux, (0, n_pad - n),
                       constant_values=fx.pad_row_aux(f.spec))
        placed = f._sharded_placement_cache = ((mesh, axes), {
            "V_sh": jax.device_put(Vp, NamedSharding(mesh, P(axes, None))),
            "seed_sh": jax.device_put(seedp, NamedSharding(mesh, P(axes))),
            "aux_sh": jax.device_put(auxp, NamedSharding(mesh, P(axes))),
        })
    entry = placed[1]
    if replicated_pool and "pool" not in entry:
        entry["pool"] = jax.device_put(
            f.V, NamedSharding(mesh, P(None, None)))
    return entry


def run_sharded_selection(
    f,                       # SubmodularFunction (untyped: avoids circularity)
    cand_rounds: jax.Array,  # (k, m) int32 global candidate indices
    w0: jax.Array,
    *,
    kind: str,
    k: int,
    top_b: int,
    counter_key: str,
    m_widest: int,
    block_m: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
    backend: str = "jnp",
    rbf_gamma: Optional[float] = None,
    pool_plan: str = "replicated",
):
    """Place operands on the mesh and run the sharded selection scan.

    ``pool_plan="replicated"`` keeps the candidate payload resident on
    every device (O(n·d) — fine for sampled/lazy candidates);
    ``pool_plan="sharded"`` passes V's own row-shard as the pool (zero
    extra resident bytes — O(n/p·d) per device total) and
    psum-materializes candidate blocks on demand (see
    :func:`make_selection_scan`). The per-shard gain tile is bounded by
    ``block_m``: autotuned from the *local* shard height n/p (never global
    n — that would under-fill every shard's memory p×), the widest
    candidate round ``m_widest``, and the number of shards whose tiles
    share one physical memory space (forced host devices: p tiles carve
    one allocator pool — sizing each from the full probe would over-commit
    p×). Under the sharded pool the take-block width is additionally
    capped at n_loc so the transient gathered block never exceeds the
    resident shard — the O(n/p) peak-memory claim covers transients too.
    Returns ``(sel, traj, n_scored)`` device arrays.
    """
    mesh = _resolve_mesh(mesh, data_axes)
    axes = tuple(data_axes)
    ndev = _mesh_extent(mesh, axes)
    n = f.n
    n_pad = ((n + ndev - 1) // ndev) * ndev
    n_loc = n_pad // ndev
    bm = block_m if block_m is not None \
        else _device_block_m(n_loc, m_widest, mesh_tiles_per_memory(mesh))
    if pool_plan == "sharded":
        bm = min(bm, max(8, n_loc))
    entry = _placed_sharded(f, mesh, axes, pool_plan == "replicated")
    V_sh, seed_sh, aux_sh = entry["V_sh"], entry["seed_sh"], entry["aux_sh"]
    pool = entry["pool"] if pool_plan == "replicated" else V_sh
    scan = make_selection_scan(
        mesh, axes, fn=f.spec, kind=kind, k=k, top_b=top_b, n_total=n,
        block_m=bm, distance=f.cfg.distance,
        policy_name=f.cfg.resolved_policy().name, counter_key=counter_key,
        backend=backend, rbf_gamma=rbf_gamma, pool_plan=pool_plan)
    return scan(V_sh, pool, seed_sh, aux_sh, cand_rounds, w0)


# ---------------------------------------------------------------------------
# Batched × sharded composition — B tenants in one (B, n/p) mesh dispatch.
# Every per-request (n,) state from the single-device batched engine gains
# its mesh layout here: the B min-caches row-shard WITH V ((B, n_loc) per
# device), and each scored batch's B per-request gain partials stack into
# the SAME psum (O(B·m) bytes, trajectory scalars riding along) instead of
# issuing B collectives. Ragged k stays the k_eff freeze mask, so bucket
# padding is inert on every shard.
# ---------------------------------------------------------------------------

_SELECTION_SCAN_BATCHED_CACHE: dict = {}


@contract(
    "distributed.selection_scan_batched[sharded]",
    factory=True,
    collective_kinds=("psum",),
    donate=("seed_sh",),
    claim="B tenants, one dispatch, O(B·n/p·d) resident per device; the "
          "round body streams blocked O(B·m·d) takes and ONE O(B·m) gains "
          "psum — per-tenant partials stack into the same collective, "
          "never B collectives; the (B, n/p) cache seed is donated")
@contract(
    "distributed.selection_scan_batched[replicated]",
    factory=True,
    collective_kinds=("psum",),
    donate=("seed_sh",),
    claim="B tenants, one dispatch; ONE O(B·m) gains psum per scored batch "
          "(every tenant's partials + trajectory scalars in one collective "
          "— not B psums); the (B, n/p) sharded cache seed is donated and "
          "aliased onto the final cache output")
def make_selection_scan_batched(
    mesh: Mesh,
    data_axes: Sequence[str],
    *,
    fn: FnSpec = FnSpec(),   # the function's static identity
    kind: str,               # "dense" | "stochastic" | "lazy"
    k: int,                  # shared scan length (max per-request k)
    top_b: int,              # CELF re-score width (lazy only)
    n_total: int,            # global ground-set size (the gain normalizer)
    block_m: int,            # per-shard candidate block (bounds the tile)
    distance: str,
    policy_name: str,
    counter_key: str,
    backend: str = "jnp",    # "jnp" | "pallas" | "pallas_interpret"
    rbf_gamma: Optional[float] = None,
    pool_plan: str = "replicated",  # "replicated" | "sharded"
):
    """Build (and cache) the jitted batched mesh-sharded k-round scan.

    The batched composition of :func:`make_selection_scan`: B same-signature
    requests lay their state out as (B, n/p) per device — ``V_sh`` is
    (B, n_pad, d) sharded ``P(None, data_axes, None)``, the B per-tenant
    cache seeds / row auxiliaries row-shard with it, and ``cand_rounds`` is
    the (B, k, m) per-request candidate indices. Returns ``run(V_sh, pool,
    seed_sh, aux_sh, cand_rounds, w0, k_eff) -> (sel (k, B), traj (k, B),
    n_scored (B,), cache_vec (B, n_pad))``.

    Collective budget: each scored candidate batch reduces ALL B requests'
    (m,) per-shard gain partials in ONE psum of O(B·m) bytes — the batch
    axis rides the collective's payload, never its count — with each
    request's stat row-sum (the trajectory scalar) concatenated into the
    same payload. The per-request column of that payload is byte-identical
    to the unbatched plan's (m+1,) psum, which is why batched-sharded
    selections, trajectories, AND per-request eval counts are bit-equal to
    B separate sharded runs. Batched CELF shares the unbatched step's
    certification loop via :func:`engine.make_batched_lazy_step_val` (the
    trajectory value rides the re-score psum; frozen requests' values
    emerge from the same collective, masked out of bound/count updates).

    ``seed_sh`` is DONATED: the final folded (B, n_pad) cache vector rides
    out with the same NamedSharding, so XLA aliases the carry onto the
    seed's per-device buffers — warm buckets reuse O(B·n/p) bytes instead
    of allocating per dispatch. ``k_eff`` (B,) int32 is the ragged-k freeze
    mask (0 = inert bucket-padding slot).

    On kernel backends each shard scores its local (B, n_loc, m) tile
    through the grid-over-(B, m_tiles, n_tiles) batched Pallas kernels
    (:mod:`repro.kernels.marginal_gain`) with the *global* ``n_total``
    normalizer, so per-shard tiles stay exact psum partials exactly like
    the unbatched sharded kernels. ``pool_plan`` has the same two memory
    plans as the unbatched factory — ``"sharded"`` passes V's own (B, n/p)
    shard as the pool and psum-materializes (B, block) candidate slabs
    from their owning shards.
    """
    axes = tuple(data_axes)
    key = (mesh, axes, fn, kind, k, top_b, n_total, block_m, distance,
           policy_name, counter_key, backend, rbf_gamma, pool_plan)
    if key in _SELECTION_SCAN_BATCHED_CACHE:
        return _SELECTION_SCAN_BATCHED_CACHE[key]
    if pool_plan not in ("replicated", "sharded"):
        raise ValueError(f"unknown pool_plan {pool_plan!r}")
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    tmpl = fx.kernel_template(fn)
    use_kernel = backend in ("pallas", "pallas_interpret") and tmpl is not None
    sharded_pool = pool_plan == "sharded"
    if use_kernel:
        from repro.kernels import ops as kops

    def local_scan(V_loc, pool, seed_loc, aux_loc, cand_rounds, w0, k_eff):
        B, n_loc, _d = V_loc.shape
        off = jax.lax.axis_index(axes) * n_loc
        seedf = seed_loc.astype(jnp.float32)
        # (B,) per-request v0 in ONE psum — the batch axis is payload
        v0 = jax.lax.psum(
            jnp.sum(fx.stat_rows(fn, seedf, aux_loc), axis=1), axes) / n_total
        psum_ = lambda x: jax.lax.psum(x, axes)  # noqa: E731

        def value_of(cache):
            vec, aux = cache
            mean_stat = jax.lax.psum(
                jnp.sum(fx.stat_rows(fn, vec, aux_loc), axis=1) / n_total,
                axes)
            return fx.value_from_stat(fn, v0, mean_stat, aux, n_total)

        def fold(cache, w):
            vec, aux = cache
            row, gidx = w
            dw = jax.vmap(
                lambda Vb, rb: pair(Vb, rb[None, :], policy)[:, 0])(V_loc, row)
            folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
            # aux advances from the PRE-fold vec. vmapping the per-request
            # fold batches graph cut's owner-gather psum OPERAND to (B,) —
            # still ONE collective — and every shard executes it
            # unconditionally before the gate, exactly like unbatched
            new_aux = jax.vmap(
                lambda vb, ab, gb: fx.fold_aux(fn, vb, ab, gb, off, n_loc,
                                               psum=psum_))(vec, aux, gidx)
            ok = gidx >= 0
            return (jnp.where(ok[:, None], folded, vec),
                    jnp.where(ok, new_aux, aux))

        def psum_gains_val(g_part, cache):
            """ONE O(B·m)-byte collective per scored batch: all B requests'
            (m,) gain partials plus their stat row-sums ride one psum —
            each request's column is byte-identical to the unbatched
            plan's (m+1,) payload."""
            vec, aux = cache
            stat = (jnp.sum(fx.stat_rows(fn, vec, aux_loc), axis=1)
                    / n_total)[:, None]
            out = jax.lax.psum(
                jnp.concatenate([g_part.astype(jnp.float32), stat], axis=1),
                axes)
            return out[:, :-1], fx.value_from_stat(fn, v0, out[:, -1], aux,
                                                   n_total)

        def score_part(vec, C):
            # per-shard (B, m) gain partials: the batched kernels tile
            # grid-over-(B, m_tiles, n_tiles) VMEM blocks themselves; the
            # jnp path vmaps the (n_loc, block_m)-streamed reduction —
            # neither materializes a (B, n_loc, m) block on any shard
            sc = fx.score_cache_rows(fn, vec, aux_loc)
            if use_kernel:
                return kops.marginal_gain(
                    V_loc, C, sc, policy=policy, rbf_gamma=rbf_gamma,
                    fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"), n_total=n_total)
            return jax.vmap(
                lambda Vb, Cb, scb, rb: _score_blocked(
                    Vb, Cb, scb, pair, policy, block_m, n_total=n_total,
                    fn=fn, row_aux=rb))(V_loc, C, sc, aux_loc)

        def gains_extra(vec, idx):
            # graph cut's index-addressed per-shard partial, per request
            # (None for every other function — vmap passes None through)
            return jax.vmap(
                lambda vb, ib: fx.gains_index_extra(fn, vb, ib, off, n_loc,
                                                    n_total))(vec, idx)

        cache0 = (seedf, jnp.zeros((B,), jnp.float32))
        w0c = (w0.astype(pool.dtype), jnp.full((B,), -1, jnp.int32))

        if sharded_pool:
            n_loc_pool = pool.shape[1]
            off_pool = jax.lax.axis_index(axes) * n_loc_pool

            def take_rows(idxv):
                """Materialize (B, mb, d) pool slabs for *global* indices:
                one psum of each owner's rows against everyone else's
                zeros, all B requests in the same collective."""
                rel = idxv - off_pool
                own = (rel >= 0) & (rel < n_loc_pool)
                rows = jnp.take_along_axis(
                    pool, jnp.clip(rel, 0, n_loc_pool - 1)[:, :, None],
                    axis=1)
                return jax.lax.psum(
                    jnp.where(own[:, :, None], rows, jnp.zeros_like(rows)),
                    axes)

            def take(j):
                return take_rows(j[:, None])[:, 0], j

            def score_idx(cache, idx):
                # stream per-request index blocks in lockstep: one
                # take-materialized (B, bm, d) slab at a time, never two
                vec, _aux = cache
                m = idx.shape[1]
                bm = min(block_m, m)
                m_pad = -(-m // bm) * bm
                idx_p = jnp.pad(idx, ((0, 0), (0, m_pad - m)))
                blocks = jnp.moveaxis(idx_p.reshape(B, -1, bm), 1, 0)
                parts = jax.lax.map(
                    lambda ib: score_part(vec, take_rows(ib)), blocks)
                parts = jnp.moveaxis(parts, 0, 1).reshape(B, -1)[:, :m]
                extra = gains_extra(vec, idx)
                return parts if extra is None else parts + extra

            def score_idx_val(cache, idx):
                return psum_gains_val(score_idx(cache, idx), cache)

            def fold_score_val(cache, w_prev, cand_t):
                cache = fold(cache, w_prev)
                gains, val = score_idx_val(cache, cand_t)
                return gains, cache, val

            def seed_val(cache):
                return score_idx_val(cache, jnp.broadcast_to(
                    jnp.arange(n_total, dtype=jnp.int32), (B, n_total)))

            sel, traj, n_scored, cache_f = drive_selection_scan_batched(
                kind=kind, k=k, top_b=top_b, n_global=n_total, k_eff=k_eff,
                take=take, n_pool=n_total, seed_val=seed_val,
                cand_rounds=cand_rounds, cache0=cache0, w0=w0c, fold=fold,
                score_idx_val=score_idx_val, fold_score_val=fold_score_val,
                value_of=value_of)
            return sel, traj, n_scored, cache_f[0]

        def score_idx_val(cache, idx):
            vec, _aux = cache
            g = score_part(vec, jnp.take_along_axis(
                pool, idx[:, :, None], axis=1))
            extra = gains_extra(vec, idx)
            return psum_gains_val(g if extra is None else g + extra, cache)

        if use_kernel and fx.kernel_fused_ok(fn):

            def fold_score_val(cache, w_prev, cand_t):
                # fused dense/stochastic round: each request's winner fold
                # happens inside the batched kernel on its local shard tile
                vec, aux = cache
                row, gidx = w_prev
                g_part, vec2 = kops.fused_gain_update(
                    V_loc, jnp.take_along_axis(
                        pool, cand_t[:, :, None], axis=1),
                    vec, row, policy=policy, rbf_gamma=rbf_gamma,
                    fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"), n_total=n_total,
                    w_valid=(gidx >= 0).astype(jnp.float32))
                cache2 = (vec2, aux)
                gains, val = psum_gains_val(g_part, cache2)
                return gains, cache2, val
        else:

            def fold_score_val(cache, w_prev, cand_t):
                cache2 = fold(cache, w_prev)
                gains, val = score_idx_val(cache2, cand_t)
                return gains, cache2, val

        sel, traj, n_scored, cache_f = drive_selection_scan_batched(
            kind=kind, k=k, top_b=top_b, n_global=n_total, k_eff=k_eff,
            pool=pool, cand_rounds=cand_rounds, cache0=cache0, w0=w0c,
            fold=fold, score_idx_val=score_idx_val,
            fold_score_val=fold_score_val, value_of=value_of)
        return sel, traj, n_scored, cache_f[0]

    smapped = shard_map(
        local_scan,
        mesh=mesh,
        in_specs=(P(None, axes, None),
                  P(None, axes, None) if sharded_pool else P(None, None, None),
                  P(None, axes), P(None, axes), P(None, None, None),
                  P(None, None), P(None)),
        out_specs=(P(None), P(None), P(None), P(None, axes)),
        check_rep=False,
    )

    @partial(jax.jit, donate_argnums=(2,))
    def run(V_sh, pool, seed_sh, aux_sh, cand_rounds, w0, k_eff):
        DEVICE_TRACE_COUNTS[counter_key] += 1
        return smapped(V_sh, pool, seed_sh, aux_sh, cand_rounds, w0, k_eff)

    _SELECTION_SCAN_BATCHED_CACHE[key] = run
    return run


def stage_sharded_batch(
    fs,                      # Sequence[SubmodularFunction]
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
    pool_plan: str = "replicated",
):
    """Pad, stack, and shard-place a bucket of B same-signature requests.

    Host-stacks each request's V/seed/aux (padding rows to the mesh extent
    with the function's inert sentinels, exactly like the unbatched
    :func:`_placed_sharded`) and issues ONE ``jax.device_put`` per operand
    with its (B, n/p) NamedSharding — async on accelerators, so a serving
    loop can stage the NEXT bucket while the current dispatch runs. No
    placement is cached on the functions: the seed must be FRESH per
    dispatch (the batched scan donates it) and bucket composition changes
    call to call. The returned payload is single-use and carries the
    (mesh, axes, pool_plan) it was staged for.
    """
    mesh = _resolve_mesh(mesh, data_axes)
    axes = tuple(data_axes)
    ndev = _mesh_extent(mesh, axes)
    f0 = fs[0]
    n = f0.n
    n_pad = ((n + ndev - 1) // ndev) * ndev
    V_np = [np.asarray(f.V) for f in fs]
    Vp = np.stack([np.pad(v, ((0, n_pad - n), (0, 0))) for v in V_np])
    seedp = np.stack([
        np.pad(np.asarray(f.cache_seed, np.float32), (0, n_pad - n),
               constant_values=fx.pad_seed(f.spec)) for f in fs])
    auxp = np.stack([
        np.pad(np.asarray(f.row_aux), (0, n_pad - n),
               constant_values=fx.pad_row_aux(f.spec)) for f in fs])
    if all(f.e0 is None for f in fs):
        w0_b = np.zeros((len(fs), f0.dim), V_np[0].dtype)
    else:
        w0_b = np.stack([
            np.asarray(f.e0, V_np[0].dtype) if f.e0 is not None
            else np.zeros((f.dim,), V_np[0].dtype) for f in fs])
    payload = {
        "mesh": mesh, "axes": axes, "pool_plan": pool_plan,
        "V_sh": jax.device_put(Vp, NamedSharding(mesh, P(None, axes, None))),
        "seed_sh": jax.device_put(seedp, NamedSharding(mesh, P(None, axes))),
        "aux_sh": jax.device_put(auxp, NamedSharding(mesh, P(None, axes))),
        "w0": jax.device_put(w0_b, NamedSharding(mesh, P(None, None))),
    }
    if pool_plan == "replicated":
        # UNPADDED (B, n, d): the replicated pool is candidate payload, and
        # lazy's ub0 seeding scores every pool row — a padded row would be
        # a real-looking candidate (matches the unbatched "pool" entry)
        payload["pool"] = jax.device_put(
            np.stack(V_np), NamedSharding(mesh, P(None, None, None)))
    return payload


def run_sharded_selection_batch(
    fs,                      # Sequence[SubmodularFunction]
    cand_rounds: jax.Array,  # (B, k, m) int32 global candidate indices
    ks: Sequence[int],
    *,
    kind: str,
    k: int,
    top_b: int,
    counter_key: str,
    m_widest: int,
    block_m: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
    backend: str = "jnp",
    rbf_gamma: Optional[float] = None,
    pool_plan: str = "replicated",
    staged: Optional[dict] = None,
):
    """Place a bucket's (B, n/p) operands and run the batched sharded scan.

    The gain tile autotunes from B·n_loc rows — the LOCAL shard height
    times the batch (never B·n global, which would under-fill every shard
    p×) — divided once by the number of shard tiles that share one
    physical memory space; under the sharded pool the take-slab width is
    additionally capped at n_loc so the (B, bm, d) transient never exceeds
    the resident shard. ``staged`` optionally passes the payload a prior
    :func:`stage_sharded_batch` already transferred (it is re-staged here
    if its mesh/axes/pool_plan disagree). Returns ``(sel (k, B),
    traj (k, B), n_scored (B,))`` device arrays.
    """
    mesh = _resolve_mesh(mesh, data_axes)
    axes = tuple(data_axes)
    ndev = _mesh_extent(mesh, axes)
    f0 = fs[0]
    B = len(fs)
    n = f0.n
    n_pad = ((n + ndev - 1) // ndev) * ndev
    n_loc = n_pad // ndev
    bm = block_m if block_m is not None \
        else _device_block_m(n_loc, m_widest, mesh_tiles_per_memory(mesh),
                             n_batch=B)
    if pool_plan == "sharded":
        bm = min(bm, max(8, n_loc))
    if staged is None or staged["mesh"] != mesh or staged["axes"] != axes \
            or staged["pool_plan"] != pool_plan:
        staged = stage_sharded_batch(fs, mesh=mesh, data_axes=axes,
                                     pool_plan=pool_plan)
    V_sh = staged["V_sh"]
    pool = staged["pool"] if pool_plan == "replicated" else V_sh
    scan = make_selection_scan_batched(
        mesh, axes, fn=f0.spec, kind=kind, k=k, top_b=top_b, n_total=n,
        block_m=bm, distance=f0.cfg.distance,
        policy_name=f0.cfg.resolved_policy().name, counter_key=counter_key,
        backend=backend, rbf_gamma=rbf_gamma, pool_plan=pool_plan)
    sel, traj, n_scored, _ = scan(
        V_sh, pool, staged["seed_sh"], staged["aux_sh"], cand_rounds,
        staged["w0"], jnp.asarray(np.asarray(ks, np.int32)))
    return sel, traj, n_scored


# ---------------------------------------------------------------------------
# GreeDi partition-then-merge (plan ``greedi``) — Mirzasoleiman et al.,
# "Distributed Submodular Maximization". Phase 1 runs the single-device
# one-dispatch dense greedy scan on every shard's own V-partition (no
# collectives at all); one O(p·k·d) psum all-gathers the p·k partial
# solutions; phase 2 re-runs the same drive_selection_scan as a merge round
# over that small replicated pool with the cache sharded (one O(p·k) psum
# per merge round). Per-device memory is O(n/p·d) + O(p·k·d).
# ---------------------------------------------------------------------------

_GREEDI_SCAN_CACHE: dict = {}


@contract(
    "distributed.greedi_scan",
    factory=True,
    driving_scans=2,
    collective_kinds=("psum",),
    claim="both GreeDi phases in ONE dispatch (partition greedy + p-"
          "solution global eval + merge greedy); the gathered solution "
          "pool is the largest collective payload — O(p·k·d), never O(n)")
def make_greedi_scan(
    mesh: Mesh,
    data_axes: Sequence[str],
    *,
    fn: FnSpec = FnSpec(),
    k: int,
    n_total: int,
    block_m: int,
    distance: str,
    policy_name: str,
    counter_key: str,
    backend: str = "jnp",
    rbf_gamma: Optional[float] = None,
):
    """Build (and cache) the jitted two-phase GreeDi scan.

    Returns ``run(V_sh, seed_sh, aux_sh, w0) -> (sel, traj, n_scored)``.
    Both phases run inside ONE ``shard_map`` dispatch: phase 1 is the
    single-device scan construction on the local partition (on Pallas
    backends a fused-eligible function's winner fold rides in the fused
    kernel exactly like plan ``device``; gains normalize by the *local* n so
    the partition function is self-consistent — for graph cut the penalty
    normalizer must match the gain normalizer for the argmax to be
    meaningful), driven with ``taken0`` masking the shard's zero-padding
    rows; phase 2 follows Mirzasoleiman et al.'s Alg. 2 in full: the
    gathered p·k partial solutions replicate (k·p·d ≪ n·d, the same budget
    class as the multiset payload), each partition's OWN solution is
    evaluated *globally* (p·k extra sharded folds), and a merge greedy over
    the pool runs under the sharded-cache psum callbacks — the answer is
    whichever of {merged greedy, best single-partition solution} scores
    higher (the "best-of-both" max the proven bound is stated for). The
    merge trajectory is the *global* f(S_t) (cache sharded, psum'd stat), so
    the returned trajectory is directly comparable with every other plan;
    ``n_scored`` sums the partition rounds' actually-scored candidates
    (psum) plus the merge round's plus the p·k global evaluation folds.
    Selections carry the GreeDi partition bound rather than matching
    centralized greedy.
    """
    axes = tuple(data_axes)
    key = (mesh, axes, fn, k, n_total, block_m, distance, policy_name,
           counter_key, backend, rbf_gamma)
    if key in _GREEDI_SCAN_CACHE:
        return _GREEDI_SCAN_CACHE[key]
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    tmpl = fx.kernel_template(fn)
    use_kernel = backend in ("pallas", "pallas_interpret") and tmpl is not None
    if use_kernel:
        from repro.kernels import ops as kops
    p_total = _mesh_extent(mesh, axes)

    def local_scan(V_loc, seed_loc, aux_loc, w0):
        n_loc, d = V_loc.shape
        lin = jax.lax.axis_index(axes)
        off = lin * n_loc
        seedf = seed_loc.astype(jnp.float32)
        cache0 = (seedf, jnp.float32(0.0))
        w0c = (w0.astype(V_loc.dtype), jnp.asarray(-1, jnp.int32))
        psum_ = lambda x: jax.lax.psum(x, axes)  # noqa: E731

        # ---- phase 1: independent dense greedy over the local partition
        # (no collectives at all — local indices, local normalizers; the
        # phase-1 trajectory is partition-local and discarded)
        v0_loc = jnp.mean(fx.stat_rows(fn, seedf, aux_loc))

        def value_local(cache):
            vec, aux = cache
            return fx.value_from_stat(
                fn, v0_loc, jnp.mean(fx.stat_rows(fn, vec, aux_loc)), aux,
                n_loc)

        def fold_local(cache, w):
            vec, aux = cache
            row, idx = w
            dw = pair(V_loc, row[None, :], policy)[:, 0]
            folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
            new_aux = fx.fold_aux(fn, vec, aux, idx, 0, n_loc)
            ok = idx >= 0
            return (jnp.where(ok, folded, vec), jnp.where(ok, new_aux, aux))

        def score_local(vec, C, n_norm):
            sc = fx.score_cache_rows(fn, vec, aux_loc)
            if use_kernel:
                return kops.marginal_gain(
                    V_loc, C, sc, policy=policy, rbf_gamma=rbf_gamma,
                    fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"), n_total=n_norm)
            return _score_blocked(V_loc, C, sc, pair, policy, block_m,
                                  n_total=n_norm, fn=fn, row_aux=aux_loc)

        if use_kernel and fx.kernel_fused_ok(fn):

            def fold_score_local(cache, w_prev, cand_t):
                vec, aux = cache
                row, idx = w_prev
                g, vec2 = kops.fused_gain_update(
                    V_loc, V_loc[cand_t], vec, row, policy=policy,
                    rbf_gamma=rbf_gamma, fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"),
                    w_valid=(idx >= 0).astype(jnp.float32))
                cache2 = (vec2, aux)
                return g, cache2, value_local(cache2)
        else:

            def fold_score_local(cache, w_prev, cand_t):
                cache2 = fold_local(cache, w_prev)
                vec2, _aux2 = cache2
                g = score_local(vec2, V_loc[cand_t], None)
                extra = fx.gains_index_extra(fn, vec2, cand_t, 0, n_loc,
                                             n_loc)
                g = g if extra is None else g + extra
                return g, cache2, value_local(cache2)

        pad_taken = (jnp.arange(n_loc, dtype=jnp.int32) + off) >= n_total
        sel1, _, nsc1 = drive_selection_scan(
            kind="dense", k=k, top_b=0, n_global=n_total, pool=V_loc,
            taken0=pad_taken,
            cand_rounds=jnp.arange(n_loc, dtype=jnp.int32)[None, :],
            cache0=cache0, w0=w0c, fold=fold_local,
            fold_score_val=fold_score_local, value_of=value_local)

        # ---- all-gather the p·k partial solutions: each shard owns one
        # slot of the (p, k, ·) buffers, one psum fills them all
        sel1 = sel1.astype(jnp.int32)
        slot = jnp.arange(p_total, dtype=jnp.int32) == lin
        merged_vec = jax.lax.psum(
            jnp.where(slot[:, None, None], V_loc[sel1][None], 0),
            axes).reshape(p_total * k, d)
        merged_idx = jax.lax.psum(
            jnp.where(slot[:, None], (sel1 + off)[None], 0),
            axes).reshape(p_total * k)
        nsc1_total = jax.lax.psum(nsc1, axes)

        # ---- global cache machinery shared by the local-solution
        # evaluation and the merge greedy
        v0g = jax.lax.psum(
            jnp.sum(fx.stat_rows(fn, seedf, aux_loc)), axes) / n_total

        def value_global(cache):
            vec, aux = cache
            mean_stat = jax.lax.psum(
                jnp.sum(fx.stat_rows(fn, vec, aux_loc)) / n_total, axes)
            return fx.value_from_stat(fn, v0g, mean_stat, aux, n_total)

        def fold_global(cache, w):
            vec, aux = cache
            row, gidx = w
            dw = pair(V_loc, row[None, :], policy)[:, 0]
            folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
            new_aux = fx.fold_aux(fn, vec, aux, gidx, off, n_loc,
                                  psum=psum_)
            ok = gidx >= 0
            return (jnp.where(ok, folded, vec), jnp.where(ok, new_aux, aux))

        def psum_gains_val(g_part, cache):
            vec, aux = cache
            payload = jnp.concatenate(
                [g_part.astype(jnp.float32),
                 (jnp.sum(fx.stat_rows(fn, vec, aux_loc)) / n_total)[None]])
            out = jax.lax.psum(payload, axes)
            return out[:-1], fx.value_from_stat(fn, v0g, out[-1], aux,
                                                n_total)

        # ---- evaluate each partition's solution GLOBALLY (best-of-both):
        # p·k extra folds against fresh sharded caches; every shard runs the
        # identical p·k fold/value collectives, so the psums stay uniform
        rows_pk = merged_vec.reshape(p_total, k, d)
        idx_pk = merged_idx.reshape(p_total, k)

        def eval_solution(args):
            rows_q, idx_q = args

            def body(cache, wt):
                row_t, idx_t = wt
                cache = fold_global(cache, (row_t, idx_t))
                return cache, value_global(cache)

            _, vals = jax.lax.scan(body, cache0, (rows_q, idx_q))
            return vals

        local_trajs = jax.lax.map(eval_solution, (rows_pk, idx_pk))  # (p, k)
        best_q = jnp.argmax(local_trajs[:, -1])
        best_local_val = local_trajs[best_q, -1]

        # ---- merge greedy over the gathered pool, cache sharded
        if use_kernel and fx.kernel_fused_ok(fn):

            def fold_score_merge(cache, w_prev, cand_t):
                vec, aux = cache
                row, gidx = w_prev
                g_part, vec2 = kops.fused_gain_update(
                    V_loc, merged_vec[cand_t], vec, row, policy=policy,
                    rbf_gamma=rbf_gamma, fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(backend != "pallas"), n_total=n_total,
                    w_valid=(gidx >= 0).astype(jnp.float32))
                cache2 = (vec2, aux)
                gains, val = psum_gains_val(g_part, cache2)
                return gains, cache2, val
        else:

            def fold_score_merge(cache, w_prev, cand_t):
                cache2 = fold_global(cache, w_prev)
                vec2, _aux2 = cache2
                g = score_local(vec2, merged_vec[cand_t], n_total)
                extra = fx.gains_index_extra(
                    fn, vec2, merged_idx[cand_t], off, n_loc, n_total)
                g = g if extra is None else g + extra
                gains, val = psum_gains_val(g, cache2)
                return gains, cache2, val

        sel2, traj2, nsc2 = drive_selection_scan(
            kind="dense", k=k, top_b=0, n_global=n_total,
            take=lambda j: (merged_vec[j], merged_idx[j]),
            n_pool=p_total * k,
            cand_rounds=jnp.arange(p_total * k, dtype=jnp.int32)[None, :],
            cache0=cache0, w0=w0c, fold=fold_global,
            fold_score_val=fold_score_merge, value_of=value_global)

        # ---- best-of-both: return whichever of (merged greedy, best
        # single-partition solution) scores higher globally; ties keep the
        # merged answer (strict >)
        use_local = best_local_val > traj2[-1]
        sel_out = jnp.where(use_local, idx_pk[best_q], merged_idx[sel2])
        traj_out = jnp.where(use_local, local_trajs[best_q], traj2)
        n_scored = nsc1_total + nsc2 + jnp.asarray(p_total * k, jnp.int32)
        return sel_out, traj_out, n_scored

    smapped = shard_map(
        local_scan,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(axes), P(None)),
        out_specs=(P(None), P(None), P(None)),
        check_rep=False,
    )

    @jax.jit
    def run(V_sh, seed_sh, aux_sh, w0):
        DEVICE_TRACE_COUNTS[counter_key] += 1
        return smapped(V_sh, seed_sh, aux_sh, w0)

    _GREEDI_SCAN_CACHE[key] = run
    return run


def run_greedi_selection(
    f,                       # SubmodularFunction (untyped: avoids circularity)
    w0: jax.Array,
    *,
    k: int,
    counter_key: str,
    block_m: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
    backend: str = "jnp",
    rbf_gamma: Optional[float] = None,
):
    """Place operands and run the GreeDi partition-then-merge scan.

    Every partition must hold at least k *real* (non-padding) rows — each
    runs an independent k-round greedy whose argmax would otherwise run out
    of candidates. Returns ``(sel, traj, n_scored)`` device arrays.
    """
    mesh = _resolve_mesh(mesh, data_axes)
    axes = tuple(data_axes)
    ndev = _mesh_extent(mesh, axes)
    n = f.n
    n_pad = ((n + ndev - 1) // ndev) * ndev
    n_loc = n_pad // ndev
    tail_real = n - (ndev - 1) * n_loc
    if tail_real < k:
        raise ValueError(
            f"greedi partitions V into {ndev} shards of {n_loc} rows; the "
            f"last shard holds only {tail_real} real rows, fewer than k={k}"
            f" — its partition greedy would run out of candidates")
    bm = block_m if block_m is not None \
        else _device_block_m(n_loc, n_loc, mesh_tiles_per_memory(mesh))
    entry = _placed_sharded(f, mesh, axes, replicated_pool=False)
    scan = make_greedi_scan(
        mesh, axes, fn=f.spec, k=k, n_total=n, block_m=bm,
        distance=f.cfg.distance, policy_name=f.cfg.resolved_policy().name,
        counter_key=counter_key, backend=backend, rbf_gamma=rbf_gamma)
    return scan(entry["V_sh"], entry["seed_sh"], entry["aux_sh"], w0)


def distributed_greedy(
    mesh: Mesh,
    V: jax.Array,
    k: int,
    cfg: EvalConfig = EvalConfig(),
    data_axes: Sequence[str] = ("data",),
    candidate_batch: Optional[int] = None,
) -> tuple[list[int], float]:
    """Pod-scale greedy: V sharded over data axes, one psum per step.

    A thin wrapper over the selection engine's ``device_sharded`` plan (all
    k rounds in one dispatch). ``candidate_batch`` bounds the per-shard
    candidate *compute tile* (default: autotuned from the probed gain-tile
    cap) — candidates stream through (n_loc, batch) tiles, so no shard
    materializes an (n_loc, n) distance block. Note the engine replicates
    the candidate pool (all of V) per device, so resident memory is
    O(n·d) + O(n/p·d) per chip — unlike the pre-engine host-streamed loop;
    see the "sharded candidate pool" ROADMAP item. Returns
    (indices, f value).

    Like the original implementation, scoring always runs the jnp pairwise
    path regardless of ``cfg.backend`` (kernel backends are normalized away
    rather than rejected).
    """
    import dataclasses

    from repro.core.functions import ExemplarClustering
    from repro.core.optimizers import greedy

    if cfg.backend in ("pallas", "pallas_interpret"):
        cfg = dataclasses.replace(cfg, backend="jnp")
    f = ExemplarClustering(jnp.asarray(V), cfg)
    res = greedy(f, k, mode="device_sharded", mesh=mesh, data_axes=data_axes,
                 block_m=candidate_batch)
    return res.indices, res.value
