"""Distributed submodular evaluation over a device mesh (shard_map).

The paper's decomposition L(S) = Σ_i L_{v_i}(S) (eq. 5/6) is *exactly* a
data-parallel sum over the ground set: shard V's rows over the mesh's data
axes, evaluate partial work-matrix column blocks locally, ``psum`` the row
sums. This scales the technique from one GPU to a pod: each chip holds
n/|data| ground vectors, the multiset payload is replicated (it is l·k·d ≪
n·d), and the only communication is one (l,)-sized all-reduce per evaluation
— the technique is embarrassingly scalable along exactly the axis that grows
with corpus size.

Greedy at pod scale: candidate gains are computed against local V shards and
psum'd; the argmax is then a replicated scalar op. One collective per greedy
step, O(l) bytes.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import distances as dist_mod
from repro.core.evaluator import EvalConfig
from repro.core.multiset import PackedMultiset
from repro.core.precision import resolve as resolve_policy


def shard_ground_set(V: jax.Array, mesh: Mesh,
                     data_axes: Sequence[str] = ("data",)) -> jax.Array:
    """Place V row-sharded over the mesh's data axes (replicated over model)."""
    spec = P(tuple(data_axes), None)
    return jax.device_put(V, NamedSharding(mesh, spec))


def make_distributed_eval(mesh: Mesh, cfg: EvalConfig,
                          data_axes: Sequence[str] = ("data",)):
    """Build a jitted distributed L(S_j ∪ {e0}) evaluator.

    Returns fn(V_sharded, data, lengths, d_e0_sharded) -> (l,) float32,
    where V is row-sharded over ``data_axes`` and the multiset is replicated.
    """
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_eval(V_loc, data, lengths, d_e0_loc, n_global):
        l, k, d = data.shape
        D = pair(V_loc, data.reshape(l * k, d), policy).reshape(V_loc.shape[0], l, k)
        mask = jnp.arange(k)[None, :] < lengths[:, None]
        big = jnp.asarray(jnp.finfo(D.dtype).max, D.dtype)
        D = jnp.where(mask[None, :, :], D, big)
        dmin = jnp.minimum(jnp.min(D, axis=-1), d_e0_loc[:, None].astype(D.dtype))
        partial_sum = jnp.sum(dmin, axis=0).astype(jnp.float32)  # (l,)
        total = jax.lax.psum(partial_sum, axes)
        return total / n_global

    smapped = shard_map(
        local_eval,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None, None), P(None), P(axes), P()),
        out_specs=P(None),
        check_rep=False,
    )

    @jax.jit
    def run(V_sharded, data, lengths, d_e0_sharded):
        n_global = jnp.asarray(V_sharded.shape[0], jnp.float32)
        return smapped(V_sharded, data, lengths, d_e0_sharded, n_global)

    return run


def make_distributed_gains(mesh: Mesh, cfg: EvalConfig,
                           data_axes: Sequence[str] = ("data",)):
    """Distributed marginal gains Δ(c_j | S) against a sharded min-cache."""
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_gains(V_loc, cands, cache_loc, n_global):
        D = pair(V_loc, cands, policy)  # (n_loc, m)
        g = jnp.sum(jnp.maximum(cache_loc[:, None] - D, 0.0), axis=0)
        return jax.lax.psum(g.astype(jnp.float32), axes) / n_global

    smapped = shard_map(
        local_gains,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None), P(axes), P()),
        out_specs=P(None),
        check_rep=False,
    )

    @jax.jit
    def run(V_sharded, cands, cache_sharded):
        n_global = jnp.asarray(V_sharded.shape[0], jnp.float32)
        return smapped(V_sharded, cands, cache_sharded, n_global)

    return run


def make_distributed_cache_update(mesh: Mesh, cfg: EvalConfig,
                                  data_axes: Sequence[str] = ("data",)):
    """min-cache update m ← min(m, d(V, x)) with both sharded the same way."""
    policy = resolve_policy(cfg.policy)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    axes = tuple(data_axes)

    def local_update(V_loc, x, cache_loc):
        D = pair(V_loc, x[None, :], policy)[:, 0]
        return jnp.minimum(cache_loc, D.astype(cache_loc.dtype))

    smapped = shard_map(
        local_update,
        mesh=mesh,
        in_specs=(P(axes, None), P(None), P(axes)),
        out_specs=P(axes),
        check_rep=False,
    )
    return jax.jit(smapped)


def distributed_greedy(
    mesh: Mesh,
    V: jax.Array,
    k: int,
    cfg: EvalConfig = EvalConfig(),
    data_axes: Sequence[str] = ("data",),
    candidate_batch: Optional[int] = None,
) -> tuple[list[int], float]:
    """Pod-scale greedy: V sharded over data axes, one psum per step.

    Runs the optimizer-aware (min-cache) greedy. Returns (indices, f value).
    """
    import numpy as np

    V_sh = shard_ground_set(V, mesh, data_axes)
    pair = dist_mod.resolve_pairwise(cfg.distance)
    d_e0 = pair(V, jnp.zeros((V.shape[-1],), V.dtype)[None, :],
                resolve_policy(cfg.policy))[:, 0]
    cache = jax.device_put(
        d_e0.astype(jnp.float32),
        NamedSharding(mesh, P(tuple(data_axes))),
    )
    gains_fn = make_distributed_gains(mesh, cfg, data_axes)
    update_fn = make_distributed_cache_update(mesh, cfg, data_axes)
    L0 = float(jnp.mean(d_e0))

    selected: list[int] = []
    n = V.shape[0]
    for _ in range(k):
        if candidate_batch is None:
            gains = np.array(gains_fn(V_sh, V_sh, cache))
        else:
            parts = []
            for s in range(0, n, candidate_batch):
                parts.append(np.asarray(gains_fn(V_sh, V[s:s + candidate_batch], cache)))
            gains = np.concatenate(parts)
        gains[np.asarray(selected, dtype=np.int64)] = -np.inf
        j = int(np.argmax(gains))
        selected.append(j)
        cache = update_fn(V_sh, V[j], cache)
    value = L0 - float(jnp.mean(cache))
    return selected, value
