"""The unified selection-engine layer (tentpole, beyond paper).

Every optimizer in the greedy family is the same machine viewed through two
orthogonal choices:

* a **round-candidate strategy** — which candidates get scored each round:

  - ``dense``       every (validated) candidate, every round; one candidate
                    row broadcast over all k rounds.
  - ``stochastic``  k pre-sampled candidate rows (one per round), drawn up
                    front so host and device paths consume identical
                    randomness.
  - ``lazy`` (CELF) stale upper bounds carried as an (n,) array; each round
                    re-scores the top-B stale candidates (``jax.lax.top_k``
                    inside the scan carry) and falls back to a full re-score
                    when the fresh-top invariant fails.

* an **execution plan** — where the rounds run:

  - ``host``           reference Python loop (one dispatch per round).
  - ``device``         all k rounds inside ONE jitted ``jax.lax.scan``
                       dispatch; gains, argmax and cache update never leave
                       the accelerator.
  - ``device_sharded`` the same scan, row-sharding V *and* the min-distance
                       cache over a device mesh via ``shard_map``. Per round,
                       each shard computes its (m,) gain partials and one
                       ``psum`` of O(m) bytes reduces them; the argmax (and
                       the CELF bound state) stays replicated.

The min-distance cache recurrence (see :mod:`repro.core.optimizers`) is the
shared substrate: a round is one (n × m) distance evaluation plus an O(n)
fold of the winner. On Pallas backends the fold rides inside the fused gain
kernel (:func:`repro.kernels.ops.fused_gain_update`), so the winner's
distance column never materializes in HBM.

CELF on device: submodularity means gains only shrink, so last round's gains
are upper bounds for this round. The scan carries those bounds as an (n,)
array; each round an inner ``jax.lax.while_loop`` re-scores the top-B stale
bounds and stops as soon as the fresh-top invariant certifies the winner —
*best fresh gain ≥ every remaining stale bound* ⇒ the fresh best is the true
argmax. When staleness defeats the shortcut the loop keeps taking the next
top-B batch, degenerating to a full re-score after ⌈n/B⌉ iterations — the
device mirror of the host CELF heap's pop-rescore-repeat, without the
per-batch host↔device round-trips.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_mod
from repro.core.evaluator import free_memory_bytes
from repro.core.functions import ExemplarClustering, gains_formula
from repro.core.precision import resolve as resolve_policy


@dataclasses.dataclass
class OptResult:
    indices: list[int]
    value: float
    trajectory: list[float]
    evaluations: int

    def exemplars(self, V) -> np.ndarray:
        return np.asarray(V)[self.indices]


#: Number of times each device engine has been *traced* (not dispatched).
#: A second run with identical shapes/statics must not increment these —
#: that is the "exactly one jitted dispatch for all k rounds" property.
DEVICE_TRACE_COUNTS: collections.Counter = collections.Counter()

#: Fraction of probed free device memory the gain tile may occupy.
GAIN_TILE_MEMORY_FRACTION = 0.25


def validate_candidates(candidates, n: int) -> np.ndarray:
    """Validate a candidate-index subset at the engine boundary.

    Out-of-range indices raise; duplicates are dropped keeping first
    occurrence (a duplicated index would otherwise be scored twice and could
    even be *selected* twice by the device argmax, which masks ``taken`` by
    index, not by position).
    """
    cand = np.asarray(candidates).reshape(-1)
    if not np.issubdtype(cand.dtype, np.integer):
        raise ValueError(
            f"candidate indices must be integers, got dtype {cand.dtype}")
    cand = cand.astype(np.int64)
    if cand.size == 0:
        raise ValueError("candidates must be non-empty")
    if cand.min() < 0 or cand.max() >= n:
        raise ValueError(
            f"candidate indices must lie in [0, {n}), got range "
            f"[{cand.min()}, {cand.max()}]")
    _, first = np.unique(cand, return_index=True)
    return cand[np.sort(first)]


_GAIN_TILE_CAP_ELEMS: Optional[int] = None


def _gain_tile_cap_elems(itemsize: int = 4) -> int:
    """Max gain-tile elements, probed ONCE per process and then frozen.

    The result feeds jit *static* arguments (``block_m``), so it must not
    float with live allocator state — a per-call probe would hand every
    dispatch a slightly different block size and force a retrace each time.
    One probe at first use captures the device's capacity class; backends
    without memory stats (CPU) fall back to the 128 MiB heuristic (2^25
    float32 elements).
    """
    global _GAIN_TILE_CAP_ELEMS
    if _GAIN_TILE_CAP_ELEMS is None:
        free = free_memory_bytes()
        if free is not None:
            _GAIN_TILE_CAP_ELEMS = max(
                int(free * GAIN_TILE_MEMORY_FRACTION) // itemsize, 1)
        else:
            _GAIN_TILE_CAP_ELEMS = 1 << 25
    return _GAIN_TILE_CAP_ELEMS


def _device_block_m(n: int, m: int, tiles_per_memory: int = 1) -> int:
    """Candidate block size bounding the (n, Bm) gain tile.

    Autotuned from the same free-memory probe ``plan_chunks`` uses
    (:func:`repro.core.evaluator.free_memory_bytes`), frozen at first use
    (see :func:`_gain_tile_cap_elems`). The floor of 8 (one TPU sublane)
    lets the cap be exceeded only at ground-set sizes where chunking V
    itself is the right tool.

    ``n`` must be the height of the tile that actually materializes — the
    *local shard* height n/p under the sharded plans, never the global n
    (sizing from global n under-fills every shard's memory by p×).
    ``tiles_per_memory`` divides the probed cap when several shards' tiles
    coexist in ONE physical memory space (forced host devices share the
    host allocator: p live tiles would over-commit the probe's free-bytes
    answer p×); real multi-chip meshes keep the default of 1 because each
    shard's tile lives in its own device memory.
    """
    cap_elems = _gain_tile_cap_elems() // max(tiles_per_memory, 1)
    if n * m <= cap_elems:
        return m
    return max(8, min(m, cap_elems // max(n, 1)))


def mesh_tiles_per_memory(mesh) -> int:
    """How many of ``mesh``'s shards carve tiles out of one memory space.

    Forced host devices (``--xla_force_host_platform_device_count``) all
    allocate from the same host RAM the free-memory probe measured, so a
    p-device mesh runs p concurrent gain tiles against one pool;
    accelerator meshes place one tile per device memory.
    """
    devs = list(mesh.devices.flat)
    if devs and devs[0].platform == "cpu":
        return len(devs)
    return 1


# ---------------------------------------------------------------------------
# Scoring core shared by the device and device_sharded plans
# ---------------------------------------------------------------------------


def _score_blocked(V, C, cache, pair, policy, block_m: int,
                   n_total: Optional[int] = None) -> jax.Array:
    """Gains of candidates C against ``cache`` in (n, block_m) tiles.

    Streams candidates in blocks so the distance tile stays memory-bounded;
    ``gains_formula`` is shared with the host path, which keeps the
    per-column reduction (and hence the argmax) identical.
    """
    mc, d = C.shape
    bm = min(block_m, mc)
    m_pad = ((mc + bm - 1) // bm) * bm
    Cp = jnp.pad(C, ((0, m_pad - mc), (0, 0)))
    blocks = Cp.reshape(-1, bm, d)
    gains = jax.lax.map(
        lambda Cb: gains_formula(V, Cb, cache, pair, policy, n_total=n_total),
        blocks,
    ).reshape(-1)
    return gains[:mc]


def _make_fold_and_score(V, pair, policy, backend, rbf_gamma, block_m):
    """Build fold-winner-then-score for the single-device scan step.

    Returns ``fn(cache, w_prev, C) -> (gains, new_cache)``. On Pallas
    backends the fold rides inside the fused gain kernel; on jnp the fold is
    an explicit O(n) minimum followed by blocked scoring.
    """
    use_kernel = backend in ("pallas", "pallas_interpret")
    if use_kernel:
        from repro.kernels import ops as kops

        def fold_and_score(cache, w_prev, C):
            # block_m only sizes the jnp streaming block (HBM working set);
            # the kernel tiles its own VMEM blocks and never materializes
            # the (n, m) matrix, so it keeps its default tile size
            return kops.fused_gain_update(
                V, C, cache, w_prev, policy=policy, rbf_gamma=rbf_gamma,
                interpret=(backend != "pallas"))
    else:

        def fold_and_score(cache, w_prev, C):
            dw = pair(V, w_prev[None, :], policy)[:, 0]
            cache = jnp.minimum(cache, dw.astype(jnp.float32))
            gains = _score_blocked(V, C, cache, pair, policy, block_m)
            return gains, cache

    return fold_and_score


# ---------------------------------------------------------------------------
# Shared round-step builders — the ONE definition of a selection round,
# consumed by both the single-device scan below and the mesh-sharded scan in
# repro.core.distributed (which differs only in its score/fold callbacks).
# ---------------------------------------------------------------------------


def make_rounds_step(take, fold_score_mean, L0):
    """Dense/stochastic scan step over per-round candidate index rows.

    ``fold_score_mean(cache, w_prev, cand_t) -> (gains, new_cache,
    mean_cache)`` folds the previous winner and scores the round's candidate
    indices; how the candidate *payload* materializes is the plan's business
    (single-device: one gather from the resident pool; sharded pool: index
    blocks psum-materialized from their owning shards, never all at once).
    ``take(idx)`` resolves indices to payload rows — for the round winner it
    is the per-round "winner column all-gather" that replaces carrying a
    materialized candidate block.
    """

    def step(carry, cand_t):
        cache, taken, w_prev = carry
        gains, cache, mean_c = fold_score_mean(cache, w_prev, cand_t)
        live = ~taken[cand_t]
        gains = jnp.where(live, gains, -jnp.inf)
        p = jnp.argmax(gains)
        j = cand_t[p]
        # a round whose candidates are all taken has no legitimate argmax:
        # emit the -1 sentinel (the engine boundary raises on it) instead of
        # silently re-selecting whatever index argmax fell through to
        j_out = jnp.where(gains[p] > -jnp.inf, j, -1)
        # cache includes winners 0..t-1 here → this is trajectory[t-1]
        val = L0 - mean_c
        return ((cache, taken.at[j].set(True), take(j)),
                (j_out, val, jnp.sum(live).astype(jnp.int32)))

    return step


def celf_max_iters(n: int, top_b: int) -> int:
    """CELF while-loop backstop shared by both execution plans: ⌈n/B⌉
    iterations re-score every candidate (the loop has then degenerated to a
    full re-score), +1 slack. The sharded plan's per-iteration psums only
    line up across shards because every plan agrees on this bound."""
    return -(-n // top_b) + 1


def make_lazy_step(take, n_pool, fold, score_idx_mean, L0, top_b: int,
                   max_iters: int):
    """CELF scan step: while-loop of top-B re-scoring over stale bounds.

    ``fold(cache, w) -> cache`` folds the previous winner once per round;
    ``score_idx_mean(cache, idx) -> (gains, mean_cache)`` scores candidate
    *indices* (replicated plans gather-and-score in one batch; the sharded
    pool streams blocked takes so the transient block never exceeds the
    resident shard even when top_b > n/p) with one psum carrying both on
    mesh plans; ``take(idx)`` resolves the winner's index to its payload
    row (sharded pool: one psum materializing only that column — the bound
    state itself stays a replicated (n,) scalar array, never an (n, d)
    payload). The loop body always runs ≥ once per round (nothing starts
    fresh), so ``mean_c`` is always the round's true mean cache; it stops
    when the fresh-top invariant — best re-scored gain ≥ every remaining
    stale bound — certifies the winner, degenerating to a full re-score
    after ⌈n/B⌉ iterations.
    """

    def step(carry, _):
        cache, taken, w_prev, ub = carry
        cache = fold(cache, w_prev)

        def invariant_fails(st):
            ub_c, fresh, _, _, it = st
            stale_max = jnp.max(jnp.where(fresh | taken, -jnp.inf, ub_c))
            fresh_best = jnp.max(jnp.where(fresh & ~taken, ub_c, -jnp.inf))
            return (fresh_best < stale_max) & (it < max_iters)

        def rescore_top_b(st):
            ub_c, fresh, scored, _, it = st
            stale = jnp.where(fresh | taken, -jnp.inf, ub_c)
            top_ub, top_idx = jax.lax.top_k(stale, top_b)
            live = top_ub > -jnp.inf
            gains_b, mean_c = score_idx_mean(cache, top_idx)
            gains_b = jnp.where(live, gains_b, -jnp.inf)
            ub_c = ub_c.at[top_idx].set(
                jnp.where(live, gains_b, ub_c[top_idx]))
            fresh = fresh.at[top_idx].set(fresh[top_idx] | live)
            return ub_c, fresh, scored + jnp.sum(live), mean_c, it + 1

        ub, fresh, scored, mean_c, _ = jax.lax.while_loop(
            invariant_fails, rescore_top_b,
            (ub, jnp.zeros((n_pool,), bool), jnp.asarray(0, jnp.int32),
             jnp.asarray(0.0, jnp.float32), jnp.asarray(0, jnp.int32)))
        j = jnp.argmax(jnp.where(fresh & ~taken, ub, -jnp.inf))
        # cache includes winners 0..t-1 here → this is trajectory[t-1]
        val = L0 - mean_c
        return ((cache, taken.at[j].set(True), take(j), ub),
                (j, val, scored))

    return step


# ---------------------------------------------------------------------------
# Shared scan driver — ub0 seeding, n_scored accounting, final fold, and
# trajectory concat were once duplicated between the single-device scan below
# and distributed.make_selection_scan; both now supply only callbacks.
# ---------------------------------------------------------------------------


def drive_selection_scan(*, kind, k, top_b, n_global, pool=None, take=None,
                         n_pool=None, taken0=None, seed_mean=None,
                         score_idx_mean=None, cand_rounds, cache0, w0, L0,
                         fold, score_mean, fold_score_mean, mean_of):
    """Run k selection rounds for any execution plan, given its callbacks.

    The plan supplies only how a candidate batch is scored and how the
    winner folds into the (possibly sharded) cache; everything else — CELF's
    ub0 bound seeding, the dense one-row closure vs the stochastic per-round
    scan xs, ``n_scored`` accounting, the final fold, and the trajectory
    concat — is plan-independent and lives here, once.

    The candidate payload is addressed through ``take(idx) -> rows``: pass a
    resident ``pool`` (single-device / replicated plans; ``take`` defaults
    to ``pool[idx]``) or an explicit ``take`` + ``n_pool`` when no plan-wide
    payload exists (sharded pool: ``take`` psum-materializes the requested
    columns from their owning shards). ``taken0`` optionally pre-marks pool
    rows as taken (GreeDi partitions mask their zero-padding rows this way);
    ``seed_mean`` overrides CELF's ub0 seeding pass and ``score_idx_mean``
    its per-round top-B re-score (sharded pool: blocked take-and-score for
    both, so no transient ever exceeds the resident shard).

    Callbacks (single-device: plain jnp/kernel ops; sharded: the same ops on
    the local shard with ONE psum per scored batch riding the gains):

    * ``fold(cache, w) -> cache`` — fold a winner's distances into the cache
      (used per lazy round and for the final trajectory point).
    * ``score_mean(cache, C) -> (gains, mean_cache)`` — score a candidate
      batch against the already-folded cache (lazy rescore + ub0 seeding).
    * ``fold_score_mean(cache, w_prev, cand_t) -> (gains, cache,
      mean_cache)`` — the fused dense/stochastic round step over the round's
      candidate *indices* (on Pallas backends the fold rides inside the gain
      kernel; sharded pool: blocked take-and-score).
    * ``mean_of(cache) -> scalar`` — global mean of the cache.

    Returns ``(sel, traj, n_scored)`` per-round stacked outputs.
    """
    if take is None:
        take = lambda idx: pool[idx]  # noqa: E731 — the replicated default
        n_pool = pool.shape[0]
    taken_init = taken0 if taken0 is not None \
        else jnp.zeros((n_pool,), bool)
    if kind == "lazy":
        if score_idx_mean is None:
            score_idx_mean = lambda cache, idx: \
                score_mean(cache, take(idx))  # noqa: E731
        step = make_lazy_step(take, n_pool, fold, score_idx_mean, L0, top_b,
                              celf_max_iters(n_global, top_b))
        # round -1: fresh singleton gains seed the bounds (counts one eval
        # per pool row, exactly like host CELF's initial full scoring)
        if seed_mean is not None:
            ub0, _ = seed_mean(cache0)
        else:
            ub0, _ = score_mean(
                cache0, pool if pool is not None
                else take(jnp.arange(n_pool, dtype=jnp.int32)))
        init = (cache0, taken_init, w0, ub0)
        (cache, _, w_last, _), (sel, vals, scored) = jax.lax.scan(
            step, init, None, length=k)
        n_scored = jnp.asarray(n_pool, jnp.int32) + jnp.sum(scored)
    else:
        step = make_rounds_step(take, fold_score_mean, L0)
        init = (cache0, taken_init, w0)
        if kind == "dense":
            # one candidate row closed over by all k rounds
            cand_row = cand_rounds[0]
            (cache, _, w_last), (sel, vals, scored) = jax.lax.scan(
                lambda carry, _: step(carry, cand_row), init, None, length=k)
        else:
            (cache, _, w_last), (sel, vals, scored) = jax.lax.scan(
                step, init, cand_rounds)
        n_scored = jnp.sum(scored)

    # one final fold for the last trajectory point
    final_val = L0 - mean_of(fold(cache, w_last))
    traj = jnp.concatenate([vals[1:], final_val[None]])
    return sel.astype(jnp.int32), traj, n_scored


# ---------------------------------------------------------------------------
# Single-device one-dispatch scan (plans: device)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("kind", "k", "top_b", "distance",
                                   "policy_name", "block_m", "backend",
                                   "rbf_gamma", "counter_key"))
def _select_scan(V, d_e0, cand_rounds, w0, *, kind, k, top_b, distance,
                 policy_name, block_m, backend, rbf_gamma, counter_key):
    """All k selection rounds in one dispatch.

    ``cand_rounds`` holds the candidate indices: (1, m) for dense (ONE row,
    closed over by every round — never materialized k times), (k, m) for
    stochastic (pre-sampled per round), (1, 0) for lazy, which derives its
    candidates from the carried stale bounds. The carry
    is ``(mincache, taken-mask, previous winner[, stale bounds])``; the
    winner is folded into the cache at the *start* of the next round — for
    dense/stochastic on the Pallas backend the fold rides inside the fused
    gain kernel so the winner's distance column never re-materializes in
    HBM; lazy folds once explicitly because its while-loop re-scores
    variable candidate batches against the already-folded cache.

    Per-round ys are ``(selected index, trajectory value, #actually-scored
    candidates)`` — the last is the engine's honest ``evaluations`` unit.
    """
    DEVICE_TRACE_COUNTS[counter_key] += 1
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    d_e0f = d_e0.astype(jnp.float32)
    L0 = jnp.mean(d_e0f)

    def fold(cache, w):
        dw = pair(V, w[None, :], policy)[:, 0]
        return jnp.minimum(cache, dw.astype(jnp.float32))

    score_mean = fold_score_mean = None
    if kind == "lazy":
        use_kernel = backend in ("pallas", "pallas_interpret")
        if use_kernel:
            from repro.kernels import ops as kops

            def score(cache, C):
                return kops.marginal_gain(
                    V, C, cache, policy=policy, rbf_gamma=rbf_gamma,
                    interpret=(backend != "pallas"))
        else:

            def score(cache, C):
                return _score_blocked(V, C, cache, pair, policy, block_m)

        def score_mean(cache, C):
            return score(cache, C), jnp.mean(cache)

    else:
        # no outer candidate padding: _score_blocked (jnp) and the fused
        # kernel (pallas) both pad internally, so the step construction is
        # identical to the device_sharded plan's
        fold_and_score = _make_fold_and_score(
            V, pair, policy, backend, rbf_gamma, block_m)

        def fold_score_mean(cache, w_prev, cand_t):
            gains, cache = fold_and_score(cache, w_prev, V[cand_t])
            return gains, cache, jnp.mean(cache)

    return drive_selection_scan(
        kind=kind, k=k, top_b=top_b, n_global=V.shape[0], pool=V,
        cand_rounds=cand_rounds, cache0=d_e0f, w0=w0.astype(V.dtype), L0=L0,
        fold=fold, score_mean=score_mean, fold_score_mean=fold_score_mean,
        mean_of=jnp.mean)


# ---------------------------------------------------------------------------
# Engine entry point
# ---------------------------------------------------------------------------


def run_selection(
    f: ExemplarClustering,
    *,
    kind: str,                        # "dense" | "stochastic" | "lazy"
    k: int,
    cand_rounds: Optional[np.ndarray] = None,
    top_b: int = 0,
    plan: str = "device",             # "device" | "device_sharded" |
                                      # "device_sharded_pool" | "greedi"
    counter_key: str,
    block_m: Optional[int] = None,
    mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """Run a round-candidate strategy under a device execution plan.

    ``cand_rounds`` carries the per-round candidate indices for the dense
    and stochastic strategies ((k, m), global indices); the lazy strategy
    derives its candidates on device and takes ``top_b`` instead (0 → the
    default re-score width of 256). A stochastic round whose sample row is
    entirely exhausted by earlier selections raises rather than silently
    re-selecting a taken index.

    Plans: ``device`` (one-dispatch scan), ``device_sharded`` (mesh-sharded
    V + cache, candidate payload replicated), ``device_sharded_pool`` (the
    candidate payload row-shards too — O(n/p·d) resident per device; scoring
    blocks and the per-round winner column psum-materialize from their
    owning shards), ``greedi`` (dense strategy only: GreeDi
    partition-then-merge — each shard greedily solves its own partition,
    the p·k partial solutions all-gather, and a merge round over that small
    replicated pool runs under the sharded-cache callbacks; selections are
    *not* identical to host greedy but carry the GreeDi constant-factor
    guarantee).
    """
    if k == 0:
        return OptResult([], 0.0, [], 0)
    n_cand = f.n if kind == "lazy" or cand_rounds is None \
        else len(np.unique(cand_rounds[0] if kind == "dense" else cand_rounds))
    if k > n_cand:
        raise ValueError(
            f"cannot select k={k} exemplars from {n_cand} distinct "
            f"candidates — once every candidate is taken the argmax would "
            f"silently re-select one")
    policy = f.cfg.resolved_policy()
    backend = f.cfg.backend if f.cfg.backend in ("pallas", "pallas_interpret") \
        else "jnp"
    if backend != "jnp" and f.cfg.distance not in dist_mod.MXU_ELIGIBLE:
        raise ValueError(
            f"device plans with a pallas backend support "
            f"{sorted(dist_mod.MXU_ELIGIBLE)}, got {f.cfg.distance!r}")
    rbf_gamma = dist_mod.RBF_GAMMA \
        if (backend != "jnp" and f.cfg.distance == "rbf") else None
    w0 = f.e0 if f.e0 is not None else jnp.zeros((f.dim,), f.V.dtype)

    if kind == "lazy":
        top_b = max(1, min(top_b or 256, f.n))
        cand_rounds = np.zeros((1, 0), np.int32)
        # lazy's widest scoring tile is the bound-seeding pass over all n
        # candidates (per-round tiles are top_b ≤ n)
        m_widest = f.n
    elif cand_rounds is None:
        raise ValueError(f"strategy {kind!r} needs cand_rounds")
    else:
        m_widest = cand_rounds.shape[1]

    if plan == "device":
        bm = block_m if block_m is not None \
            else _device_block_m(f.n, m_widest)
        sel, traj, n_scored = _select_scan(
            f.V, f.d_e0, jnp.asarray(cand_rounds, jnp.int32), w0,
            kind=kind, k=k, top_b=top_b, distance=f.cfg.distance,
            policy_name=policy.name, block_m=bm, backend=backend,
            rbf_gamma=rbf_gamma, counter_key=counter_key)
    elif plan in ("device_sharded", "device_sharded_pool"):
        from repro.core import distributed as dist_engine

        sel, traj, n_scored = dist_engine.run_sharded_selection(
            f, jnp.asarray(cand_rounds, jnp.int32), w0, kind=kind, k=k,
            top_b=top_b, counter_key=counter_key, m_widest=m_widest,
            block_m=block_m, mesh=mesh, data_axes=data_axes,
            backend=backend, rbf_gamma=rbf_gamma,
            pool_plan="sharded" if plan == "device_sharded_pool"
            else "replicated")
    elif plan == "greedi":
        from repro.core import distributed as dist_engine

        if kind != "dense":
            raise ValueError(
                "plan 'greedi' partitions the *dense* greedy strategy; "
                f"strategy {kind!r} has no partition-then-merge form here")
        if cand_rounds.shape[1] != f.n:
            raise ValueError(
                "plan 'greedi' partitions the full ground set; candidate "
                "subsets are not supported (every V row must be eligible "
                "in its own partition)")
        sel, traj, n_scored = dist_engine.run_greedi_selection(
            f, w0, k=k, counter_key=counter_key, block_m=block_m,
            mesh=mesh, data_axes=data_axes, backend=backend,
            rbf_gamma=rbf_gamma)
    else:
        raise ValueError(f"unknown execution plan {plan!r}")

    sel = [int(x) for x in np.asarray(sel)]
    if any(s < 0 for s in sel):
        bad = sel.index(-1)
        raise ValueError(
            f"round {bad} had no untaken candidate (its sample row is "
            f"exhausted by earlier selections) — the argmax would silently "
            f"re-select a taken index")
    traj = [float(x) for x in np.asarray(traj)]
    return OptResult(sel, traj[-1] if traj else 0.0, traj, int(n_scored))
