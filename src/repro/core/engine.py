"""The unified selection-engine layer (tentpole, beyond paper).

Every optimizer in the greedy family is the same machine viewed through two
orthogonal choices:

* a **round-candidate strategy** — which candidates get scored each round:

  - ``dense``       every (validated) candidate, every round; one candidate
                    row broadcast over all k rounds.
  - ``stochastic``  k pre-sampled candidate rows (one per round), drawn up
                    front so host and device paths consume identical
                    randomness.
  - ``lazy`` (CELF) stale upper bounds carried as an (n,) array; each round
                    re-scores the top-B stale candidates (``jax.lax.top_k``
                    inside the scan carry) and falls back to a full re-score
                    when the fresh-top invariant fails.

* an **execution plan** — where the rounds run:

  - ``host``           reference Python loop (one dispatch per round).
  - ``device``         all k rounds inside ONE jitted ``jax.lax.scan``
                       dispatch; gains, argmax and cache update never leave
                       the accelerator.
  - ``device_sharded`` the same scan, row-sharding V *and* the min-distance
                       cache over a device mesh via ``shard_map``. Per round,
                       each shard computes its (m,) gain partials and one
                       ``psum`` of O(m) bytes reduces them; the argmax (and
                       the CELF bound state) stays replicated.

The min-distance cache recurrence (see :mod:`repro.core.optimizers`) is the
shared substrate: a round is one (n × m) distance evaluation plus an O(n)
fold of the winner. On Pallas backends the fold rides inside the fused gain
kernel (:func:`repro.kernels.ops.fused_gain_update`), so the winner's
distance column never materializes in HBM.

CELF on device: submodularity means gains only shrink, so last round's gains
are upper bounds for this round. The scan carries those bounds as an (n,)
array; each round an inner ``jax.lax.while_loop`` re-scores the top-B stale
bounds and stops as soon as the fresh-top invariant certifies the winner —
*best fresh gain ≥ every remaining stale bound* ⇒ the fresh best is the true
argmax. When staleness defeats the shortcut the loop keeps taking the next
top-B batch, degenerating to a full re-score after ⌈n/B⌉ iterations — the
device mirror of the host CELF heap's pop-rescore-repeat, without the
per-batch host↔device round-trips.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contract
from repro.core import distances as dist_mod
from repro.core import functions as fx
from repro.core.evaluator import free_memory_bytes
from repro.core.functions import FnSpec, SubmodularFunction
from repro.core.precision import resolve as resolve_policy


@dataclasses.dataclass
class OptResult:
    indices: list[int]
    value: float
    trajectory: list[float]
    evaluations: int

    def exemplars(self, V) -> np.ndarray:
        return np.asarray(V)[self.indices]


#: Number of times each device engine has been *traced* (not dispatched).
#: A second run with identical shapes/statics must not increment these —
#: that is the "exactly one jitted dispatch for all k rounds" property.
DEVICE_TRACE_COUNTS: collections.Counter = collections.Counter()

#: Fraction of probed free device memory the gain tile may occupy.
GAIN_TILE_MEMORY_FRACTION = 0.25


def validate_candidates(candidates, n: int) -> np.ndarray:
    """Validate a candidate-index subset at the engine boundary.

    Out-of-range indices raise; duplicates are dropped keeping first
    occurrence (a duplicated index would otherwise be scored twice and could
    even be *selected* twice by the device argmax, which masks ``taken`` by
    index, not by position).
    """
    cand = np.asarray(candidates).reshape(-1)
    if not np.issubdtype(cand.dtype, np.integer):
        raise ValueError(
            f"candidate indices must be integers, got dtype {cand.dtype}")
    cand = cand.astype(np.int64)
    if cand.size == 0:
        raise ValueError("candidates must be non-empty")
    if cand.min() < 0 or cand.max() >= n:
        raise ValueError(
            f"candidate indices must lie in [0, {n}), got range "
            f"[{cand.min()}, {cand.max()}]")
    _, first = np.unique(cand, return_index=True)
    return cand[np.sort(first)]


_GAIN_TILE_CAP_ELEMS: Optional[int] = None


def _gain_tile_cap_elems(itemsize: int = 4) -> int:
    """Max gain-tile elements, probed ONCE per process and then frozen.

    The result feeds jit *static* arguments (``block_m``), so it must not
    float with live allocator state — a per-call probe would hand every
    dispatch a slightly different block size and force a retrace each time.
    One probe at first use captures the device's capacity class; backends
    without memory stats (CPU) fall back to the 128 MiB heuristic (2^25
    float32 elements).
    """
    global _GAIN_TILE_CAP_ELEMS
    if _GAIN_TILE_CAP_ELEMS is None:
        free = free_memory_bytes()
        if free is not None:
            _GAIN_TILE_CAP_ELEMS = max(
                int(free * GAIN_TILE_MEMORY_FRACTION) // itemsize, 1)
        else:
            _GAIN_TILE_CAP_ELEMS = 1 << 25
    return _GAIN_TILE_CAP_ELEMS


def _device_block_m(n: int, m: int, tiles_per_memory: int = 1,
                    n_batch: int = 1) -> int:
    """Candidate block size bounding the (n, Bm) gain tile.

    Autotuned from the same free-memory probe ``plan_chunks`` uses
    (:func:`repro.core.evaluator.free_memory_bytes`), frozen at first use
    (see :func:`_gain_tile_cap_elems`). The floor of 8 (one TPU sublane)
    lets the cap be exceeded only at ground-set sizes where chunking V
    itself is the right tool.

    ``n`` must be the height of the tile that actually materializes — the
    *local shard* height n/p under the sharded plans, never the global n
    (sizing from global n under-fills every shard's memory by p×).
    ``tiles_per_memory`` divides the probed cap when several shards' tiles
    coexist in ONE physical memory space (forced host devices share the
    host allocator: p live tiles would over-commit the probe's free-bytes
    answer p×); real multi-chip meshes keep the default of 1 because each
    shard's tile lives in its own device memory.
    ``n_batch`` scales the effective tile height: the batched engine scores
    B requests' (n, Bm) tiles in ONE dispatch, so the live footprint is
    (B·n, Bm) — sizing a B=1024 bucket as if B=1 would over-commit memory
    B× (the same failure mode as sizing a shard tile from global n).
    """
    cap_elems = _gain_tile_cap_elems() // max(tiles_per_memory, 1)
    rows = n * max(n_batch, 1)
    if rows * m <= cap_elems:
        return m
    return max(8, min(m, cap_elems // max(rows, 1)))


def mesh_tiles_per_memory(mesh) -> int:
    """How many of ``mesh``'s shards carve tiles out of one memory space.

    Forced host devices (``--xla_force_host_platform_device_count``) all
    allocate from the same host RAM the free-memory probe measured, so a
    p-device mesh runs p concurrent gain tiles against one pool;
    accelerator meshes place one tile per device memory.
    """
    devs = list(mesh.devices.flat)
    if devs and devs[0].platform == "cpu":
        return len(devs)
    return 1


# ---------------------------------------------------------------------------
# Scoring core shared by the device and device_sharded plans
# ---------------------------------------------------------------------------


def _score_blocked(V, C, sc, pair, policy, block_m: int,
                   n_total: Optional[int] = None, fn: FnSpec = FnSpec(),
                   row_aux=None) -> jax.Array:
    """Gains of candidate payload C against score-cache rows ``sc`` in
    (n, block_m) tiles.

    Streams candidates in blocks so the distance tile stays memory-bounded;
    ``functions.gains_formula_spec`` is shared with the host path, which
    keeps the per-column reduction (and hence the argmax) identical. The
    index-addressed extra term (graph cut's penalty) is NOT included —
    callers that know the candidates' global indices add
    ``functions.gains_index_extra`` outside the payload blocking.
    """
    mc, d = C.shape
    bm = min(block_m, mc)
    m_pad = ((mc + bm - 1) // bm) * bm
    Cp = jnp.pad(C, ((0, m_pad - mc), (0, 0)))
    blocks = Cp.reshape(-1, bm, d)
    gains = jax.lax.map(
        lambda Cb: fx.gains_formula_spec(fn, V, Cb, sc, row_aux, pair,
                                         policy, n_total=n_total),
        blocks,
    ).reshape(-1)
    return gains[:mc]


def _make_score_payload(V, pair, policy, backend, rbf_gamma, block_m,
                        fn: FnSpec, row_aux, n_total=None):
    """Build ``score(sc, C) -> gains`` over candidate payload rows.

    Routes through the shared min/max Pallas kernel template when the
    function has one and the backend asks for kernels; otherwise the blocked
    jnp reduction. Gains exclude the index-addressed extra term.
    """
    tmpl = fx.kernel_template(fn)
    if backend != "jnp" and tmpl is not None:
        from repro.kernels import ops as kops

        def score(sc, C):
            return kops.marginal_gain(
                V, C, sc, policy=policy, rbf_gamma=rbf_gamma,
                fold=tmpl[0], score_affine=tmpl[1], n_total=n_total,
                interpret=(backend != "pallas"))
    else:

        def score(sc, C):
            return _score_blocked(V, C, sc, pair, policy, block_m,
                                  n_total=n_total, fn=fn, row_aux=row_aux)

    return score


def _make_fold_and_score(V, pair, policy, backend, rbf_gamma, block_m,
                         fn: FnSpec = FnSpec(), row_aux=None, n_total=None):
    """Build fold-winner-then-score for a dense/stochastic scan step.

    Returns ``step(vec, w_row, w_ok, C) -> (gains, new_vec)`` over the cache
    *vector*: fold the previous winner's row in (gated by the float ``w_ok``
    — round 0 has no winner, and the max/additive folds are not idempotent),
    then score candidate payload ``C`` against the updated cache. On Pallas
    backends with a fused-eligible function the fold rides inside the fused
    gain kernel; otherwise an explicit O(n) fold precedes (kernel or
    blocked-jnp) scoring. Scalar aux state and index-addressed gain extras
    are the caller's business (they need global winner/candidate indices).
    """
    tmpl = fx.kernel_template(fn)
    if backend != "jnp" and tmpl is not None and fx.kernel_fused_ok(fn):
        from repro.kernels import ops as kops

        def fold_and_score(vec, w_row, w_ok, C):
            # block_m only sizes the jnp streaming block (HBM working set);
            # the kernel tiles its own VMEM blocks and never materializes
            # the (n, m) matrix, so it keeps its default tile size
            return kops.fused_gain_update(
                V, C, vec, w_row, policy=policy, rbf_gamma=rbf_gamma,
                fold=tmpl[0], score_affine=tmpl[1], n_total=n_total,
                w_valid=w_ok, interpret=(backend != "pallas"))
    else:
        score = _make_score_payload(V, pair, policy, backend, rbf_gamma,
                                    block_m, fn, row_aux, n_total=n_total)

        def fold_and_score(vec, w_row, w_ok, C):
            dw = pair(V, w_row[None, :], policy)[:, 0]
            folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
            vec = jnp.where(w_ok > 0, folded, vec)
            gains = score(fx.score_cache_rows(fn, vec, row_aux), C)
            return gains, vec

    return fold_and_score


# ---------------------------------------------------------------------------
# Shared round-step builders — the ONE definition of a selection round,
# consumed by both the single-device scan below and the mesh-sharded scan in
# repro.core.distributed (which differs only in its score/fold callbacks).
# ---------------------------------------------------------------------------


def make_rounds_step(take, fold_score_val):
    """Dense/stochastic scan step over per-round candidate index rows.

    ``fold_score_val(cache, w_prev, cand_t) -> (gains, new_cache, value)``
    folds the previous winner and scores the round's candidate indices; how
    the candidate *payload* materializes is the plan's business
    (single-device: one gather from the resident pool; sharded pool: index
    blocks psum-materialized from their owning shards, never all at once).
    ``take(idx)`` resolves a winner index to its ``(payload row, global
    index)`` carry — the row is the per-round "winner column all-gather"
    that replaces carrying a materialized candidate block; the global index
    feeds the next round's gated fold (and index-addressed aux state). The
    ``cache`` is the function's ``(vec, aux)`` pytree.
    """

    def step(carry, cand_t):
        cache, taken, w_prev = carry
        gains, cache, val = fold_score_val(cache, w_prev, cand_t)
        live = ~taken[cand_t]
        gains = jnp.where(live, gains, -jnp.inf)
        p = jnp.argmax(gains)
        j = cand_t[p]
        # a round whose candidates are all taken has no legitimate argmax:
        # emit the -1 sentinel (the engine boundary raises on it) instead of
        # silently re-selecting whatever index argmax fell through to
        j_out = jnp.where(gains[p] > -jnp.inf, j, -1)
        # cache includes winners 0..t-1 here → val is trajectory[t-1]
        return ((cache, taken.at[j].set(True), take(j)),
                (j_out, val, jnp.sum(live).astype(jnp.int32)))

    return step


def celf_max_iters(n: int, top_b: int) -> int:
    """CELF while-loop backstop shared by both execution plans: ⌈n/B⌉
    iterations re-score every candidate (the loop has then degenerated to a
    full re-score), +1 slack. The sharded plan's per-iteration psums only
    line up across shards because every plan agrees on this bound."""
    return -(-n // top_b) + 1


def make_lazy_step(take, n_pool, fold, score_idx_val, top_b: int,
                   max_iters: int):
    """CELF scan step: while-loop of top-B re-scoring over stale bounds.

    ``fold(cache, w) -> cache`` folds the previous ``(row, index)`` winner
    once per round (gated internally on index ≥ 0);
    ``score_idx_val(cache, idx) -> (gains, value)`` scores candidate
    *indices* (replicated plans gather-and-score in one batch; the sharded
    pool streams blocked takes so the transient block never exceeds the
    resident shard even when top_b > n/p) with one psum carrying both on
    mesh plans; ``take(idx)`` resolves the winner's index to its
    ``(payload row, global index)`` carry (sharded pool: one psum
    materializing only that column — the bound state itself stays a
    replicated (n,) scalar array, never an (n, d) payload). The loop body
    always runs ≥ once per round (nothing starts fresh), so ``val`` is
    always the round's true f(S_t); it stops when the fresh-top invariant —
    best re-scored gain ≥ every remaining stale bound — certifies the
    winner, degenerating to a full re-score after ⌈n/B⌉ iterations.
    """

    def step(carry, _):
        cache, taken, w_prev, ub = carry
        cache = fold(cache, w_prev)

        def invariant_fails(st):
            ub_c, fresh, _, _, it = st
            stale_max = jnp.max(jnp.where(fresh | taken, -jnp.inf, ub_c))
            fresh_best = jnp.max(jnp.where(fresh & ~taken, ub_c, -jnp.inf))
            return (fresh_best < stale_max) & (it < max_iters)

        def rescore_top_b(st):
            ub_c, fresh, scored, _, it = st
            stale = jnp.where(fresh | taken, -jnp.inf, ub_c)
            top_ub, top_idx = jax.lax.top_k(stale, top_b)
            live = top_ub > -jnp.inf
            gains_b, val = score_idx_val(cache, top_idx)
            gains_b = jnp.where(live, gains_b, -jnp.inf)
            ub_c = ub_c.at[top_idx].set(
                jnp.where(live, gains_b, ub_c[top_idx]))
            fresh = fresh.at[top_idx].set(fresh[top_idx] | live)
            return ub_c, fresh, scored + jnp.sum(live), val, it + 1

        ub, fresh, scored, val, _ = jax.lax.while_loop(
            invariant_fails, rescore_top_b,
            (ub, jnp.zeros((n_pool,), bool), jnp.asarray(0, jnp.int32),
             jnp.asarray(0.0, jnp.float32), jnp.asarray(0, jnp.int32)))
        j = jnp.argmax(jnp.where(fresh & ~taken, ub, -jnp.inf))
        # cache includes winners 0..t-1 here → val is trajectory[t-1]
        return ((cache, taken.at[j].set(True), take(j), ub),
                (j, val, scored))

    return step


# ---------------------------------------------------------------------------
# Shared scan driver — ub0 seeding, n_scored accounting, final fold, and
# trajectory concat were once duplicated between the single-device scan below
# and distributed.make_selection_scan; both now supply only callbacks.
# ---------------------------------------------------------------------------


def drive_selection_scan(*, kind, k, top_b, n_global, pool=None, take=None,
                         n_pool=None, taken0=None, seed_val=None,
                         score_idx_val=None, cand_rounds, cache0, w0,
                         fold, fold_score_val=None, value_of=None,
                         with_final_cache=False):
    """Run k selection rounds for any execution plan, given its callbacks.

    The plan supplies only how a candidate batch is scored and how the
    winner folds into the (possibly sharded) cache; everything else — CELF's
    ub0 bound seeding, the dense one-row closure vs the stochastic per-round
    scan xs, ``n_scored`` accounting, the final fold, and the trajectory
    concat — is plan-independent and lives here, once. The cache is the
    function's ``(vec, aux)`` pytree; the winner carry is a ``(payload row,
    global index)`` pair whose index is −1 before round 0 (folds gate on it
    — the max/additive folds of the function zoo are not idempotent).

    The candidate payload is addressed through ``take(idx) -> (row,
    gidx)``: pass a resident ``pool`` (single-device / replicated plans;
    ``take`` defaults to ``(pool[idx], idx)``) or an explicit ``take`` +
    ``n_pool`` when no plan-wide payload exists (sharded pool: ``take``
    psum-materializes the requested columns from their owning shards) or
    when pool-local and global indices differ (GreeDi's merge round).
    ``taken0`` optionally pre-marks pool rows as taken (GreeDi partitions
    mask their zero-padding rows this way); ``seed_val`` overrides CELF's
    ub0 seeding pass (sharded pool: blocked take-and-score, so no transient
    ever exceeds the resident shard).

    Callbacks (single-device: plain jnp/kernel ops; sharded: the same ops on
    the local shard with ONE psum per scored batch riding the gains):

    * ``fold(cache, w) -> cache`` — fold a winner ``(row, gidx)`` into the
      cache (used per lazy round and for the final trajectory point),
      gated internally on gidx ≥ 0.
    * ``score_idx_val(cache, idx) -> (gains, value)`` — score candidate
      indices against the already-folded cache (lazy rescore + ub0 seeding).
    * ``fold_score_val(cache, w_prev, cand_t) -> (gains, cache, value)`` —
      the fused dense/stochastic round step over the round's candidate
      *indices* (on Pallas backends the fold rides inside the gain kernel;
      sharded pool: blocked take-and-score).
    * ``value_of(cache) -> scalar`` — the global f(S) of the cache.

    Returns ``(sel, traj, n_scored)`` per-round stacked outputs;
    ``with_final_cache=True`` appends the fully-folded final cache pytree
    (jitted callers return its vec so a donated seed buffer aliases it).
    """
    if take is None:
        take = lambda idx: (pool[idx], idx)  # noqa: E731 — replicated default
        n_pool = pool.shape[0]
    taken_init = taken0 if taken0 is not None \
        else jnp.zeros((n_pool,), bool)
    if kind == "lazy":
        step = make_lazy_step(take, n_pool, fold, score_idx_val, top_b,
                              celf_max_iters(n_global, top_b))
        # round -1: fresh singleton gains seed the bounds (counts one eval
        # per pool row, exactly like host CELF's initial full scoring)
        if seed_val is not None:
            ub0, _ = seed_val(cache0)
        else:
            ub0, _ = score_idx_val(
                cache0, jnp.arange(n_pool, dtype=jnp.int32))
        init = (cache0, taken_init, w0, ub0)
        (cache, _, w_last, _), (sel, vals, scored) = jax.lax.scan(
            step, init, None, length=k)
        n_scored = jnp.asarray(n_pool, jnp.int32) + jnp.sum(scored)
    else:
        step = make_rounds_step(take, fold_score_val)
        init = (cache0, taken_init, w0)
        if kind == "dense":
            # one candidate row closed over by all k rounds
            cand_row = cand_rounds[0]
            (cache, _, w_last), (sel, vals, scored) = jax.lax.scan(
                lambda carry, _: step(carry, cand_row), init, None, length=k)
        else:
            (cache, _, w_last), (sel, vals, scored) = jax.lax.scan(
                step, init, cand_rounds)
        n_scored = jnp.sum(scored)

    # one final fold for the last trajectory point
    cache_f = fold(cache, w_last)
    final_val = value_of(cache_f)
    traj = jnp.concatenate([vals[1:], final_val[None]])
    if with_final_cache:
        return sel.astype(jnp.int32), traj, n_scored, cache_f
    return sel.astype(jnp.int32), traj, n_scored


# ---------------------------------------------------------------------------
# Batched multi-tenant stepping — B independent requests, ONE dispatch.
#
# Every carry leaf grows a leading B axis ((B, n) caches, (B, n) taken
# masks, (B, d) winner rows, (B, n) CELF bounds); gains/argmax/fold/top_k
# run batched per step. Ragged k rides as a per-request ``k_eff`` vector:
# rounds t ≥ k_eff[b] freeze request b's carry (its transient fold still
# produces the correct trajectory value f(S_{k_eff})), emit the −1 sentinel,
# and count zero evaluations — so bucket-padding slots (k_eff = 0) are
# completely inert. Per-request selections, trajectories, and evaluation
# counts are identical to running the unbatched engine B times.
# ---------------------------------------------------------------------------


def _freeze_where(act, new, old):
    """Per-request carry gate: take ``new`` leaves where the request is
    active, keep ``old`` where it is frozen (``act`` is (B,) bool; every
    leaf carries a leading B axis)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(
            act.reshape(act.shape + (1,) * (a.ndim - 1)), a, b),
        new, old)


def make_batched_rounds_step(take, fold_score_val, k_eff):
    """Batched :func:`make_rounds_step` — dense/stochastic rounds over a
    leading request axis.

    ``fold_score_val(cache, w_prev, cand_t) -> (gains (B, m), cache,
    value (B,))`` folds each request's previous winner and scores its own
    candidate row; ``take(idx (B,)) -> ((B, d) rows, idx)`` resolves the
    per-request winners. ``k_eff`` (B,) int32 is the ragged-k mask: the xs
    carry the round index t, and requests with t ≥ k_eff freeze.
    """
    B = k_eff.shape[0]
    rows = jnp.arange(B)

    def step(carry, xs):
        cand_t, t = xs
        cache, taken, w_prev = carry
        gains, cache2, val = fold_score_val(cache, w_prev, cand_t)
        live = ~jnp.take_along_axis(taken, cand_t, axis=1)
        gains = jnp.where(live, gains, -jnp.inf)
        p = jnp.argmax(gains, axis=1)
        j = jnp.take_along_axis(cand_t, p[:, None], axis=1)[:, 0]
        best = jnp.take_along_axis(gains, p[:, None], axis=1)[:, 0]
        act = t < k_eff
        # exhausted sample row → −1 sentinel, exactly like the unbatched
        # step; frozen rounds also emit −1 (demux truncates them away)
        j_out = jnp.where(act & (best > -jnp.inf), j, -1)
        new_carry = (cache2, taken.at[rows, j].set(True), take(j))
        carry = _freeze_where(act, new_carry, carry)
        scored = jnp.where(act, jnp.sum(live, axis=1).astype(jnp.int32), 0)
        return carry, (j_out, val, scored)

    return step


def make_batched_lazy_step(take, fold, score_idx, value_of, top_b: int,
                           max_iters: int, k_eff):
    """Batched :func:`make_lazy_step` — per-request CELF bound state.

    Each request carries its own (n,) stale bounds, freshness is tracked
    per request, and the while-loop condition is "ANY request still fails
    the fresh-top invariant" — a certified (or frozen) request stops
    scoring immediately (its ``live`` lanes mask out), so per-request
    evaluation counts match the unbatched engine exactly: within a round a
    request is active for consecutive iterations 0..c_b−1 and its c_b is
    the same count the unbatched while-loop would run (the global
    ``max_iters`` backstop cuts every request at the same iteration the
    unbatched loop would, because certification is monotone within a
    round).

    Unlike the unbatched step, the trajectory value is computed directly as
    ``value_of(cache2)`` rather than riding the re-score callback — the
    single-device batched plan has no psum to share, and frozen requests
    (which skip the loop entirely) still need their f(S_{k_eff}) emitted.
    """
    B = k_eff.shape[0]
    rows = jnp.arange(B)[:, None]

    def step(carry, t):
        cache, taken, w_prev, ub = carry
        cache2 = fold(cache, w_prev)
        act = t < k_eff
        val = value_of(cache2)

        def request_active(ub_c, fresh):
            stale_max = jnp.max(
                jnp.where(fresh | taken, -jnp.inf, ub_c), axis=1)
            fresh_best = jnp.max(
                jnp.where(fresh & ~taken, ub_c, -jnp.inf), axis=1)
            return (fresh_best < stale_max) & act

        def invariant_fails(st):
            ub_c, fresh, _, it = st
            return jnp.any(request_active(ub_c, fresh)) & (it < max_iters)

        def rescore_top_b(st):
            ub_c, fresh, scored, it = st
            active = request_active(ub_c, fresh)
            stale = jnp.where(fresh | taken, -jnp.inf, ub_c)
            top_ub, top_idx = jax.lax.top_k(stale, top_b)
            live = (top_ub > -jnp.inf) & active[:, None]
            gains_b = score_idx(cache2, top_idx)
            gains_b = jnp.where(live, gains_b, -jnp.inf)
            prev = jnp.take_along_axis(ub_c, top_idx, axis=1)
            ub_c = ub_c.at[rows, top_idx].set(
                jnp.where(live, gains_b, prev))
            fresh = fresh.at[rows, top_idx].set(
                jnp.take_along_axis(fresh, top_idx, axis=1) | live)
            scored = scored + jnp.sum(live, axis=1).astype(jnp.int32)
            return ub_c, fresh, scored, it + 1

        ub2, fresh, scored, _ = jax.lax.while_loop(
            invariant_fails, rescore_top_b,
            (ub, jnp.zeros(taken.shape, bool),
             jnp.zeros((B,), jnp.int32), jnp.asarray(0, jnp.int32)))
        j = jnp.argmax(jnp.where(fresh & ~taken, ub2, -jnp.inf), axis=1)
        new_carry = (cache2, taken.at[rows[:, 0], j].set(True), take(j), ub2)
        carry = _freeze_where(act, new_carry, carry)
        return carry, (jnp.where(act, j, -1), val,
                       jnp.where(act, scored, 0))

    return step


def make_batched_lazy_step_val(take, fold, score_idx_val, top_b: int,
                               max_iters: int, k_eff):
    """Batched CELF step whose trajectory value RIDES the re-score callback
    — the mesh-sharded form of :func:`make_batched_lazy_step`.

    ``score_idx_val(cache, idx (B, m)) -> ((B, m) gains, (B,) value)`` is
    the sharded plans' one-psum-per-batch callback: every request's gain
    partials and its stat row-sum cross the mesh in the SAME collective, so
    a round body issues exactly one O(B·m) psum per re-score iteration and
    no separate value collective. The value part is computed from the
    (loop-invariant) folded cache, so whichever iteration runs last yields
    the same per-request f(S_t) — including frozen requests, whose
    transient fold still produces their f(S_{k_eff}).

    The one structural difference from the single-device batched step: the
    while loop runs AT LEAST one iteration even when every request is
    frozen (``it == 0`` keeps the condition alive), because the frozen
    requests' trajectory values only exist inside the psum the loop body
    issues. The extra iteration is inert — ``live`` masks every lane, so
    bounds, freshness, and per-request eval counts are untouched — and on
    rounds where any request is active the trip count is identical to the
    single-device batched step's.
    """
    B = k_eff.shape[0]
    rows = jnp.arange(B)[:, None]

    def step(carry, t):
        cache, taken, w_prev, ub = carry
        cache2 = fold(cache, w_prev)
        act = t < k_eff

        def request_active(ub_c, fresh):
            stale_max = jnp.max(
                jnp.where(fresh | taken, -jnp.inf, ub_c), axis=1)
            fresh_best = jnp.max(
                jnp.where(fresh & ~taken, ub_c, -jnp.inf), axis=1)
            return (fresh_best < stale_max) & act

        def invariant_fails(st):
            ub_c, fresh, _, _, it = st
            return (jnp.any(request_active(ub_c, fresh)) | (it == 0)) \
                & (it < max_iters)

        def rescore_top_b(st):
            ub_c, fresh, scored, _, it = st
            active = request_active(ub_c, fresh)
            stale = jnp.where(fresh | taken, -jnp.inf, ub_c)
            top_ub, top_idx = jax.lax.top_k(stale, top_b)
            live = (top_ub > -jnp.inf) & active[:, None]
            gains_b, val = score_idx_val(cache2, top_idx)
            gains_b = jnp.where(live, gains_b, -jnp.inf)
            prev = jnp.take_along_axis(ub_c, top_idx, axis=1)
            ub_c = ub_c.at[rows, top_idx].set(
                jnp.where(live, gains_b, prev))
            fresh = fresh.at[rows, top_idx].set(
                jnp.take_along_axis(fresh, top_idx, axis=1) | live)
            scored = scored + jnp.sum(live, axis=1).astype(jnp.int32)
            return ub_c, fresh, scored, val, it + 1

        ub2, fresh, scored, val, _ = jax.lax.while_loop(
            invariant_fails, rescore_top_b,
            (ub, jnp.zeros(taken.shape, bool), jnp.zeros((B,), jnp.int32),
             jnp.zeros((B,), jnp.float32), jnp.asarray(0, jnp.int32)))
        j = jnp.argmax(jnp.where(fresh & ~taken, ub2, -jnp.inf), axis=1)
        new_carry = (cache2, taken.at[rows[:, 0], j].set(True), take(j), ub2)
        carry = _freeze_where(act, new_carry, carry)
        return carry, (jnp.where(act, j, -1), val,
                       jnp.where(act, scored, 0))

    return step


def drive_selection_scan_batched(*, kind, k, top_b, n_global, pool=None,
                                 k_eff, take=None, n_pool=None,
                                 seed_val=None, cand_rounds, cache0, w0,
                                 fold, score_idx=None, score_idx_val=None,
                                 fold_score_val=None, value_of=None):
    """Batched :func:`drive_selection_scan` — one scan, B requests.

    ``pool`` is the (B, n, d) stacked payload; ``cand_rounds`` is
    (B, k, m) (dense callers broadcast one row; lazy passes (B, 1, 0));
    ``k_eff`` (B,) int32 the per-request effective k (ragged-k masking —
    bucket-padding slots pass 0). The callbacks are the batched analogues
    of the unbatched driver's: ``fold(cache, (rows, idx)) -> cache``,
    ``score_idx(cache, idx (B, m)) -> (B, m) gains``,
    ``fold_score_val(cache, w_prev, cand_t) -> (gains, cache, (B,) value)``,
    ``value_of(cache) -> (B,)``.

    Like the unbatched driver, plans with no resident per-request payload
    pass an explicit ``take(idx (B,)) -> ((B, d) rows, idx)`` + ``n_pool``
    instead of ``pool`` (the batched sharded pool psum-materializes each
    request's columns from their owning shards), and ``seed_val`` overrides
    CELF's ub0 seeding pass. Mesh plans pass ``score_idx_val`` (gains and
    per-request trajectory values riding ONE psum —
    :func:`make_batched_lazy_step_val`) where single-device plans pass
    ``score_idx``/``value_of`` separately.

    Returns ``(sel (k, B), traj (k, B), n_scored (B,), final cache)`` —
    the final cache rides out so the jitted dispatch can alias its vec
    onto the donated seed buffer.
    """
    B = k_eff.shape[0]
    if take is None:
        rows = jnp.arange(B)
        take = lambda idx: (pool[rows, idx], idx)  # noqa: E731
        n_pool = pool.shape[1]
    taken_init = jnp.zeros((B, n_pool), bool)
    ts = jnp.arange(k, dtype=jnp.int32)
    if kind == "lazy":
        if score_idx_val is not None:
            step = make_batched_lazy_step_val(
                take, fold, score_idx_val, top_b,
                celf_max_iters(n_global, top_b), k_eff)
        else:
            step = make_batched_lazy_step(
                take, fold, score_idx, value_of, top_b,
                celf_max_iters(n_global, top_b), k_eff)
        # round -1: per-request singleton gains seed the bounds (counts one
        # eval per pool row for every request that runs ≥ 1 round)
        if seed_val is not None:
            ub0, _ = seed_val(cache0)
        elif score_idx_val is not None:
            ub0, _ = score_idx_val(cache0, jnp.broadcast_to(
                jnp.arange(n_pool, dtype=jnp.int32), (B, n_pool)))
        else:
            ub0 = score_idx(cache0, jnp.broadcast_to(
                jnp.arange(n_pool, dtype=jnp.int32), (B, n_pool)))
        init = (cache0, taken_init, w0, ub0)
        (cache, _, w_last, _), (sel, vals, scored) = jax.lax.scan(
            step, init, ts)
        n_scored = jnp.where(
            k_eff > 0,
            jnp.asarray(n_pool, jnp.int32) + jnp.sum(scored, axis=0), 0)
    else:
        step = make_batched_rounds_step(take, fold_score_val, k_eff)
        init = (cache0, taken_init, w0)
        if kind == "dense":
            cand_row = cand_rounds[:, 0, :]
            (cache, _, w_last), (sel, vals, scored) = jax.lax.scan(
                lambda carry, t: step(carry, (cand_row, t)), init, ts)
        else:
            (cache, _, w_last), (sel, vals, scored) = jax.lax.scan(
                step, init, (jnp.swapaxes(cand_rounds, 0, 1), ts))
        n_scored = jnp.sum(scored, axis=0)

    # one final fold for the last trajectory point (frozen requests fold
    # their held winner transiently — still exactly f(S_{k_eff}))
    cache_f = fold(cache, w_last)
    final_val = value_of(cache_f)
    traj = jnp.concatenate([vals[1:], final_val[None, :]], axis=0)
    return sel.astype(jnp.int32), traj, n_scored.astype(jnp.int32), cache_f


# ---------------------------------------------------------------------------
# Single-device one-dispatch scan (plans: device)
# ---------------------------------------------------------------------------


@contract(
    "engine.select_scan",
    donate=("seed",),
    memory=True,
    claim="all k rounds in ONE dispatch; collective-free; the cache seed "
          "is donated and aliased onto the final cache output; gains stay "
          "in the compute dtype; temp bytes stay at blocked-tile scale")
@partial(jax.jit, static_argnames=("fn", "kind", "k", "top_b", "distance",
                                   "policy_name", "block_m", "backend",
                                   "rbf_gamma", "counter_key"),
         donate_argnums=(1,))
def _select_scan(V, seed, row_aux, cand_rounds, w0, *, fn, kind, k, top_b,
                 distance, policy_name, block_m, backend, rbf_gamma,
                 counter_key):
    """All k selection rounds in one dispatch, for any vec-cache function.

    ``seed`` is DONATED and the final folded cache vector — same (n,)
    float32 shape — rides out as the 4th output, so XLA aliases the carry's
    final buffer onto the seed's allocation: repeated same-signature calls
    (warm-bucket serving) reuse the cache buffer instead of allocating a
    fresh one per dispatch. Callers therefore pass a freshly-built seed
    (:func:`run_selection` copies ``f.cache_seed``, which may alias the
    function's resident ``d_e0``).

    ``fn`` is the function's static :class:`~repro.core.functions.FnSpec`;
    ``seed``/``row_aux`` its cache seed and per-row auxiliary. The identical
    cache-semantics helpers the host protocol methods use are re-traced here
    around the scan, which is what makes host and device selections agree.

    ``cand_rounds`` holds the candidate indices: (1, m) for dense (ONE row,
    closed over by every round — never materialized k times), (k, m) for
    stochastic (pre-sampled per round), (1, 0) for lazy, which derives its
    candidates from the carried stale bounds. The carry is ``((vec, aux)
    cache, taken-mask, previous (row, idx) winner[, stale bounds])``; the
    winner is folded into the cache at the *start* of the next round (gated
    on idx ≥ 0 — round 0 has no winner and the max/additive folds are not
    idempotent) — for dense/stochastic on the Pallas backend with a
    fused-eligible function the fold rides inside the fused gain kernel so
    the winner's distance column never re-materializes in HBM; lazy folds
    once explicitly because its while-loop re-scores variable candidate
    batches against the already-folded cache.

    Per-round ys are ``(selected index, trajectory value, #actually-scored
    candidates)`` — the last is the engine's honest ``evaluations`` unit.
    """
    DEVICE_TRACE_COUNTS[counter_key] += 1
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    n = V.shape[0]
    seedf = seed.astype(jnp.float32)
    v0 = jnp.mean(fx.stat_rows(fn, seedf, row_aux))

    def value_of(cache):
        vec, aux = cache
        return fx.value_from_stat(
            fn, v0, jnp.mean(fx.stat_rows(fn, vec, row_aux)), aux, n)

    def fold(cache, w):
        vec, aux = cache
        row, idx = w
        dw = pair(V, row[None, :], policy)[:, 0]
        folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
        new_aux = fx.fold_aux(fn, vec, aux, idx, 0, n)
        ok = idx >= 0
        return (jnp.where(ok, folded, vec), jnp.where(ok, new_aux, aux))

    score = _make_score_payload(V, pair, policy, backend, rbf_gamma,
                                block_m, fn, row_aux)

    def score_idx(cache, idx):
        vec, _aux = cache
        gains = score(fx.score_cache_rows(fn, vec, row_aux), V[idx])
        extra = fx.gains_index_extra(fn, vec, idx, 0, n, n)
        return gains if extra is None else gains + extra

    def score_idx_val(cache, idx):
        return score_idx(cache, idx), value_of(cache)

    fold_score_val = None
    if kind != "lazy":
        # no outer candidate padding: _score_blocked (jnp) and the fused
        # kernel (pallas) both pad internally, so the step construction is
        # identical to the device_sharded plan's
        if backend != "jnp" and fx.kernel_fused_ok(fn) \
                and fx.kernel_template(fn) is not None:
            fold_and_score = _make_fold_and_score(
                V, pair, policy, backend, rbf_gamma, block_m, fn=fn,
                row_aux=row_aux)

            def fold_score_val(cache, w_prev, cand_t):
                vec, aux = cache
                row, idx = w_prev
                gains, vec2 = fold_and_score(
                    vec, row, (idx >= 0).astype(jnp.float32), V[cand_t])
                cache2 = (vec2, aux)  # fused-eligible functions carry no aux
                return gains, cache2, value_of(cache2)
        else:

            def fold_score_val(cache, w_prev, cand_t):
                cache2 = fold(cache, w_prev)
                return score_idx(cache2, cand_t), cache2, value_of(cache2)

    w0c = (w0.astype(V.dtype), jnp.asarray(-1, jnp.int32))
    sel, traj, n_scored, cache_f = drive_selection_scan(
        kind=kind, k=k, top_b=top_b, n_global=n, pool=V,
        cand_rounds=cand_rounds, cache0=(seedf, jnp.float32(0.0)), w0=w0c,
        fold=fold, score_idx_val=score_idx_val,
        fold_score_val=fold_score_val, value_of=value_of,
        with_final_cache=True)
    return sel, traj, n_scored, cache_f[0]


@contract(
    "engine.select_scan_batched",
    donate=("seed",),
    memory=True,
    claim="all k rounds of B independent requests in ONE dispatch; "
          "collective-free; the stacked (B, n) seed is donated; per-request "
          "temp bytes stay at blocked-tile scale")
@partial(jax.jit, static_argnames=("fn", "kind", "k", "top_b", "distance",
                                   "policy_name", "block_m", "backend",
                                   "rbf_gamma", "counter_key"),
         donate_argnums=(1,))
def _select_scan_batched(V, seed, row_aux, cand_rounds, w0, k_eff, *, fn,
                         kind, k, top_b, distance, policy_name, block_m,
                         backend, rbf_gamma, counter_key):
    """All k rounds of B independent requests in ONE dispatch.

    The batched mirror of :func:`_select_scan`: ``V (B, n, d)``, ``seed /
    row_aux (B, n)``, ``cand_rounds (B, k, m)``, ``w0 (B, d)``, ``k_eff
    (B,)``. The cache-protocol helpers broadcast over the leading axis
    unchanged; the two index-addressed helpers (graph cut's
    ``gains_index_extra`` / ``fold_aux`` gathers) vmap per request. Scoring
    routes through the grid-over-B kernels (:mod:`repro.kernels.ops`
    batched dispatch) on Pallas backends, a vmapped :func:`_score_blocked`
    otherwise. ``seed`` is donated exactly like the unbatched dispatch
    (the final (B, n) cache output aliases it) — callers pass freshly
    stacked buffers.
    """
    DEVICE_TRACE_COUNTS[counter_key] += 1
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    n = V.shape[1]
    seedf = seed.astype(jnp.float32)
    v0 = jnp.mean(fx.stat_rows(fn, seedf, row_aux), axis=1)

    def value_of(cache):
        vec, aux = cache
        return fx.value_from_stat(
            fn, v0, jnp.mean(fx.stat_rows(fn, vec, row_aux), axis=1),
            aux, n)

    def pair_rows(w_rows):
        # per-request distance of each request's V to its own winner row
        return jax.vmap(lambda Vb, r: pair(Vb, r[None, :], policy)[:, 0])(
            V, w_rows)

    def fold(cache, w):
        vec, aux = cache
        row, idx = w
        dw = pair_rows(row)
        folded = fx.fold_vec_rows(fn, vec, dw.astype(jnp.float32))
        new_aux = jax.vmap(
            lambda v, a, g: fx.fold_aux(fn, v, a, g, 0, n))(vec, aux, idx)
        ok = idx >= 0
        return (jnp.where(ok[:, None], folded, vec),
                jnp.where(ok, new_aux, aux))

    tmpl = fx.kernel_template(fn)
    if backend != "jnp" and tmpl is not None:
        from repro.kernels import ops as kops

        def score(sc, C):
            return kops.marginal_gain(
                V, C, sc, policy=policy, rbf_gamma=rbf_gamma,
                fold=tmpl[0], score_affine=tmpl[1],
                interpret=(backend != "pallas"))
    else:

        def score(sc, C):
            return jax.vmap(
                lambda Vb, Cb, scb, rb: _score_blocked(
                    Vb, Cb, scb, pair, policy, block_m, fn=fn, row_aux=rb)
            )(V, C, sc, row_aux)

    def score_idx(cache, idx):
        vec, _aux = cache
        C = jnp.take_along_axis(V, idx[..., None], axis=1)
        gains = score(fx.score_cache_rows(fn, vec, row_aux), C)
        if fx.gains_index_extra(fn, vec[0], idx[0], 0, n, n) is None:
            return gains
        extra = jax.vmap(
            lambda v, ix: fx.gains_index_extra(fn, v, ix, 0, n, n))(vec, idx)
        return gains + extra

    fold_score_val = None
    if kind != "lazy":
        if backend != "jnp" and fx.kernel_fused_ok(fn) and tmpl is not None:
            from repro.kernels import ops as kops

            def fold_score_val(cache, w_prev, cand_t):
                vec, aux = cache
                row, idx = w_prev
                C = jnp.take_along_axis(V, cand_t[..., None], axis=1)
                gains, vec2 = kops.fused_gain_update(
                    V, C, vec, row, policy=policy, rbf_gamma=rbf_gamma,
                    fold=tmpl[0], score_affine=tmpl[1],
                    w_valid=(idx >= 0).astype(jnp.float32),
                    interpret=(backend != "pallas"))
                cache2 = (vec2, aux)  # fused-eligible functions carry no aux
                return gains, cache2, value_of(cache2)
        else:

            def fold_score_val(cache, w_prev, cand_t):
                cache2 = fold(cache, w_prev)
                return score_idx(cache2, cand_t), cache2, value_of(cache2)

    B = V.shape[0]
    w0c = (w0.astype(V.dtype), jnp.full((B,), -1, jnp.int32))
    cache0 = (seedf, jnp.zeros((B,), jnp.float32))
    sel, traj, n_scored, cache_f = drive_selection_scan_batched(
        kind=kind, k=k, top_b=top_b, n_global=n, pool=V, k_eff=k_eff,
        cand_rounds=cand_rounds, cache0=cache0, w0=w0c, fold=fold,
        score_idx=score_idx, fold_score_val=fold_score_val,
        value_of=value_of)
    return sel, traj, n_scored, cache_f[0]


# ---------------------------------------------------------------------------
# Engine entry point
# ---------------------------------------------------------------------------


def run_selection(
    f: SubmodularFunction,
    *,
    kind: str,                        # "dense" | "stochastic" | "lazy"
    k: int,
    cand_rounds: Optional[np.ndarray] = None,
    top_b: int = 0,
    plan: str = "device",             # "device" | "device_sharded" |
                                      # "device_sharded_pool" | "greedi"
    counter_key: str,
    block_m: Optional[int] = None,
    mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """Run a round-candidate strategy under a device execution plan.

    ``cand_rounds`` carries the per-round candidate indices for the dense
    and stochastic strategies ((k, m), global indices); the lazy strategy
    derives its candidates on device and takes ``top_b`` instead (0 → the
    default re-score width of 256). A stochastic round whose sample row is
    entirely exhausted by earlier selections raises rather than silently
    re-selecting a taken index.

    Plans: ``device`` (one-dispatch scan), ``device_sharded`` (mesh-sharded
    V + cache, candidate payload replicated), ``device_sharded_pool`` (the
    candidate payload row-shards too — O(n/p·d) resident per device; scoring
    blocks and the per-round winner column psum-materialize from their
    owning shards), ``greedi`` (dense strategy only: GreeDi
    partition-then-merge — each shard greedily solves its own partition,
    the p·k partial solutions all-gather, and a merge round over that small
    replicated pool runs under the sharded-cache callbacks; selections are
    *not* identical to host greedy but carry the GreeDi constant-factor
    guarantee).
    """
    if k == 0:
        return OptResult([], 0.0, [], 0)
    fn = f.spec
    if fn.name not in fx.DEVICE_PLAN_ELIGIBLE:
        raise ValueError(
            f"function {fn.name!r} has no n-aligned vec cache to shard or "
            f"scan over — it runs on the host execution plans only")
    n_cand = f.n if kind == "lazy" or cand_rounds is None \
        else len(np.unique(cand_rounds[0] if kind == "dense" else cand_rounds))
    if k > n_cand:
        raise ValueError(
            f"cannot select k={k} exemplars from {n_cand} distinct "
            f"candidates — once every candidate is taken the argmax would "
            f"silently re-select one")
    policy = f.cfg.resolved_policy()
    backend = f.cfg.backend if f.cfg.backend in ("pallas", "pallas_interpret") \
        else "jnp"
    if fx.kernel_template(fn) is None:
        # no kernel form (saturated coverage): jnp scoring on any backend
        backend = "jnp"
    if backend != "jnp" and f.cfg.distance not in dist_mod.MXU_ELIGIBLE:
        raise ValueError(
            f"device plans with a pallas backend support "
            f"{sorted(dist_mod.MXU_ELIGIBLE)}, got {f.cfg.distance!r}")
    rbf_gamma = dist_mod.RBF_GAMMA \
        if (backend != "jnp" and f.cfg.distance == "rbf") else None
    w0 = f.e0 if f.e0 is not None else jnp.zeros((f.dim,), f.V.dtype)

    if kind == "lazy":
        top_b = max(1, min(top_b or 256, f.n))
        cand_rounds = np.zeros((1, 0), np.int32)
        # lazy's widest scoring tile is the bound-seeding pass over all n
        # candidates (per-round tiles are top_b ≤ n)
        m_widest = f.n
    elif cand_rounds is None:
        raise ValueError(f"strategy {kind!r} needs cand_rounds")
    else:
        m_widest = cand_rounds.shape[1]

    if plan == "device":
        bm = block_m if block_m is not None \
            else _device_block_m(f.n, m_widest)
        # _select_scan donates the seed: copy it (f.cache_seed may alias
        # the function's resident d_e0, which must survive this call)
        sel, traj, n_scored, _ = _select_scan(
            f.V, jnp.array(f.cache_seed), f.row_aux,
            jnp.asarray(cand_rounds, jnp.int32), w0,
            fn=fn, kind=kind, k=k, top_b=top_b, distance=f.cfg.distance,
            policy_name=policy.name, block_m=bm, backend=backend,
            rbf_gamma=rbf_gamma, counter_key=counter_key)
    elif plan in ("device_sharded", "device_sharded_pool"):
        from repro.core import distributed as dist_engine

        sel, traj, n_scored = dist_engine.run_sharded_selection(
            f, jnp.asarray(cand_rounds, jnp.int32), w0, kind=kind, k=k,
            top_b=top_b, counter_key=counter_key, m_widest=m_widest,
            block_m=block_m, mesh=mesh, data_axes=data_axes,
            backend=backend, rbf_gamma=rbf_gamma,
            pool_plan="sharded" if plan == "device_sharded_pool"
            else "replicated")
    elif plan == "greedi":
        from repro.core import distributed as dist_engine

        if kind != "dense":
            raise ValueError(
                "plan 'greedi' partitions the *dense* greedy strategy; "
                f"strategy {kind!r} has no partition-then-merge form here")
        if cand_rounds.shape[1] != f.n:
            raise ValueError(
                "plan 'greedi' partitions the full ground set; candidate "
                "subsets are not supported (every V row must be eligible "
                "in its own partition)")
        sel, traj, n_scored = dist_engine.run_greedi_selection(
            f, w0, k=k, counter_key=counter_key, block_m=block_m,
            mesh=mesh, data_axes=data_axes, backend=backend,
            rbf_gamma=rbf_gamma)
    else:
        raise ValueError(f"unknown execution plan {plan!r}")

    sel = [int(x) for x in np.asarray(sel)]
    if any(s < 0 for s in sel):
        bad = sel.index(-1)
        raise ValueError(
            f"round {bad} had no untaken candidate (its sample row is "
            f"exhausted by earlier selections) — the argmax would silently "
            f"re-select a taken index")
    traj = [float(x) for x in np.asarray(traj)]
    return OptResult(sel, traj[-1] if traj else 0.0, traj, int(n_scored))


def _stack_batch_payload(fs: Sequence[SubmodularFunction]) -> dict:
    """Host-stack B same-signature requests into one (B, …) device payload.

    Stacks through NumPy, not jnp.stack: an XLA concat over B small device
    arrays costs a dispatch per operand, which at serving batch sizes
    dwarfs the scan itself (~20ms vs ~2ms at B=64 on CPU). np.asarray of a
    committed array is a cheap transfer, np.stack is one memcpy, and the
    single jnp.asarray builds one fresh device buffer — which also keeps
    the seed donation-safe (cache_seed may alias each f's resident d_e0).
    Factored out of :func:`run_selection_batch` so the serving layer can
    stage the NEXT bucket's transfer while the current dispatch runs
    (:func:`stage_selection_batch`).
    """
    f0 = fs[0]
    B = len(fs)
    V_b = jnp.asarray(np.stack([np.asarray(f.V) for f in fs]))
    seed_b = jnp.asarray(
        np.stack([np.asarray(f.cache_seed, np.float32) for f in fs]))
    aux_b = jnp.asarray(np.stack([np.asarray(f.row_aux) for f in fs]))
    if all(f.e0 is None for f in fs):
        w0_b = jnp.zeros((B, f0.dim), f0.V.dtype)
    else:
        w0_b = jnp.asarray(np.stack([
            np.asarray(f.e0 if f.e0 is not None
                       else jnp.zeros((f.dim,), f.V.dtype))
            for f in fs]), f0.V.dtype)
    return {"V": V_b, "seed": seed_b, "aux": aux_b, "w0": w0_b}


def stage_selection_batch(
    fs: Sequence[SubmodularFunction],
    *,
    plan: str = "device",
    mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> Optional[dict]:
    """Pre-stage a bucket's stacked payload ahead of its dispatch.

    Issues the host→device transfers (``jax.device_put`` under the hood —
    async on accelerators) for the payload :func:`run_selection_batch`
    would otherwise build inline, so a serving loop can overlap the NEXT
    bucket's staging with the CURRENT bucket's running dispatch. The
    returned dict is single-use: it contains the fresh donation-safe cache
    seed for exactly one ``run_selection_batch(..., staged=...)`` call.
    """
    if not fs:
        return None
    if plan == "device":
        return _stack_batch_payload(fs)
    if plan in ("device_sharded", "device_sharded_pool"):
        from repro.core import distributed as dist_engine

        return dist_engine.stage_sharded_batch(
            fs, mesh=mesh, data_axes=tuple(data_axes),
            pool_plan="sharded" if plan == "device_sharded_pool"
            else "replicated")
    raise ValueError(f"unknown batched execution plan {plan!r}")


def run_selection_batch(
    fs: Sequence[SubmodularFunction],
    *,
    kind: str,                        # "dense" | "stochastic" | "lazy"
    k: int,
    ks: Optional[Sequence[int]] = None,
    cand_rounds: Optional[np.ndarray] = None,
    top_b: int = 0,
    counter_key: str,
    block_m: Optional[int] = None,
    plan: str = "device",
    mesh=None,
    data_axes: Sequence[str] = ("data",),
    staged: Optional[dict] = None,
) -> list[OptResult]:
    """Solve B independent selection requests in ONE jitted dispatch.

    The batched device-plan entry point: every request in ``fs`` must
    share the jit signature — same function spec, same (n, d), same
    ``EvalConfig`` — which is exactly what the serving layer's bucketing
    guarantees. ``k`` is the shared scan length; ``ks`` optionally gives
    each request its own effective k ≤ k (ragged k via masking: request b
    freezes after ``ks[b]`` rounds and its results are truncated to
    ``ks[b]`` at demux; ``ks[b] = 0`` marks an inert bucket-padding slot).

    ``cand_rounds`` is (B, k, m) per-request candidate indices for the
    dense/stochastic strategies; dense may pass None for the
    full-ground-set default. Per-request selections, trajectories, and
    evaluation counts are identical to B :func:`run_selection` calls —
    only the dispatch is amortized.

    ``plan`` composes the batch axis with the execution plans:
    ``"device"`` (single-device, state (B, n) resident), or
    ``"device_sharded"`` / ``"device_sharded_pool"`` (state laid out
    (B, n/p) per device on ``mesh`` — B per-tenant min-caches row-shard
    with V, each round issues ONE psum of O(B·m) bytes with every
    request's partials stacked into the same collective, and per-request
    results stay bit-identical to each request's unbatched sharded run).
    ``staged`` optionally passes a payload pre-transferred by
    :func:`stage_selection_batch` (same fs, same plan).
    """
    if not fs:
        return []
    f0 = fs[0]
    B = len(fs)
    fn = f0.spec
    for f in fs[1:]:
        if f.spec != fn:
            raise ValueError(
                f"batched requests must share one function spec, got "
                f"{fn} and {f.spec}")
        if f.V.shape != f0.V.shape or f.V.dtype != f0.V.dtype:
            raise ValueError(
                f"batched requests must share one (n, d) payload shape, "
                f"got {f0.V.shape} and {f.V.shape} — bucket by signature "
                f"before dispatching")
        if f.cfg != f0.cfg:
            raise ValueError(
                "batched requests must share one EvalConfig (distance / "
                "policy / backend enter the jit signature)")
    ks = [int(k)] * B if ks is None else [int(x) for x in ks]
    if len(ks) != B:
        raise ValueError(f"ks has {len(ks)} entries for {B} requests")
    if any(kb < 0 or kb > k for kb in ks):
        raise ValueError(f"per-request k must lie in [0, {k}], got {ks}")
    if k == 0 or all(kb == 0 for kb in ks):
        return [OptResult([], 0.0, [], 0) for _ in fs]
    if fn.name not in fx.DEVICE_PLAN_ELIGIBLE:
        raise ValueError(
            f"function {fn.name!r} has no n-aligned vec cache to batch-scan "
            f"over — it runs on the host execution plans only")
    policy = f0.cfg.resolved_policy()
    backend = f0.cfg.backend \
        if f0.cfg.backend in ("pallas", "pallas_interpret") else "jnp"
    if fx.kernel_template(fn) is None:
        backend = "jnp"
    if backend != "jnp" and f0.cfg.distance not in dist_mod.MXU_ELIGIBLE:
        raise ValueError(
            f"device plans with a pallas backend support "
            f"{sorted(dist_mod.MXU_ELIGIBLE)}, got {f0.cfg.distance!r}")
    rbf_gamma = dist_mod.RBF_GAMMA \
        if (backend != "jnp" and f0.cfg.distance == "rbf") else None
    n = f0.n

    if kind == "lazy":
        top_b = max(1, min(top_b or 256, n))
        cand_rounds = np.zeros((B, 1, 0), np.int32)
        m_widest = n
    else:
        if cand_rounds is None:
            if kind != "dense":
                raise ValueError(f"strategy {kind!r} needs cand_rounds")
            cand_rounds = np.broadcast_to(
                np.arange(n, dtype=np.int32)[None, None, :], (B, 1, n))
        cand_rounds = np.asarray(cand_rounds)
        if cand_rounds.ndim != 3 or cand_rounds.shape[0] != B:
            raise ValueError(
                f"batched cand_rounds must be (B, k, m), got "
                f"{cand_rounds.shape} for B={B}")
        if kind == "dense" and cand_rounds.shape[1] != 1:
            cand_rounds = cand_rounds[:, :1]
        for b, kb in enumerate(ks):
            if kb == 0:
                continue
            n_cand = len(np.unique(
                cand_rounds[b, 0] if kind == "dense" else cand_rounds[b]))
            if kb > n_cand:
                raise ValueError(
                    f"request {b}: cannot select k={kb} exemplars from "
                    f"{n_cand} distinct candidates")
        m_widest = cand_rounds.shape[2]

    if plan in ("device_sharded", "device_sharded_pool"):
        from repro.core import distributed as dist_engine

        sel, traj, n_scored = dist_engine.run_sharded_selection_batch(
            fs, jnp.asarray(cand_rounds, jnp.int32), ks, kind=kind, k=k,
            top_b=top_b, counter_key=counter_key, m_widest=m_widest,
            block_m=block_m, mesh=mesh, data_axes=tuple(data_axes),
            backend=backend, rbf_gamma=rbf_gamma,
            pool_plan="sharded" if plan == "device_sharded_pool"
            else "replicated", staged=staged)
    elif plan == "device":
        bm = block_m if block_m is not None \
            else _device_block_m(n, m_widest, n_batch=B)
        payload = staged if staged is not None else _stack_batch_payload(fs)
        sel, traj, n_scored, _ = _select_scan_batched(
            payload["V"], payload["seed"], payload["aux"],
            jnp.asarray(cand_rounds, jnp.int32), payload["w0"],
            jnp.asarray(ks, jnp.int32), fn=fn, kind=kind, k=k, top_b=top_b,
            distance=f0.cfg.distance, policy_name=policy.name, block_m=bm,
            backend=backend, rbf_gamma=rbf_gamma, counter_key=counter_key)
    else:
        raise ValueError(f"unknown batched execution plan {plan!r}")
    sel = np.asarray(sel)            # (k, B)
    traj = np.asarray(traj)          # (k, B)
    n_scored = np.asarray(n_scored)  # (B,)
    out = []
    for b, kb in enumerate(ks):
        sb = [int(x) for x in sel[:kb, b]]
        if any(s < 0 for s in sb):
            bad = sb.index(-1)
            raise ValueError(
                f"request {b}, round {bad} had no untaken candidate (its "
                f"sample row is exhausted by earlier selections)")
        tb = [float(x) for x in traj[:kb, b]]
        out.append(OptResult(sb, tb[-1] if tb else 0.0, tb,
                             int(n_scored[b])))
    return out
