"""The multiset evaluation engine — the paper's core contribution, TPU-native.

Given a ground set ``V`` (n, d) and a packed multiset ``S_multi`` (l, k, d),
computes ``L(S_j ∪ {e0})`` for all j at once by (conceptually) building the
work matrix

    W[j, i] = |V|⁻¹ · min_{s ∈ S_j ∪ {e0}} d(v_i, s)            (paper eq. 7)

and reducing rows. Two modes:

* ``two_pass`` — paper-faithful: materialize ``W`` (in chunks), then reduce.
  Kept because Sieve-family optimizers can reuse ``W`` columns and because it
  is the baseline for §Perf.
* ``fused`` — beyond-paper: the row reduction is fused into the distance
  computation; ``W`` never exists in HBM. HBM traffic drops from O(l·n) to
  O(l) on the output side.

Three backends:

* ``jnp``   — pure jnp (XLA); the oracle and the CPU baseline.
* ``naive`` — paper's Algorithm 2, a per-set loop. The single-thread CPU
  baseline for the speedup benchmarks.
* ``pallas`` / ``pallas_interpret`` — the Pallas TPU kernel (MXU Gram tile +
  fused min/sum epilogue); ``_interpret`` validates on CPU.

Chunking (paper §IV-B-3): ``memory_budget_bytes`` bounds the per-chunk working
set; chunk count follows the paper's formula, and exhaustion raises with the
paper's remediation advice (lower precision / bigger device).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distances as dist_mod
from repro.core.multiset import PackedMultiset
from repro.core.precision import PrecisionPolicy, resolve as resolve_policy


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """Configuration for multiset evaluation."""

    distance: str = "sqeuclidean"
    policy: str | PrecisionPolicy = "fp32"
    mode: str = "fused"  # "fused" | "two_pass"
    backend: str = "jnp"  # "jnp" | "naive" | "pallas" | "pallas_interpret"
    kernel_variant: str = "flat"  # pallas layout: "flat" (k-major) | "loop"
    #: None → no chunking; an int → hard byte budget; "auto" → derive from
    #: the free-memory probe φ (:func:`free_memory_bytes`).
    memory_budget_bytes: Optional[int | str] = None
    n_block: Optional[int] = None  # stream over V in blocks of this many rows

    def __post_init__(self):
        # Fail at construction, not deep inside the first dispatch: an
        # unknown distance used to surface as resolve_pairwise's KeyError
        # mid-trace, long after the config was built.
        if self.distance not in dist_mod.PAIRWISE:
            raise ValueError(
                f"unknown distance {self.distance!r}; registered: "
                f"{sorted(dist_mod.PAIRWISE)}")
        if self.mode not in ("fused", "two_pass"):
            raise ValueError(
                f"mode must be 'fused' or 'two_pass', got {self.mode!r}")
        if self.backend not in ("jnp", "naive", "pallas", "pallas_interpret"):
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of "
                f"'jnp', 'naive', 'pallas', 'pallas_interpret'")
        if self.kernel_variant not in ("flat", "loop"):
            raise ValueError(
                f"kernel_variant must be 'flat' or 'loop', "
                f"got {self.kernel_variant!r}")
        resolve_policy(self.policy)  # raises on unknown policy names
        if isinstance(self.memory_budget_bytes, str) \
                and self.memory_budget_bytes != "auto":
            raise ValueError(
                f"memory_budget_bytes must be an int, None, or 'auto'; "
                f"got {self.memory_budget_bytes!r}")

    def resolved_policy(self) -> PrecisionPolicy:
        return resolve_policy(self.policy)


#: Fraction of probed free memory an "auto" budget hands to the chunk planner
#: (headroom for XLA temporaries and the output buffers).
AUTO_BUDGET_FRACTION = 0.8

#: Resolved "auto" budget, probed ONCE per process and then frozen: chunk
#: boundaries feed traced shapes, so a budget floating with live allocator
#: state would change chunk lengths call-to-call and retrace every call.
_AUTO_BUDGET_BYTES: "Optional[int] | bool" = False  # False = not yet probed


def free_memory_bytes(device=None) -> Optional[int]:
    """The paper's free-memory probe φ (§IV-B-3): free bytes on ``device``.

    Uses the runtime's allocator statistics (``Device.memory_stats``), which
    accelerator backends expose and CPU does not. Returns None when the
    backend has no stats — callers fall back to their static heuristics.

    This probe is shared by the engine's gain-tile autotuner
    (``engine._device_block_m``), whose cap likewise freezes at first use.
    Its two sizing factors compose multiplicatively on top of the probed
    cap: the batched-sharded plans score (B·n_loc)-row slabs per device, so
    the tile is sized from ``n_loc`` rows × ``n_batch=B`` with the cap
    divided ONCE by ``mesh_tiles_per_memory`` (forced host devices share
    this one probed allocator; a real accelerator mesh owns one memory per
    device and divides by 1).
    """
    try:
        dev = device if device is not None else jax.local_devices()[0]
        stats = dev.memory_stats()
    except Exception:  # backend without stats support
        return None
    if not stats or "bytes_limit" not in stats:
        return None
    return max(int(stats["bytes_limit"]) - int(stats.get("bytes_in_use", 0)), 0)


def resolve_memory_budget(budget: Optional[int | str]) -> Optional[int]:
    """Resolve ``memory_budget_bytes``: pass ints/None through, probe "auto".

    The "auto" probe runs once per process and is then frozen (chunk counts
    feed traced shapes — a drifting budget would retrace every call). A
    probe that reports 0 free bytes resolves to a 0 budget (so the chunk
    planner raises :class:`ChunkingError` with the paper's remediation
    advice) rather than silently disabling chunking — only a *probeless*
    backend degrades to unchunked.
    """
    global _AUTO_BUDGET_BYTES
    if budget == "auto":
        if _AUTO_BUDGET_BYTES is False:
            free = free_memory_bytes()
            _AUTO_BUDGET_BYTES = (
                int(free * AUTO_BUDGET_FRACTION) if free is not None else None)
        return _AUTO_BUDGET_BYTES
    if isinstance(budget, str):
        raise ValueError(
            f"memory_budget_bytes must be an int, None, or 'auto'; "
            f"got {budget!r}")
    return budget


class ChunkingError(MemoryError):
    """Raised when not even a single evaluation set fits the memory budget.

    The paper (§IV-B-3): "suggests either the use of lower floating-point
    precision … or better suited hardware with larger memory."
    """


def bytes_per_set(n: int, k_max: int, d: int, policy: PrecisionPolicy, mode: str) -> int:
    """μ_s — device bytes needed per evaluation set (paper §IV-B-3).

    Counts the packed set payload, the Gram/distance block against all of V,
    and (two_pass only) the materialized W row. V itself is excluded — the
    paper pre-loads it at init and accounts it in the free-memory probe φ.
    """
    cs = policy.itemsize
    acc = jnp.dtype(policy.accum_dtype).itemsize  # Gram/W block width
    mu = k_max * d * cs + n * k_max * acc
    if mode == "two_pass":
        mu += n * acc
    return mu


def plan_chunks(
    l: int, n: int, k_max: int, d: int, policy: PrecisionPolicy, mode: str,
    budget_bytes: Optional[int | str],
) -> list[tuple[int, int]]:
    """Split l sets into chunks fitting the budget. Returns [start, stop) pairs.

    ``budget_bytes`` may be "auto", resolved via the free-memory probe φ.
    """
    budget_bytes = resolve_memory_budget(budget_bytes)
    if budget_bytes is None:
        return [(0, l)]
    mu = bytes_per_set(n, k_max, d, policy, mode)
    per_chunk = budget_bytes // mu  # n_chunk-size = ⌊φ μ_s⁻¹⌋
    if per_chunk == 0:
        raise ChunkingError(
            f"memory budget {budget_bytes}B cannot fit a single evaluation set "
            f"(μ_s={mu}B). Use a lower floating-point precision or a larger "
            f"memory budget (paper §IV-B-3)."
        )
    n_chunks = math.ceil(l / per_chunk)  # ⌈l · n_chunk-size⁻¹⌉
    return [(i * per_chunk, min((i + 1) * per_chunk, l)) for i in range(n_chunks)]


# ---------------------------------------------------------------------------
# jnp backend
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("distance", "policy_name"))
def _min_dists_block(
    V: jax.Array,
    data: jax.Array,
    lengths: jax.Array,
    d_e0: jax.Array,
    distance: str,
    policy_name: str,
) -> jax.Array:
    """(n, l) matrix of min_{s∈S_j∪{e0}} d(v_i, s) for one chunk of sets."""
    policy = resolve_policy(policy_name)
    l, k, d = data.shape
    pair = dist_mod.resolve_pairwise(distance)
    D = pair(V, data.reshape(l * k, d), policy)  # (n, l·k)
    D = D.reshape(V.shape[0], l, k)
    mask = jnp.arange(k)[None, :] < lengths[:, None]  # (l, k)
    big = jnp.asarray(jnp.finfo(D.dtype).max, D.dtype)
    D = jnp.where(mask[None, :, :], D, big)
    dmin = jnp.min(D, axis=-1)  # (n, l)
    return jnp.minimum(dmin, d_e0[:, None].astype(D.dtype))


@partial(jax.jit, static_argnames=("distance", "policy_name"))
def _fused_block(V, data, lengths, d_e0, distance, policy_name) -> jax.Array:
    """Fused: per-set L values for one chunk — W rows never materialized."""
    dmin = _min_dists_block(V, data, lengths, d_e0, distance, policy_name)
    n = V.shape[0]
    return jnp.sum(dmin, axis=0) / n  # (l,)


def _eval_jnp(
    V: jax.Array, packed: PackedMultiset, d_e0: jax.Array, cfg: EvalConfig
) -> jax.Array:
    policy = cfg.resolved_policy()
    chunks = plan_chunks(
        packed.num_sets, V.shape[0], packed.k_max, packed.dim, policy,
        cfg.mode, cfg.memory_budget_bytes,
    )
    n = V.shape[0]
    outs = []
    for start, stop in chunks:
        sub = packed.slice_sets(start, stop)
        if cfg.n_block is not None:
            outs.append(
                _eval_jnp_nblocked(V, sub, d_e0, cfg, policy)
            )
        elif cfg.mode == "two_pass":
            W = _min_dists_block(
                V, sub.data, sub.lengths, d_e0, cfg.distance, policy.name
            )  # (n, l_c) — the paper's W (transposed), materialized
            outs.append(jnp.sum(W, axis=0) / n)
        else:
            outs.append(
                _fused_block(V, sub.data, sub.lengths, d_e0, cfg.distance, policy.name)
            )
    return jnp.concatenate(outs) if len(outs) > 1 else outs[0]


def _eval_jnp_nblocked(V, packed, d_e0, cfg, policy) -> jax.Array:
    """Stream over V in blocks (bounds the n×l·k Gram block)."""
    n = V.shape[0]
    nb = cfg.n_block
    n_pad = math.ceil(n / nb) * nb
    Vp = jnp.pad(V, ((0, n_pad - n), (0, 0)))
    # padded rows contribute d_e0 = +inf-min guard: give them d_e0 = 0 and
    # subtract nothing — instead mask by weighting rows.
    d_e0p = jnp.pad(d_e0, (0, n_pad - n))
    valid = (jnp.arange(n_pad) < n).astype(jnp.float32)

    def body(carry, xs):
        vblk, eblk, wblk = xs
        dmin = _min_dists_block(
            vblk, packed.data, packed.lengths, eblk, cfg.distance, policy.name
        )
        return carry + jnp.sum(dmin * wblk[:, None], axis=0), None

    init = jnp.zeros((packed.num_sets,), jnp.float32)
    xs = (
        Vp.reshape(-1, nb, V.shape[1]),
        d_e0p.reshape(-1, nb),
        valid.reshape(-1, nb),
    )
    total, _ = jax.lax.scan(body, init, xs)
    return total / n


# ---------------------------------------------------------------------------
# naive backend — paper Algorithm 2 (single-set CPU loop), the ST baseline
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("distance", "policy_name"))
def _naive_single_set(V, sdata, slen, d_e0, distance, policy_name):
    pair = dist_mod.resolve_pairwise(distance)
    policy = resolve_policy(policy_name)

    def point_loss(v, de):
        # inner loop of Algorithm 2: t = min(t, d(s, v)) over s ∈ S
        dd = pair(v[None, :], sdata, policy)[0]
        dd = jnp.where(jnp.arange(sdata.shape[0]) < slen, dd, jnp.finfo(dd.dtype).max)
        return jnp.minimum(jnp.min(dd), de.astype(dd.dtype))

    sums = jax.lax.map(lambda args: point_loss(*args), (V, d_e0))
    return jnp.sum(sums) / V.shape[0]


def _eval_naive(V, packed, d_e0, cfg) -> jax.Array:
    policy = cfg.resolved_policy()
    vals = []
    for j in range(packed.num_sets):  # the un-parallelized outer loop
        vals.append(
            _naive_single_set(V, packed.data[j], packed.lengths[j], d_e0,
                              cfg.distance, policy.name)
        )
    return jnp.stack(vals)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def e0_distances(
    V: jax.Array,
    e0: Optional[jax.Array],
    distance: str,
    policy: "str | PrecisionPolicy" = "fp32",
) -> jax.Array:
    """d(v_i, e0) for all i. e0 defaults to the all-zero auxiliary vector.

    ``policy`` is the caller's precision policy — half-precision sweeps must
    compute the e0 column with the same policy as the rest of the work matrix.
    """
    if e0 is None:
        e0 = jnp.zeros((V.shape[-1],), V.dtype)
    pair = dist_mod.resolve_pairwise(distance)
    return pair(V, e0[None, :], resolve_policy(policy))[:, 0]


def evaluate_multiset(
    V: jax.Array,
    packed: PackedMultiset,
    cfg: EvalConfig = EvalConfig(),
    d_e0: Optional[jax.Array] = None,
    e0: Optional[jax.Array] = None,
) -> jax.Array:
    """L(S_j ∪ {e0}) for every set in the multiset. Returns (l,) float32."""
    if d_e0 is None:
        d_e0 = e0_distances(V, e0, cfg.distance, cfg.policy)
    if cfg.backend == "jnp":
        out = _eval_jnp(V, packed, d_e0, cfg)
    elif cfg.backend == "naive":
        out = _eval_naive(V, packed, d_e0, cfg)
    elif cfg.backend in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops  # lazy: avoid circular import

        if cfg.distance not in dist_mod.MXU_ELIGIBLE:
            raise ValueError(
                f"pallas backend supports {sorted(dist_mod.MXU_ELIGIBLE)}, "
                f"got {cfg.distance!r}"
            )
        out = kops.exemplar_eval(
            V,
            packed.data,
            packed.lengths,
            d_e0,
            policy=cfg.resolved_policy(),
            mode=cfg.mode,
            variant=cfg.kernel_variant if cfg.mode == "fused" else "loop",
            interpret=(cfg.backend == "pallas_interpret"),
            memory_budget_bytes=cfg.memory_budget_bytes,
            rbf_gamma=dist_mod.RBF_GAMMA if cfg.distance == "rbf" else None,
        )
    else:
        raise ValueError(f"unknown backend {cfg.backend!r}")
    return out.astype(jnp.float32)


def work_matrix(
    V: jax.Array,
    packed: PackedMultiset,
    cfg: EvalConfig = EvalConfig(mode="two_pass"),
    d_e0: Optional[jax.Array] = None,
    e0: Optional[jax.Array] = None,
) -> jax.Array:
    """The paper's W, shape (l, n): W[j,i] = min-dist / n. Materialized.

    Respects ``cfg.memory_budget_bytes`` via the same chunk planner as
    :func:`evaluate_multiset` — without it a large multiset OOMs here while
    the fused path with an identical config would have chunked.
    """
    if d_e0 is None:
        d_e0 = e0_distances(V, e0, cfg.distance, cfg.policy)
    policy = cfg.resolved_policy()
    chunks = plan_chunks(
        packed.num_sets, V.shape[0], packed.k_max, packed.dim, policy,
        "two_pass", cfg.memory_budget_bytes,
    )
    outs = []
    for start, stop in chunks:
        sub = packed.slice_sets(start, stop)
        outs.append(_min_dists_block(
            V, sub.data, sub.lengths, d_e0, cfg.distance, policy.name
        ))  # (n, l_c)
    dmin = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return dmin.T / V.shape[0]
