"""Submodular functions: exemplar-based clustering (paper Def. 5) and friends.

``ExemplarClustering`` is the paper's function

    f(S) = L({e0}) − L(S ∪ {e0})

wrapped around the multiset evaluation engine. It additionally exposes the
*optimizer-aware incremental interface* (min-distance cache) used by Greedy —
see DESIGN.md §2 "one step further".
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_mod
from repro.core.evaluator import EvalConfig, e0_distances, evaluate_multiset
from repro.core.multiset import PackedMultiset, pack_base_plus_candidates, pack_sets
from repro.core.precision import resolve as resolve_policy


def gains_formula(V, cands, mincache, pair, policy, n_total=None):
    """Δ(c_j | S) = |V|⁻¹ Σ_i relu(m_i − d(v_i, c_j)) for all candidates.

    The single source of the gain reduction: the host path (via
    ``_gains_vs_cache``) and the device scan engine both call this, which is
    what makes their argmax selections bit-compatible.

    ``n_total`` overrides the |V| normalizer — pass the *global* ground-set
    size when V is one row-shard of a mesh-sharded ground set, so that the
    per-shard partials ``psum`` to the exact global gains.
    """
    D = pair(V, cands, policy)  # (n, m)
    gains = jnp.sum(jnp.maximum(mincache[:, None] - D, 0.0), axis=0)
    return gains / (V.shape[0] if n_total is None else n_total)


@partial(jax.jit, static_argnames=("distance", "policy_name", "n_total"))
def _gains_vs_cache(V, cands, mincache, distance, policy_name, n_total=None):
    pair = dist_mod.resolve_pairwise(distance)
    return gains_formula(V, cands, mincache, pair, resolve_policy(policy_name),
                         n_total=n_total)


@partial(jax.jit, static_argnames=("distance", "policy"))
def _point_distances_block(V, X, distance, policy):
    # policy rides as the static itself (frozen dataclass → hashable), so a
    # custom PrecisionPolicy object works without a registry entry
    return dist_mod.resolve_pairwise(distance)(V, X, policy).T


@partial(jax.jit, static_argnames=("distance", "policy_name"))
def _update_cache(V, new_point, mincache, distance, policy_name):
    pair = dist_mod.resolve_pairwise(distance)
    D = pair(V, new_point[None, :], resolve_policy(policy_name))[:, 0]
    return jnp.minimum(mincache, D)


class ExemplarClustering:
    """Monotone submodular exemplar-clustering function over a ground set V.

    Args:
      V: (n, d) ground set.
      cfg: evaluation configuration (distance, precision, mode, backend).
      e0: auxiliary vector (paper: the all-zero vector). None → zeros.
    """

    def __init__(self, V: jax.Array, cfg: EvalConfig = EvalConfig(),
                 e0: Optional[jax.Array] = None):
        self.V = jnp.asarray(V)
        self.cfg = cfg
        self.e0 = e0
        # L({e0}) is S-independent; computed "conventionally" once (paper §IV-B-1)
        self.d_e0 = e0_distances(self.V, e0, cfg.distance, cfg.policy)
        self.L0 = float(jnp.mean(self.d_e0.astype(jnp.float32)))

    # -- generic multiset interface (the paper's engine) --------------------

    def loss_multi(self, packed: PackedMultiset) -> jax.Array:
        """L(S_j ∪ {e0}) for all sets — (l,)."""
        return evaluate_multiset(self.V, packed, self.cfg, d_e0=self.d_e0)

    def value_multi(self, packed: PackedMultiset) -> jax.Array:
        """f(S_j) for all sets — (l,)."""
        return self.L0 - self.loss_multi(packed)

    def value(self, S: jax.Array) -> float:
        """f(S) for a single (k, d) set. Empty S → 0 (paper §IV)."""
        S = jnp.asarray(S)
        if S.ndim != 2:
            raise ValueError(f"S must be (k, d), got {S.shape}")
        if S.shape[0] == 0:
            return 0.0
        packed = PackedMultiset(S[None], jnp.array([S.shape[0]], jnp.int32))
        return float(self.value_multi(packed)[0])

    def value_sets(self, sets: Sequence[np.ndarray]) -> jax.Array:
        return self.value_multi(pack_sets(sets, dtype=self.cfg.resolved_policy().compute_dtype))

    def greedy_step_values(self, base: jax.Array, candidates: jax.Array) -> jax.Array:
        """Paper-faithful greedy step: f(S ∪ {c_j}) for all candidates."""
        packed = pack_base_plus_candidates(base, candidates)
        return self.value_multi(packed)

    # -- optimizer-aware incremental interface (beyond paper) ---------------

    def init_mincache(self, sharding=None) -> jax.Array:
        """m_i = d(v_i, e0): the min-dist cache of S = ∅ (e0 always included).

        Stored float32 regardless of policy: the cache seeds n-sized
        reductions, which overflow in f16 for large n even though the
        distances themselves were computed at policy precision.

        ``sharding`` optionally places the cache (a ``jax.sharding.Sharding``,
        typically the same row-sharding as a mesh-sharded V — the cache is
        V-aligned state and must live wherever V's rows live).
        """
        cache = self.d_e0.astype(jnp.float32)
        if sharding is not None:
            cache = jax.device_put(cache, sharding)
        return cache

    def marginal_gains(self, candidates: jax.Array, mincache: jax.Array,
                       use_kernel: bool = False,
                       n_total: Optional[int] = None) -> jax.Array:
        """Δ(c_j | S) for all candidates given S's min-dist cache. O(n·m·d).

        ``n_total`` is the sharding-aware normalizer: when this function
        instance wraps one row-shard of a global ground set, pass the global
        n so the returned per-shard partials ``psum`` to the global gains.
        """
        policy = self.cfg.resolved_policy()
        if use_kernel or self.cfg.backend in ("pallas", "pallas_interpret"):
            if self.cfg.distance not in dist_mod.MXU_ELIGIBLE:
                raise ValueError(
                    f"kernel marginal gains support "
                    f"{sorted(dist_mod.MXU_ELIGIBLE)}, got "
                    f"{self.cfg.distance!r}")
            from repro.kernels import ops as kops

            return kops.marginal_gain(
                self.V, candidates, mincache, policy=policy,
                rbf_gamma=dist_mod.RBF_GAMMA
                if self.cfg.distance == "rbf" else None,
                interpret=(self.cfg.backend != "pallas"),
                n_total=n_total,
            )
        return _gains_vs_cache(self.V, candidates, mincache,
                               self.cfg.distance, policy.name,
                               n_total=n_total)

    def update_mincache(self, mincache: jax.Array, new_point: jax.Array) -> jax.Array:
        return _update_cache(self.V, new_point, mincache,
                             self.cfg.distance, self.cfg.resolved_policy().name)

    def value_from_mincache(self, mincache: jax.Array) -> float:
        return self.L0 - float(jnp.mean(mincache))

    def point_distances(self, x: jax.Array) -> jax.Array:
        """d(v_i, x) for all i — one streaming element against the ground set."""
        pair = dist_mod.resolve_pairwise(self.cfg.distance)
        policy = self.cfg.resolved_policy()
        return pair(self.V, x[None, :], policy)[:, 0]

    def point_distances_block(self, X: jax.Array,
                              policy: "Optional[str | object]" = None
                              ) -> jax.Array:
        """d(v_i, x_b) for a block of B stream elements — (B, n).

        One jitted engine dispatch for the whole block (the batched-streaming
        path); row b matches ``point_distances(X[b])`` up to matmul
        vectorization. ``policy`` overrides the config's precision policy for
        this block (name or :class:`~repro.core.precision.PrecisionPolicy`),
        threaded through as a jit-static so each policy compiles once — the
        streaming engine ingests at the configured precision while the sieve
        state stays float32.
        """
        pol = resolve_policy(policy if policy is not None
                             else self.cfg.resolved_policy())
        return _point_distances_block(self.V, jnp.asarray(X),
                                      self.cfg.distance, policy=pol)

    # -- metadata ------------------------------------------------------------

    @property
    def n(self) -> int:
        return self.V.shape[0]

    @property
    def dim(self) -> int:
        return self.V.shape[1]
