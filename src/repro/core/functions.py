"""Submodular functions behind ONE cache-semantics protocol (paper Def. 5 +).

``ExemplarClustering`` is the paper's function

    f(S) = L({e0}) − L(S ∪ {e0})

wrapped around the multiset evaluation engine, plus the *optimizer-aware
incremental interface* (min-distance cache) used by Greedy — see DESIGN.md §2
"one step further".

The paper's evaluation trick — keep an n-sized per-element cache on device
and score candidates as a fold over it — is not specific to exemplar
clustering. This module factors it into a **cache-semantics protocol** every
execution plan (host loop, one-dispatch device scan, the three mesh-sharded
plans, the streaming sieve table) consumes generically:

* ``init_cache() -> (vec, aux)`` — the empty-set cache: a per-element (n,)
  float32 vector plus one scalar of winner-dependent state (graph cut's
  pairwise penalty; 0 elsewhere).
* ``gains_from_cache(cache, idx) -> (m,)`` — marginal gains of candidate
  *indices* against the cache.
* ``fold_winner(cache, j) -> cache`` — fold one accepted winner in.
* ``value_from_cache(cache) -> float`` — f(S) from the cache alone.

Plus the streaming hooks (``point_distances_block`` and the sieve-row gain /
fold forms) and, for the device plans, the trace-level dispatch helpers
below: each function is identified by a hashable :class:`FnSpec` that rides
the jit statics, and a family of ``spec``-dispatched module functions
(``gains_rows`` / ``fold_vec_rows`` / ``stat_rows`` / ``value_from_stat`` /
…) give every plan the same arithmetic to trace.

Registered objectives (``FUNCTIONS``) and their cache semantics:

========================  ==========================  =======================
objective                 cache vec semantics          candidate gain
========================  ==========================  =======================
``exemplar``              min-distance m_i (seeded    n⁻¹ Σ relu(m_i − d_ic)
                          d(v_i, e0)); fold = min
``facility_location``     max-similarity c_i (seeded  n⁻¹ Σ relu(s_ic − c_i)
                          0); fold = max — the exact
                          dual of the min cache
``graph_cut``             coverage Σ_{j∈S} s_ij;      n⁻¹ Σ s_ic −
                          fold = add; aux carries     (λ/n)(2·c_c + s_cc)
                          the pairwise penalty
``saturated_coverage``    coverage, capped at         n⁻¹ Σ [min(c_i+s_ic,
                          cap_i = sat·Σ_j s_ij;       cap_i) − min(c_i,
                          fold = add                  cap_i)]
``feature_based``         per-feature mass Σ|v_s|     d⁻¹ Σ_t [√(acc_t+F_ct)
                          (a (d,) cache — host         − √acc_t]
                          plans only)
========================  ==========================  =======================

Similarity functions use ONE transform of the configured distance,
``s(x, y) = relu(SIM_ALPHA + SIM_BETA · d(x, y))`` — for the ``rbf``
distance (d = 2 − 2·exp(−γ‖x−y‖²) ∈ [0, 2]) this is exactly exp(−γ‖x−y‖²),
and for ``sqeuclidean`` a hinge similarity with s(x, x) = 1. Because the
transform is affine-then-relu, the Pallas gain kernels evaluate it *in-tile*
from the distance they already computed (see the shared min/max kernel
template in :mod:`repro.kernels.marginal_gain`), and every gain normalizes
by an explicit global ``n_total`` so per-shard tiles remain exact psum
partials under the sharded plans.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_mod
from repro.core.evaluator import EvalConfig, e0_distances, evaluate_multiset
from repro.core.multiset import PackedMultiset, pack_base_plus_candidates, pack_sets
from repro.core.precision import resolve as resolve_policy

#: Similarity transform s = relu(SIM_ALPHA + SIM_BETA · d): the ONE affine
#: the kernels evaluate in-tile. With d the rbf distance this is exactly the
#: rbf kernel value; with sqeuclidean it is a hinge similarity of range 1.
SIM_ALPHA = 1.0
SIM_BETA = -0.5
#: s(x, x) — the affine at d = 0 (every registered distance has d(x,x)=0).
SIM_SELF = 1.0

#: Functions the device execution plans (device / device_sharded /
#: device_sharded_pool / greedi) can run: an (n,)-vec cache folded by
#: winner distances. ``feature_based`` keeps a (d,)-shaped cache and is
#: host-plans-only by construction.
DEVICE_PLAN_ELIGIBLE = frozenset(
    {"exemplar", "facility_location", "graph_cut", "saturated_coverage"})

#: Functions the streaming sieve table supports: threshold sieves need
#: monotone gains from the (S_max, n) row caches alone (graph cut's gain
#: needs the winner-indexed penalty, which a stream element doesn't have).
SIEVE_ELIGIBLE = frozenset(
    {"exemplar", "facility_location", "saturated_coverage"})


class FnSpec(NamedTuple):
    """Hashable (→ jit-static) identity of a submodular objective.

    Rides the static arguments of every device-plan trace and the sharded
    scan cache keys, so each registered function compiles its own executable
    while sharing one engine construction. ``lam`` (graph cut) and ``sat``
    (saturated coverage) are the only per-function parameters that reach
    traced arithmetic.
    """

    name: str = "exemplar"
    lam: float = 0.0
    sat: float = 0.0


# ---------------------------------------------------------------------------
# Trace-level semantics, dispatched on the (static) FnSpec. These are the
# ONE definition of each objective's arithmetic: the host protocol methods,
# the single-device scan, the three sharded plans, and the sieve table all
# call the same functions, which is what makes their selections agree.
# ---------------------------------------------------------------------------


def similarity(D):
    """s = relu(SIM_ALPHA + SIM_BETA · d) applied elementwise."""
    return jnp.maximum(SIM_ALPHA + SIM_BETA * D, 0.0)


def kernel_template(spec: FnSpec):
    """The (fold, affine) parameterization of the shared Pallas gain-kernel
    template, or None when the function has no kernel form (saturated
    coverage's capped-min gain is not an affine-relu of the distance;
    feature_based never touches distances).

    ``fold="min"`` scores ``relu(cache − d)`` (the exemplar min-cache);
    ``fold="max"`` scores ``relu((α + β·d) − cache)`` — the max-cache dual,
    exact because the cache is ≥ 0 so the inner relu of the similarity is
    redundant inside the outer one.
    """
    if spec.name == "exemplar":
        return ("min", None)
    if spec.name in ("facility_location", "graph_cut"):
        return ("max", (SIM_ALPHA, SIM_BETA))
    return None


def kernel_fused_ok(spec: FnSpec) -> bool:
    """Whether the fused fold-and-score kernel applies: the fold must be the
    min/max of the template (graph cut *scores* through the max template —
    against its static row_aux — but folds by addition, outside)."""
    return spec.name in ("exemplar", "facility_location")


def pad_seed(spec: FnSpec) -> float:
    """Cache-seed value for zero-padding rows under the sharded plans.

    Exemplar pads 0 (relu(0 − d) = 0 — pads never gain). The max-cache
    functions pad +inf: a zero V row is a *real-looking* point whose
    similarity to candidates is positive, so only an infinite cache entry
    (relu(s − inf) = 0) makes pad rows inert. Additive caches pad 0 and
    rely on ``pad_row_aux`` to zero their gain/stat contributions.
    """
    return float("inf") if spec.name == "facility_location" else 0.0


def pad_row_aux(spec: FnSpec) -> float:
    """Row-auxiliary value for padding rows: the dead-row sentinel.

    facility_location / graph_cut mark pads +inf (masks their stat rows;
    graph cut additionally *scores* against row_aux, so +inf zeroes pad
    gains); saturated_coverage pads cap = 0 (a zero cap self-masks both
    gains and stat).
    """
    if spec.name in ("facility_location", "graph_cut"):
        return float("inf")
    return 0.0


def score_cache_rows(spec: FnSpec, vec, row_aux):
    """The per-row baseline the gain formula subtracts against — what the
    kernel template receives as its ``cache`` operand. Graph cut's heavy
    term Σ_i s_ic is S-independent, so it scores against the *static*
    row_aux (0 on real rows, +inf on pads) and the live cache only enters
    through the winner-indexed penalty (:func:`gains_index_extra`)."""
    if spec.name == "graph_cut":
        return row_aux
    return vec


def gains_rows(spec: FnSpec, sc, D, row_aux):
    """(n, m) per-row gain contributions (pre-normalizer) of candidates with
    distance columns ``D`` against score-cache rows ``sc``."""
    if spec.name == "exemplar":
        return jnp.maximum(sc[:, None] - D, 0.0)
    if spec.name in ("facility_location", "graph_cut"):
        # relu((α + β·d) − cache): cache ≥ 0 ⇒ identical to
        # relu(relu(α + β·d) − cache), with one relu fewer in-tile
        return jnp.maximum((SIM_ALPHA + SIM_BETA * D) - sc[:, None], 0.0)
    if spec.name == "saturated_coverage":
        s = similarity(D)
        cap = row_aux[:, None]
        return jnp.minimum(sc[:, None] + s, cap) - jnp.minimum(sc[:, None], cap)
    raise ValueError(f"no row-gain form for function {spec.name!r}")


def gains_formula_spec(spec: FnSpec, V, cands, sc, row_aux, pair, policy,
                       n_total=None):
    """Candidate gains (m,) — the generic form of :func:`gains_formula`.

    ``n_total`` overrides the |V| normalizer — pass the *global* ground-set
    size when V is one row-shard of a mesh-sharded ground set, so that the
    per-shard partials ``psum`` to the exact global gains. Graph cut's
    winner-indexed penalty is NOT included here (it needs candidate
    *indices*, not payload) — callers add :func:`gains_index_extra`.
    """
    D = pair(V, cands, policy)  # (n, m)
    rows = gains_rows(spec, sc, D, row_aux)
    return jnp.sum(rows, axis=0) / (V.shape[0] if n_total is None else n_total)


def gains_index_extra(spec: FnSpec, vec, gidx, off, n_loc, n_total):
    """Per-candidate additive gain term that reads the candidate's OWN cache
    entry (graph cut's redundancy penalty −(λ/n)(2·cov_S(c) + s_cc)); None
    for every other function.

    Shard-safe by construction: each cache row is a *complete* value on its
    owning shard (the fold adds every winner's full similarity column), so
    the owner contributes the one real term and every other shard 0 — the
    term rides the existing per-batch gains psum with no extra collective.
    """
    if spec.name != "graph_cut":
        return None
    rel = gidx - off
    own = (rel >= 0) & (rel < n_loc)
    vc = vec[jnp.clip(rel, 0, n_loc - 1)]
    return jnp.where(
        own, -(spec.lam / n_total) * (2.0 * vc + SIM_SELF), 0.0
    ).astype(jnp.float32)


def fold_vec_rows(spec: FnSpec, vec, dw):
    """Fold one winner's float32 distance column ``dw`` into the cache rows.
    Broadcasts over leading axes (the sieve table folds (S_max, n) against
    a (n,) element row)."""
    if spec.name == "exemplar":
        return jnp.minimum(vec, dw)
    if spec.name == "facility_location":
        return jnp.maximum(vec, similarity(dw))
    if spec.name in ("graph_cut", "saturated_coverage"):
        return vec + similarity(dw)
    raise ValueError(f"no vec fold for function {spec.name!r}")


def fold_aux(spec: FnSpec, vec, aux, gidx, off, n_loc, psum=None):
    """Advance the scalar aux state for winner index ``gidx`` (computed from
    the cache BEFORE the winner's column folds in). Graph cut accumulates
    its pairwise penalty P ← P + 2·cov_S(w) + s_ww via an owner-shard gather
    (``psum`` reduces it on mesh plans; pass None on single-device). Every
    other function returns ``aux`` unchanged — and issues no collective.
    """
    if spec.name != "graph_cut":
        return aux
    rel = gidx - off
    own = (rel >= 0) & (rel < n_loc)
    vw = jnp.where(own, vec[jnp.clip(rel, 0, n_loc - 1)], 0.0)
    if psum is not None:
        vw = psum(vw)
    return aux + 2.0 * vw + SIM_SELF


def stat_rows(spec: FnSpec, vec, row_aux):
    """The per-row statistic whose global mean enters the trajectory value.

    Masks dead (padding) rows through ``row_aux`` — the max-cache functions
    carry +inf sentinels there, saturated coverage a 0 cap — so zero-padded
    shards sum exactly. Broadcasts over leading axes (sieve tables).
    """
    if spec.name == "exemplar":
        return vec
    if spec.name in ("facility_location", "graph_cut"):
        return jnp.where(jnp.isinf(row_aux), 0.0, vec)
    if spec.name == "saturated_coverage":
        return jnp.minimum(vec, row_aux)
    raise ValueError(f"no stat form for function {spec.name!r}")


def value_from_stat(spec: FnSpec, v0, mean_stat, aux=0.0, n_total=1):
    """f(S) from the global stat mean: exemplar's L0 − mean(cache), the
    coverage functions' mean directly, graph cut's mean minus the aux
    penalty. ``v0`` is the empty-set baseline (mean of the REAL seed rows:
    L0 for exemplar, 0 elsewhere)."""
    if spec.name == "exemplar":
        return v0 - mean_stat
    if spec.name == "graph_cut":
        return mean_stat - spec.lam * aux / n_total
    return mean_stat


def sieve_gain_rows(spec: FnSpec, caches, dvec, row_aux):
    """(rows, n) per-element gain contributions of one stream element
    (distance row ``dvec``) against each cache row — the jnp form of the
    sieve kernel template."""
    if spec.name == "exemplar":
        return jnp.maximum(caches - dvec[None, :], 0.0)
    if spec.name == "facility_location":
        return jnp.maximum(
            (SIM_ALPHA + SIM_BETA * dvec)[None, :] - caches, 0.0)
    if spec.name == "saturated_coverage":
        s = similarity(dvec)[None, :]
        cap = row_aux[None, :]
        return jnp.minimum(caches + s, cap) - jnp.minimum(caches, cap)
    raise ValueError(f"function {spec.name!r} has no sieve-row gain form")


def sieve_fold_rows(spec: FnSpec, caches, dvec, accept):
    """Fold one element into the rows where ``accept`` holds."""
    folded = fold_vec_rows(spec, caches, dvec[None, :])
    return jnp.where(accept[:, None], folded, caches)


# ---------------------------------------------------------------------------
# Legacy exemplar-only reduction (kept: the standalone distributed
# evaluators and external callers consume it under this name)
# ---------------------------------------------------------------------------


def gains_formula(V, cands, mincache, pair, policy, n_total=None):
    """Δ(c_j | S) = |V|⁻¹ Σ_i relu(m_i − d(v_i, c_j)) for all candidates.

    The exemplar instance of :func:`gains_formula_spec`, kept under its
    original name for the standalone distributed evaluators.

    ``n_total`` overrides the |V| normalizer — pass the *global* ground-set
    size when V is one row-shard of a mesh-sharded ground set, so that the
    per-shard partials ``psum`` to the exact global gains.
    """
    D = pair(V, cands, policy)  # (n, m)
    gains = jnp.sum(jnp.maximum(mincache[:, None] - D, 0.0), axis=0)
    return gains / (V.shape[0] if n_total is None else n_total)


@partial(jax.jit, static_argnames=("distance", "policy_name", "n_total"))
def _gains_vs_cache(V, cands, mincache, distance, policy_name, n_total=None):
    pair = dist_mod.resolve_pairwise(distance)
    return gains_formula(V, cands, mincache, pair, resolve_policy(policy_name),
                         n_total=n_total)


@partial(jax.jit, static_argnames=("distance", "policy"))
def _point_distances_block(V, X, distance, policy):
    # policy rides as the static itself (frozen dataclass → hashable), so a
    # custom PrecisionPolicy object works without a registry entry
    return dist_mod.resolve_pairwise(distance)(V, X, policy).T


@partial(jax.jit, static_argnames=("distance", "policy_name"))
def _update_cache(V, new_point, mincache, distance, policy_name):
    pair = dist_mod.resolve_pairwise(distance)
    D = pair(V, new_point[None, :], resolve_policy(policy_name))[:, 0]
    return jnp.minimum(mincache, D)


# ---------------------------------------------------------------------------
# Protocol jit helpers (shared by every vec-cache function's host methods)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("fn", "distance", "policy_name"))
def _protocol_gains_jit(V, vec, row_aux, idx, *, fn, distance, policy_name):
    pair = dist_mod.resolve_pairwise(distance)
    policy = resolve_policy(policy_name)
    n = V.shape[0]
    sc = score_cache_rows(fn, vec, row_aux)
    g = gains_formula_spec(fn, V, V[idx], sc, row_aux, pair, policy, n_total=n)
    extra = gains_index_extra(fn, vec, idx, 0, n, n)
    return g if extra is None else g + extra


@partial(jax.jit, static_argnames=("fn",))
def _protocol_extra_jit(vec, idx, *, fn, n_total):
    return gains_index_extra(fn, vec, idx, 0, vec.shape[0], n_total)


@partial(jax.jit, static_argnames=("fn", "distance", "policy_name"))
def _protocol_fold_jit(V, vec, aux, j, *, fn, distance, policy_name):
    pair = dist_mod.resolve_pairwise(distance)
    policy = resolve_policy(policy_name)
    dw = pair(V, V[j][None, :], policy)[:, 0].astype(jnp.float32)
    new_aux = fold_aux(fn, vec, aux, j, 0, V.shape[0])
    return fold_vec_rows(fn, vec, dw), new_aux


@partial(jax.jit, static_argnames=("fn",))
def _protocol_value_jit(vec, aux, row_aux, v0, *, fn, n_total):
    return value_from_stat(fn, v0, jnp.mean(stat_rows(fn, vec, row_aux)),
                           aux, n_total)


@partial(jax.jit, static_argnames=("distance", "policy_name", "block"))
def _saturation_caps(V, sat, *, distance, policy_name, block):
    """cap_i = sat · Σ_j s(d(v_i, v_j)) in (n, block) column tiles (the
    saturated-coverage ceiling — one O(n²·d) pass at construction)."""
    pair = dist_mod.resolve_pairwise(distance)
    policy = resolve_policy(policy_name)
    n = V.shape[0]
    nb = -(-n // block)
    Vp = jnp.pad(V, ((0, nb * block - n), (0, 0)))
    valid = (jnp.arange(nb * block) < n).reshape(nb, block)

    def col(args):
        Cb, vb = args
        s = similarity(pair(V, Cb, policy))
        s = jnp.where(vb[None, :], s, 0.0)
        return jnp.sum(s.astype(jnp.float32), axis=1)

    caps = jnp.sum(jax.lax.map(col, (Vp.reshape(nb, block, -1), valid)),
                   axis=0)
    return sat * caps


# ---------------------------------------------------------------------------
# The function classes
# ---------------------------------------------------------------------------


class SubmodularFunction:
    """Base of the function zoo: the cache-semantics protocol over (V, cfg).

    Subclasses set ``spec`` (their :class:`FnSpec` identity) and, where the
    defaults don't apply, override ``cache_seed`` / ``row_aux`` / ``v0``.
    The four protocol methods below are the host execution plan; the device
    plans re-derive the identical arithmetic from ``spec`` at trace time.
    """

    spec: FnSpec = FnSpec()

    def __init__(self, V: jax.Array, cfg: EvalConfig = EvalConfig(),
                 e0: Optional[jax.Array] = None):
        self.V = jnp.asarray(V)
        self.cfg = cfg
        self.e0 = e0
        self._row_aux: Optional[jax.Array] = None
        self._cache_seed: Optional[jax.Array] = None

    # -- per-function state -------------------------------------------------

    @property
    def cache_seed(self) -> jax.Array:
        """(n,) float32 empty-set cache vector (0 for coverage caches).

        Memoized: repeated access (the serving layer stacks B seeds per
        dispatch) must not pay a device op each time. Callers that donate
        must copy — the returned buffer is shared."""
        if self._cache_seed is None:
            self._cache_seed = jnp.zeros((self.n,), jnp.float32)
        return self._cache_seed

    @property
    def row_aux(self) -> jax.Array:
        """(n,) float32 static per-row auxiliary (caps / score baseline)."""
        if self._row_aux is None:
            self._row_aux = jnp.zeros((self.n,), jnp.float32)
        return self._row_aux

    @property
    def v0(self) -> float:
        """Empty-set baseline f-value term (mean of the real seed rows)."""
        return 0.0

    # -- the cache-semantics protocol ---------------------------------------

    def init_cache(self, sharding=None):
        """The empty-set cache ``(vec, aux)``.

        Stored float32 regardless of policy: the cache seeds n-sized
        reductions, which overflow in f16 for large n even though the
        distances themselves were computed at policy precision.

        ``sharding`` optionally places the vec (a ``jax.sharding.Sharding``,
        typically the same row-sharding as a mesh-sharded V — the cache is
        V-aligned state and must live wherever V's rows live); the scalar
        aux is replicated state.
        """
        vec = self.cache_seed
        if sharding is not None:
            vec = jax.device_put(vec, sharding)
        return (vec, jnp.float32(0.0))

    def gains_from_cache(self, cache, idx) -> jax.Array:
        """Δ(c | S) for candidate *indices* ``idx`` against the cache.

        Kernel backends route through the shared min/max Pallas gain-kernel
        template when the function has one (see :func:`kernel_template`);
        functions without a kernel form fall back to the jnp reduction.
        """
        vec, _aux = cache
        idx = jnp.asarray(idx, jnp.int32)
        policy = self.cfg.resolved_policy()
        tmpl = kernel_template(self.spec)
        if self.cfg.backend in ("pallas", "pallas_interpret") \
                and tmpl is not None:
            if self.cfg.distance not in dist_mod.MXU_ELIGIBLE:
                raise ValueError(
                    f"kernel marginal gains support "
                    f"{sorted(dist_mod.MXU_ELIGIBLE)}, got "
                    f"{self.cfg.distance!r}")
            from repro.kernels import ops as kops

            g = kops.marginal_gain(
                self.V, self.V[idx],
                score_cache_rows(self.spec, vec, self.row_aux),
                policy=policy, fold=tmpl[0], score_affine=tmpl[1],
                rbf_gamma=dist_mod.RBF_GAMMA
                if self.cfg.distance == "rbf" else None,
                interpret=(self.cfg.backend != "pallas"))
            if self.spec.name == "graph_cut":
                g = g + _protocol_extra_jit(vec, idx, fn=self.spec,
                                            n_total=self.n)
            return g
        return _protocol_gains_jit(
            self.V, vec, self.row_aux, idx, fn=self.spec,
            distance=self.cfg.distance, policy_name=policy.name)

    def fold_winner(self, cache, j):
        """cache after folding winner index ``j`` in."""
        vec, aux = cache
        return _protocol_fold_jit(
            self.V, vec, aux, jnp.asarray(j, jnp.int32), fn=self.spec,
            distance=self.cfg.distance,
            policy_name=self.cfg.resolved_policy().name)

    def value_from_cache(self, cache) -> float:
        vec, aux = cache
        return float(_protocol_value_jit(
            vec, aux, self.row_aux, jnp.float32(self.v0), fn=self.spec,
            n_total=self.n))

    # -- streaming hooks ----------------------------------------------------

    def point_distances(self, x: jax.Array) -> jax.Array:
        """d(v_i, x) for all i — one streaming element against the ground set."""
        pair = dist_mod.resolve_pairwise(self.cfg.distance)
        policy = self.cfg.resolved_policy()
        return pair(self.V, x[None, :], policy)[:, 0]

    def point_distances_block(self, X: jax.Array,
                              policy: "Optional[str | object]" = None
                              ) -> jax.Array:
        """d(v_i, x_b) for a block of B stream elements — (B, n).

        One jitted engine dispatch for the whole block (the batched-streaming
        path); row b matches ``point_distances(X[b])`` up to matmul
        vectorization. ``policy`` overrides the config's precision policy for
        this block (name or :class:`~repro.core.precision.PrecisionPolicy`),
        threaded through as a jit-static so each policy compiles once — the
        streaming engine ingests at the configured precision while the sieve
        state stays float32.
        """
        pol = resolve_policy(policy if policy is not None
                             else self.cfg.resolved_policy())
        return _point_distances_block(self.V, jnp.asarray(X),
                                      self.cfg.distance, policy=pol)

    # -- metadata ------------------------------------------------------------

    @property
    def n(self) -> int:
        return self.V.shape[0]

    @property
    def dim(self) -> int:
        return self.V.shape[1]


class ExemplarClustering(SubmodularFunction):
    """Monotone submodular exemplar-clustering function over a ground set V.

    Args:
      V: (n, d) ground set.
      cfg: evaluation configuration (distance, precision, mode, backend).
      e0: auxiliary vector (paper: the all-zero vector). None → zeros.
    """

    spec = FnSpec(name="exemplar")

    def __init__(self, V: jax.Array, cfg: EvalConfig = EvalConfig(),
                 e0: Optional[jax.Array] = None):
        super().__init__(V, cfg, e0)
        # L({e0}) is S-independent; computed "conventionally" once (paper §IV-B-1)
        self.d_e0 = e0_distances(self.V, e0, cfg.distance, cfg.policy)
        self.L0 = float(jnp.mean(self.d_e0.astype(jnp.float32)))

    @property
    def cache_seed(self) -> jax.Array:
        if self._cache_seed is None:
            self._cache_seed = self.d_e0.astype(jnp.float32)
        return self._cache_seed

    @property
    def v0(self) -> float:
        return self.L0

    # -- generic multiset interface (the paper's engine) --------------------

    def loss_multi(self, packed: PackedMultiset) -> jax.Array:
        """L(S_j ∪ {e0}) for all sets — (l,)."""
        return evaluate_multiset(self.V, packed, self.cfg, d_e0=self.d_e0)

    def value_multi(self, packed: PackedMultiset) -> jax.Array:
        """f(S_j) for all sets — (l,)."""
        return self.L0 - self.loss_multi(packed)

    def value(self, S: jax.Array) -> float:
        """f(S) for a single (k, d) set. Empty S → 0 (paper §IV)."""
        S = jnp.asarray(S)
        if S.ndim != 2:
            raise ValueError(f"S must be (k, d), got {S.shape}")
        if S.shape[0] == 0:
            return 0.0
        packed = PackedMultiset(S[None], jnp.array([S.shape[0]], jnp.int32))
        return float(self.value_multi(packed)[0])

    def value_sets(self, sets: Sequence[np.ndarray]) -> jax.Array:
        return self.value_multi(pack_sets(sets, dtype=self.cfg.resolved_policy().compute_dtype))

    def greedy_step_values(self, base: jax.Array, candidates: jax.Array) -> jax.Array:
        """Paper-faithful greedy step: f(S ∪ {c_j}) for all candidates."""
        packed = pack_base_plus_candidates(base, candidates)
        return self.value_multi(packed)

    # -- optimizer-aware incremental interface (beyond paper) ---------------

    def init_mincache(self, sharding=None) -> jax.Array:
        """m_i = d(v_i, e0): the min-dist cache of S = ∅ (e0 always included).

        The bare-(n,) exemplar form of :meth:`init_cache`, kept for callers
        of the original min-cache interface (same float32/sharding rules).
        """
        cache = self.d_e0.astype(jnp.float32)
        if sharding is not None:
            cache = jax.device_put(cache, sharding)
        return cache

    def marginal_gains(self, candidates: jax.Array, mincache: jax.Array,
                       use_kernel: bool = False,
                       n_total: Optional[int] = None) -> jax.Array:
        """Δ(c_j | S) for all candidates given S's min-dist cache. O(n·m·d).

        ``n_total`` is the sharding-aware normalizer: when this function
        instance wraps one row-shard of a global ground set, pass the global
        n so the returned per-shard partials ``psum`` to the global gains.
        """
        policy = self.cfg.resolved_policy()
        if use_kernel or self.cfg.backend in ("pallas", "pallas_interpret"):
            if self.cfg.distance not in dist_mod.MXU_ELIGIBLE:
                raise ValueError(
                    f"kernel marginal gains support "
                    f"{sorted(dist_mod.MXU_ELIGIBLE)}, got "
                    f"{self.cfg.distance!r}")
            from repro.kernels import ops as kops

            return kops.marginal_gain(
                self.V, candidates, mincache, policy=policy,
                rbf_gamma=dist_mod.RBF_GAMMA
                if self.cfg.distance == "rbf" else None,
                interpret=(self.cfg.backend != "pallas"),
                n_total=n_total,
            )
        return _gains_vs_cache(self.V, candidates, mincache,
                               self.cfg.distance, policy.name,
                               n_total=n_total)

    def update_mincache(self, mincache: jax.Array, new_point: jax.Array) -> jax.Array:
        return _update_cache(self.V, new_point, mincache,
                             self.cfg.distance, self.cfg.resolved_policy().name)

    def value_from_mincache(self, mincache: jax.Array) -> float:
        return self.L0 - float(jnp.mean(mincache))


class FacilityLocation(SubmodularFunction):
    """Facility location f(S) = n⁻¹ Σ_i max_{s∈S} s(v_i, s) — the exact
    max-cache dual of the exemplar min cache: seed 0, fold = maximum, gains
    relu(s_ic − c_i). Monotone submodular; scores through the shared Pallas
    kernel template with ``fold="max"``."""

    spec = FnSpec(name="facility_location")


class GraphCut(SubmodularFunction):
    """Graph cut f(S) = n⁻¹ Σ_i Σ_{j∈S} s_ij − (λ/n) Σ_{j,j'∈S} s_jj'.

    The cache vec carries per-element coverage Σ_{j∈S} s_ij (additive fold);
    the scalar aux carries the pairwise penalty. ``lam`` must lie in
    (0, 0.5]: with s ≥ 0 and s(x,x) = 1, λ ≤ 0.5 keeps every marginal gain
    non-negative (monotone), which the greedy family's guarantees assume.
    """

    def __init__(self, V: jax.Array, cfg: EvalConfig = EvalConfig(),
                 e0: Optional[jax.Array] = None, lam: float = 0.5):
        if not 0.0 < lam <= 0.5:
            raise ValueError(
                f"graph_cut lam must lie in (0, 0.5] (monotonicity holds "
                f"for λ ≤ 0.5 with s(x,x)=1), got {lam}")
        self.spec = FnSpec(name="graph_cut", lam=float(lam))
        super().__init__(V, cfg, e0)


class SaturatedCoverage(SubmodularFunction):
    """Saturated coverage f(S) = n⁻¹ Σ_i min(Σ_{j∈S} s_ij, cap_i) with
    cap_i = sat · Σ_j s_ij. Monotone submodular; its capped-min gain is not
    an affine-relu of the distance, so it scores through the jnp reduction
    on every backend (the documented non-kernel member of the zoo)."""

    def __init__(self, V: jax.Array, cfg: EvalConfig = EvalConfig(),
                 e0: Optional[jax.Array] = None, sat: float = 0.25):
        if not 0.0 < sat <= 1.0:
            raise ValueError(
                f"saturated_coverage sat must lie in (0, 1], got {sat}")
        self.spec = FnSpec(name="saturated_coverage", sat=float(sat))
        super().__init__(V, cfg, e0)

    @property
    def row_aux(self) -> jax.Array:
        if self._row_aux is None:
            self._row_aux = _saturation_caps(
                self.V, jnp.float32(self.spec.sat),
                distance=self.cfg.distance,
                policy_name=self.cfg.resolved_policy().name,
                block=min(1024, max(8, self.n)))
        return self._row_aux


@partial(jax.jit, static_argnames=())
def _feature_gains_jit(F, acc, idx):
    root = jnp.sqrt(acc)[None, :]
    return jnp.mean(jnp.sqrt(acc[None, :] + F[idx]) - root, axis=1)


class FeatureBased(SubmodularFunction):
    """Feature-based f(S) = d⁻¹ Σ_t √(Σ_{s∈S} |v_s|_t): a concave-over-
    modular function whose cache is the (d,)-shaped per-feature mass — NOT
    an n-sized per-element cache, so it runs on the host plans only (the
    device plans raise; there is nothing to shard along n)."""

    spec = FnSpec(name="feature_based")

    def __init__(self, V: jax.Array, cfg: EvalConfig = EvalConfig(),
                 e0: Optional[jax.Array] = None):
        super().__init__(V, cfg, e0)
        self.F = jnp.abs(self.V).astype(jnp.float32)

    def init_cache(self, sharding=None):
        acc = jnp.zeros((self.dim,), jnp.float32)
        if sharding is not None:
            acc = jax.device_put(acc, sharding)
        return (acc, jnp.float32(0.0))

    def gains_from_cache(self, cache, idx) -> jax.Array:
        acc, _ = cache
        return _feature_gains_jit(self.F, acc, jnp.asarray(idx, jnp.int32))

    def fold_winner(self, cache, j):
        acc, aux = cache
        return (acc + self.F[jnp.asarray(j, jnp.int32)], aux)

    def value_from_cache(self, cache) -> float:
        acc, _ = cache
        return float(jnp.mean(jnp.sqrt(acc)))


#: The registered function zoo: name → constructor ``F(V, cfg=..., e0=...)``
#: (per-function parameters default sensibly; construct directly to set
#: ``lam`` / ``sat``).
FUNCTIONS = {
    "exemplar": ExemplarClustering,
    "facility_location": FacilityLocation,
    "graph_cut": GraphCut,
    "saturated_coverage": SaturatedCoverage,
    "feature_based": FeatureBased,
}
