"""Packed representation of a *multiset of evaluation sets* (paper §IV-B-2).

The paper packs ``S_multi = {S_1 … S_l}`` into one dense device buffer so that
(a) the host→device copy is a single large transaction and (b) on-device access
is coalesced. Sets of unequal size leave blank fields ("not absolutely
space-efficient", §IV-B-2) — the same trade-off here becomes zero-padding plus
a validity mask.

TPU adaptation (DESIGN.md §2/§6): the CUDA code interleaves vectors round-robin
so that *warp* lanes touching ``s_j[k]`` hit one memory segment. On TPU the
consumer is a matmul over a ``(l·k_max, d)`` operand, so the optimal layout is
the dense row-major ``(l, k_max, d)`` block itself — interleaving would destroy
the contraction layout. The padding-fraction accounting (``pad_fraction``)
matches the paper's blank-field accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PackedMultiset:
    """Dense ``(l, k_max, d)`` payload + per-set lengths.

    Attributes:
      data: ``(l, k_max, d)`` array; rows past ``lengths[j]`` are padding.
      lengths: ``(l,)`` int32 number of valid vectors per set.
    """

    data: jax.Array
    lengths: jax.Array

    @property
    def num_sets(self) -> int:
        return self.data.shape[0]

    @property
    def k_max(self) -> int:
        return self.data.shape[1]

    @property
    def dim(self) -> int:
        return self.data.shape[2]

    def mask(self) -> jax.Array:
        """(l, k_max) bool — True where a slot holds a real vector."""
        return jnp.arange(self.k_max)[None, :] < self.lengths[:, None]

    def pad_fraction(self) -> float:
        """Fraction of allocated slots that are blank (paper's unused fields)."""
        total = self.num_sets * self.k_max
        used = int(np.asarray(jax.device_get(jnp.sum(self.lengths))))
        return 1.0 - used / max(total, 1)

    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize + self.lengths.size * 4

    def slice_sets(self, start: int, stop: int) -> "PackedMultiset":
        """Chunking support: a view of sets [start, stop) (paper §IV-B-3)."""
        return PackedMultiset(self.data[start:stop], self.lengths[start:stop])


def pack_sets(sets: Sequence[np.ndarray], dtype=jnp.float32) -> PackedMultiset:
    """Pack a list of ``(k_j, d)`` arrays into a PackedMultiset."""
    if not sets:
        raise ValueError("cannot pack an empty multiset")
    dims = {s.shape[-1] for s in sets}
    if len(dims) != 1:
        raise ValueError(f"inconsistent dims across sets: {dims}")
    d = dims.pop()
    lengths = np.array([s.shape[0] for s in sets], dtype=np.int32)
    k_max = int(lengths.max())
    l = len(sets)
    buf = np.zeros((l, k_max, d), dtype=np.float32)
    for j, s in enumerate(sets):
        buf[j, : s.shape[0]] = np.asarray(s, dtype=np.float32)
    return PackedMultiset(jnp.asarray(buf, dtype=dtype), jnp.asarray(lengths))


def pack_base_plus_candidates(
    base: jax.Array, candidates: jax.Array, base_len: int | None = None
) -> PackedMultiset:
    """Greedy-step multiset: ``S_j = S ∪ {c_j}`` without an l× copy of S.

    Returns a PackedMultiset with ``data[j] = concat(S, c_j)``. The base is
    broadcast (XLA materializes it lazily under jit), matching the paper's
    observation that Greedy's equal-size sets make the dense layout free of
    blank fields.

    Args:
      base: ``(k, d)`` current set (k may be 0).
      candidates: ``(m, d)``.
      base_len: valid prefix length of ``base`` if it is itself padded.
    """
    m = candidates.shape[0]
    k = base.shape[0]
    blen = k if base_len is None else base_len
    tiled = jnp.broadcast_to(base[None], (m, k, base.shape[-1]))
    data = jnp.concatenate([tiled, candidates[:, None, :]], axis=1)
    lengths = jnp.full((m,), blen + 1, dtype=jnp.int32)
    # Move each candidate into the first padding slot when base is padded:
    # slot order is irrelevant to min-reduction, so leaving the candidate at
    # position k with mask length blen+1 would be wrong only if blen < k.
    if base_len is not None and base_len < k:
        # place candidate at index blen instead of k
        data = data.at[:, blen, :].set(candidates)
        data = data[:, : blen + 1, :]
    return PackedMultiset(data, lengths)
