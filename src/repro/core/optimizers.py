"""Submodular maximizers built on the multiset evaluation engine.

Every optimizer here evaluates *many* sets per step — the paper's central
observation ("optimizer-aware", §IV-A). Three evaluation styles are used:

* **multiset** — the paper-faithful path: each step packs
  ``{S ∪ {c_1}, …, S ∪ {c_m}}`` and calls the work-matrix engine. O(n·k·l).
* **mincache** — the beyond-paper incremental path: gains against the
  min-distance cache. O(n·l·d) per step (k drops out).
* **device** — the mincache recurrence hoisted entirely on device: a
  ``jax.lax.scan`` runs all k greedy rounds inside ONE jitted dispatch, with
  candidate gains, argmax selection, and the cache update never leaving the
  accelerator (no per-round host↔device copies, no per-round dispatch).

The greedy family (greedy / stochastic_greedy / lazy_greedy) is built on the
**selection engine** (:mod:`repro.core.engine`): a round-candidate strategy
(dense / stochastic / lazy-CELF) composed with an execution plan (``host``
reference loop, ``device`` one-dispatch scan, ``device_sharded`` mesh-sharded
scan with one O(m) psum per round — see :mod:`repro.core.distributed`).

The min-distance cache obeys the recurrence

    m_i^(0)   = d(v_i, e0)
    m_i^(t+1) = min(m_i^(t), d(v_i, s_{t+1}))          (s_{t+1} = round-t winner)
    Δ(c | S_t) = |V|⁻¹ Σ_i max(m_i^(t) − d(v_i, c), 0)
    f(S_t)     = L({e0}) − |V|⁻¹ Σ_i m_i^(t)

so each greedy round is one (n × m) distance evaluation plus an O(n) fold of
the winner — the device engine evaluates the fold *inside* the next round's
gain kernel (see ``kernels/marginal_gain.gain_update_eval``), which means the
winner's distance column never materializes in HBM.

Optimizer modes:
  greedy               ``mode="mincache"`` (host reference, alias ``"host"``),
                       ``mode="multiset"`` (paper-faithful), ``mode="device"``,
                       ``mode="device_sharded"`` (mesh-sharded V + cache).
  stochastic_greedy    ``mode="host"`` reference loop, ``mode="device"`` or
                       ``mode="device_sharded"``; all consume the same
                       precomputed per-round candidate sample matrix, so
                       selections agree (exactly on the jnp backend; on
                       pallas backends the in-kernel winner fold can differ
                       in the last ulp from the host's jnp update, which may
                       flip a near-tie argmax at reduced precision).
  lazy_greedy          CELF lazy evaluation with stale upper bounds:
                       ``mode="host"`` reference loop (the exact host-side
                       mirror of the engine's top-B rescore policy),
                       ``mode="device"`` (re-scoring against carried stale
                       bounds inside the one-dispatch scan) or
                       ``mode="device_sharded"``.
  sieve_streaming      Badanidiyuru et al. (1/2 − ε), streaming;
                       ``mode="host"`` / ``mode="device"``.
  sieve_streaming_pp   Kazemi et al., LB-pruned sieves (1/2 − ε), less
                       memory; ``mode="host"`` / ``mode="device"``.
  three_sieves         Buschjäger et al., single adaptive sieve ((1−ε)(1−1/e)
                       w.h.p.), minimal memory; host-only.
  salsa                Norouzi-Fard et al. dense-threshold ensemble
                       (simplified: fixed dense schedules, no OPT oracle);
                       ``mode="host"`` / ``mode="device"``.

The streaming family runs on the **sieve engine**
(:mod:`repro.core.streaming`): a fixed-capacity table of threshold sieves
keyed by integer exponent, living on device. ``mode="host"`` steps the table
one jitted dispatch per element (the exact array-semantics mirror);
``mode="device"`` consumes each stream block of ``block_size`` elements with
ONE jitted ``lax.scan`` over elements — singleton gain, grid rebuild, accept
rule, cache min-update, and member bookkeeping all in the scan body. Both
plans make bit-identical decisions, so selections AND evaluation counts agree
across modes.

All return an :class:`OptResult` (indices into V, value, trajectory, and the
number of *evaluations*). For the greedy family ``evaluations`` counts
**actually-scored candidates**: candidates whose gain entered a round's
argmax (already-selected candidates are masked out before the argmax and do
not count). Host and device plans count identically, so the numbers are
directly comparable across modes — and, for stochastic greedy, comparable
with the pool-sampling formulation despite the +k per-round overdraw.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.engine import (DEVICE_TRACE_COUNTS, OptResult,
                               run_selection, validate_candidates)
from repro.core.functions import ExemplarClustering, SubmodularFunction


def _require_exemplar(f: SubmodularFunction, what: str) -> ExemplarClustering:
    """Paths that consume exemplar-only structure (the packed multiset
    engine, the L0/d_e0 streaming shortcuts) guard here; everything else in
    this module speaks the generic cache-semantics protocol."""
    if f.spec.name != "exemplar":
        raise ValueError(
            f"{what} is exemplar-only (it evaluates through the packed "
            f"multiset / L0 interface); function {f.spec.name!r} runs on "
            f"the cache-protocol paths instead")
    return f


# ---------------------------------------------------------------------------
# Greedy family — strategies over the selection engine
# ---------------------------------------------------------------------------


def greedy(
    f: SubmodularFunction,
    k: int,
    mode: str = "mincache",
    candidates: Optional[np.ndarray] = None,
    block_m: Optional[int] = None,
    mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """Algorithm 1 of the paper. ``mode`` picks the evaluation style:

    ``"mincache"`` (alias ``"host"``) — host loop over rounds, device gains.
    ``"multiset"`` — paper-faithful: pack {S ∪ {c}} ∀c and call the engine.
    ``"device"``  — all k rounds in one jitted ``lax.scan`` dispatch.
    ``"device_sharded"`` — the same scan with V and the min-distance cache
    row-sharded over a device ``mesh`` (defaults to all local devices on a
    1-D "data" mesh); one O(m) psum per round; candidate payload replicated.
    ``"device_sharded_pool"`` — additionally row-shards the candidate
    payload (O(n/p·d) resident per device; candidate blocks and the round
    winner psum-materialize from their owning shards). Selections are
    identical to every other exact plan.
    ``"greedi"`` — GreeDi partition-then-merge (Mirzasoleiman et al.): each
    shard greedily solves its own V-partition, the p·k partial solutions
    all-gather, and a merge greedy over them yields the answer. O(n/p·d)
    per device and the cheapest collective footprint, but selections carry
    the GreeDi constant-factor guarantee instead of matching centralized
    greedy; requires the full candidate pool (no ``candidates`` subset) and
    ≥ k rows per partition.
    """
    n = f.n
    cand_idx = np.arange(n) if candidates is None \
        else validate_candidates(candidates, n)
    if k > len(cand_idx):
        raise ValueError(
            f"cannot select k={k} exemplars from {len(cand_idx)} distinct "
            f"candidates")
    if mode == "host":
        mode = "mincache"
    if mode in ("device", "device_sharded", "device_sharded_pool", "greedi"):
        # ONE candidate row: the engine closes over it for all k rounds
        cand_rounds = cand_idx[None, :]
        counter = {"device": "greedy", "device_sharded": "greedy_sharded",
                   "device_sharded_pool": "greedy_sharded_pool",
                   "greedi": "greedy_greedi"}[mode]
        return run_selection(
            f, kind="dense", k=k, cand_rounds=cand_rounds,
            plan=mode, counter_key=counter, block_m=block_m, mesh=mesh,
            data_axes=data_axes)
    selected: list[int] = []
    traj: list[float] = []
    evals = 0
    if mode == "mincache":
        cache = f.init_cache()
        for _ in range(k):
            gains = np.array(f.gains_from_cache(cache, cand_idx))
            masked = np.isin(cand_idx, selected)
            evals += len(cand_idx) - int(masked.sum())
            gains[masked] = -np.inf
            j = int(cand_idx[int(np.argmax(gains))])
            selected.append(j)
            cache = f.fold_winner(cache, j)
            traj.append(f.value_from_cache(cache))
    elif mode == "multiset":
        _require_exemplar(f, "greedy mode='multiset'")
        for _ in range(k):
            base = f.V[np.asarray(selected, dtype=np.int64)] if selected else \
                jnp.zeros((0, f.dim), f.V.dtype)
            vals = np.array(f.greedy_step_values(base, f.V[cand_idx]))
            masked = np.isin(cand_idx, selected)
            evals += len(cand_idx) - int(masked.sum())
            vals[masked] = -np.inf
            j = int(cand_idx[int(np.argmax(vals))])
            selected.append(j)
            traj.append(float(vals.max()))
    else:
        raise ValueError(f"unknown greedy mode {mode!r}")
    return OptResult(selected, traj[-1] if traj else 0.0, traj, evals)


def lazy_greedy(
    f: SubmodularFunction,
    k: int,
    batch: int = 256,
    mode: str = "host",
    mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """CELF: maintain stale upper bounds (submodularity ⇒ gains only shrink).

    ``mode="host"`` is the reference loop and the exact host-side mirror of
    the engine's rescore policy: stale bounds in an (n,) array, per round a
    loop re-scores the top-``batch`` stale candidates at once (the
    evaluation engine still sees multiset-sized problems — optimizer-
    awareness preserved) until the fresh-top invariant certifies the winner.
    Because host and device run the *same* policy, selections AND
    ``evaluations`` counts agree across modes on the jnp backend (up to
    exact float ties).

    ``mode="device"`` runs CELF entirely on device: the stale bounds ride
    the one-dispatch scan carry, each iteration re-scores the top-``batch``
    of them via ``jax.lax.top_k``. ``mode="device_sharded"`` additionally
    row-shards V and the cache over a ``mesh``; the bound state stays
    replicated. ``mode="device_sharded_pool"`` also row-shards the
    candidate payload — the ub0 seeding pass and every top-B re-score
    psum-materialize their candidate blocks from the owning shards, so
    resident per-device memory is O(n/p·d) plus the O(n)-scalar bound
    state.
    """
    if k > f.n:
        raise ValueError(f"cannot select k={k} exemplars from n={f.n}")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if k == 0:
        return OptResult([], 0.0, [], 0)
    if mode in ("device", "device_sharded", "device_sharded_pool"):
        counter = {"device": "lazy_greedy",
                   "device_sharded": "lazy_greedy_sharded",
                   "device_sharded_pool": "lazy_greedy_sharded_pool"}[mode]
        return run_selection(
            f, kind="lazy", k=k, top_b=batch, plan=mode,
            counter_key=counter, mesh=mesh, data_axes=data_axes)
    if mode != "host":
        raise ValueError(f"unknown lazy_greedy mode {mode!r}")
    n = f.n
    B = max(1, min(batch, n))
    cache = f.init_cache()
    all_idx = np.arange(n)
    ub = np.asarray(f.gains_from_cache(cache, all_idx), np.float32).copy()
    evals = n
    taken = np.zeros(n, bool)
    selected: list[int] = []
    traj: list[float] = []
    for _ in range(k):
        fresh = np.zeros(n, bool)
        while True:
            stale_vals = np.where(fresh | taken, -np.inf, ub)
            fresh_best = np.max(np.where(fresh & ~taken, ub, -np.inf))
            if fresh_best >= stale_vals.max():
                break  # fresh-top invariant: the fresh best is the argmax
            top_idx = np.argsort(-stale_vals, kind="stable")[:B]
            top_idx = top_idx[stale_vals[top_idx] > -np.inf]
            ub[top_idx] = np.asarray(f.gains_from_cache(cache, top_idx))
            fresh[top_idx] = True
            evals += len(top_idx)
        j = int(np.argmax(np.where(fresh & ~taken, ub, -np.inf)))
        selected.append(j)
        taken[j] = True
        cache = f.fold_winner(cache, j)
        traj.append(f.value_from_cache(cache))
    return OptResult(selected, traj[-1] if traj else 0.0, traj, evals)


def stochastic_greedy(
    f: SubmodularFunction, k: int, eps: float = 0.05, seed: int = 0,
    mode: str = "host", block_m: Optional[int] = None,
    mesh=None, data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """Sample ⌈(n/k)·ln(1/ε)⌉ candidates per round; (1−1/e−ε) in expectation.

    All k rounds' candidate samples are drawn up front (so the host and
    device paths consume identical randomness); already-selected candidates
    are masked at scoring time. Each round draws k extra candidates so that
    after masking at most k selected ones, at least the required m fresh
    candidates remain — no round can degenerate to an all-masked argmax.
    ``evaluations`` counts the candidates that actually entered each round's
    argmax (identically in every mode), which keeps the numbers comparable
    with the pool-sampling formulation despite the overdraw.
    """
    n = f.n
    if k > n:
        raise ValueError(f"cannot select k={k} exemplars from n={n}")
    if k == 0:
        return OptResult([], 0.0, [], 0)
    rng = np.random.default_rng(seed)
    m = min(n, int(math.ceil(n / k * math.log(1.0 / eps))))
    m_draw = min(n, m + k)
    samples = np.stack(
        [rng.choice(n, size=m_draw, replace=False) for _ in range(k)])
    if mode in ("device", "device_sharded", "device_sharded_pool"):
        counter = {"device": "stochastic_greedy",
                   "device_sharded": "stochastic_greedy_sharded",
                   "device_sharded_pool":
                       "stochastic_greedy_sharded_pool"}[mode]
        return run_selection(
            f, kind="stochastic", k=k, cand_rounds=samples,
            plan=mode, counter_key=counter, block_m=block_m, mesh=mesh,
            data_axes=data_axes)
    if mode != "host":
        raise ValueError(f"unknown stochastic_greedy mode {mode!r}")
    cache = f.init_cache()
    selected: list[int] = []
    traj: list[float] = []
    evals = 0
    for t in range(k):
        cand = samples[t]
        gains = np.array(f.gains_from_cache(cache, cand))
        masked = np.isin(cand, selected)
        evals += len(cand) - int(masked.sum())
        gains[masked] = -np.inf
        j = int(cand[int(np.argmax(gains))])
        selected.append(j)
        cache = f.fold_winner(cache, j)
        traj.append(f.value_from_cache(cache))
    return OptResult(selected, traj[-1], traj, evals)


# ---------------------------------------------------------------------------
# Streaming sieves — built on the streaming sieve engine
# (:mod:`repro.core.streaming`): a fixed-capacity table of threshold sieves
# keyed by integer exponent, offered every arriving element. Like the greedy
# family, each algorithm composes one accept-rule *variant* with an execution
# plan: ``mode="host"`` steps the table one jitted dispatch per element (the
# exact array-semantics mirror), ``mode="device"`` consumes each stream block
# of B elements with ONE jitted ``lax.scan`` — singleton gain, grid rebuild,
# accept rule, cache min-update, and member bookkeeping all in the scan body.
# ---------------------------------------------------------------------------


def _stream_eval_count(n_elements: int, n_sieves: int) -> int:
    """Streaming ``evaluations`` unit, identical across the sieve family:
    each arriving element is scored against every live sieve in one engine
    call (min. 1 — the singleton gain is always computed)."""
    return n_elements * max(n_sieves, 1)


def _stream(f: SubmodularFunction, order: Optional[Sequence[int]], seed: int) -> Iterable[int]:
    idx = np.arange(f.n)
    if order is None:
        np.random.default_rng(seed).shuffle(idx)
        return idx
    return np.asarray(order)


def _stream_blocks(f: SubmodularFunction, order: Optional[Sequence[int]],
                   seed: int, block: int):
    """Yield (indices, distance rows, singleton gains) per stream block.

    One engine dispatch per block computes the (B, n) distances of the next B
    stream elements against the ground set — the batched replacement for the
    per-element ``point_distances`` round-trip. Exemplar-only: the singleton
    gains read d_e0 directly (callers guard via ``_require_exemplar``).
    """
    idx = np.asarray(_stream(f, order, seed))
    d_e0 = np.asarray(f.d_e0, np.float32)
    for s in range(0, len(idx), block):
        ib = idx[s:s + block]
        dmat = np.asarray(f.point_distances_block(f.V[ib]), np.float32)
        singles = np.maximum(d_e0[None, :] - dmat, 0.0).mean(axis=1)
        yield ib, dmat, singles


def _run_sieve(f: SubmodularFunction, k: int, eps: float, variant: str,
               order, seed: int, block_size: int, mode: str,
               s_max: Optional[int], mesh=None,
               data_axes: Sequence[str] = ("data",)) -> OptResult:
    """Drive a sieve-table engine over the stream under a host/device/
    device_sharded plan."""
    from repro.core.streaming import make_sieve_engine

    idx = np.asarray(_stream(f, order, seed))
    eng = make_sieve_engine(f, k, eps, variant=variant, mode=mode,
                            s_max=s_max, block_size=block_size, mesh=mesh,
                            data_axes=data_axes)
    for s in range(0, len(idx), block_size):
        ib = idx[s:s + block_size]
        eng.offer(ib, f.V[ib])
    members, value = eng.best()
    return OptResult(members, value, [value], eng.evaluations())


def sieve_streaming(
    f: SubmodularFunction, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64, mode: str = "host",
    s_max: Optional[int] = None, mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """SieveStreaming [4]: thresholds (1+ε)^i ∈ [m, 2km], m = max singleton.

    ``mode="device"`` consumes each stream block in one jitted scan dispatch;
    ``mode="host"`` is the per-element array-semantics mirror. ``s_max``
    overrides the sieve-table capacity (see :mod:`repro.core.streaming`).
    ``mode="device_sharded"`` (or an explicit ``mesh``) column-shards the
    sieve cache table over the mesh — O(S_max·n/p) streaming state per
    device.
    """
    return _run_sieve(f, k, eps, "sieve", order, seed, block_size, mode,
                      s_max, mesh=mesh, data_axes=data_axes)


def sieve_streaming_pp(
    f: SubmodularFunction, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64, mode: str = "host",
    s_max: Optional[int] = None, mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """SieveStreaming++ [19]: prune sieves below LB = best current value.

    LB moves after every accept, so the grid window is re-derived per
    element — inside the scan body under ``mode="device"``.
    """
    return _run_sieve(f, k, eps, "pp", order, seed, block_size, mode, s_max,
                      mesh=mesh, data_axes=data_axes)


def three_sieves(
    f: SubmodularFunction, k: int, eps: float = 0.1, T: int = 50,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64,
) -> OptResult:
    """ThreeSieves [18]: one sieve, threshold lowered after T rejections."""
    f = _require_exemplar(f, "three_sieves")
    cache = np.asarray(f.init_mincache(), np.float32)
    members: list[int] = []
    evals = 0
    m_seen = 0.0
    tau_idx: Optional[int] = None  # current exponent into the (1+eps) grid
    rejections = 0
    done = False
    for ib, dmat, singles in _stream_blocks(f, order, seed, block_size):
        for bi, idx in enumerate(ib):
            if singles[bi] > m_seen:
                m_seen = float(singles[bi])
                hi = k * m_seen
                tau_idx = math.floor(math.log(hi) / math.log1p(eps)) if hi > 0 else None
                rejections = 0
            if tau_idx is None or len(members) >= k:
                # no gain computed for a full/unarmed sieve — and none
                # counted: ``evaluations`` reflects work actually done
                # (the engine-boundary accounting rule)
                continue
            dvec = dmat[bi]
            gain = float(np.maximum(cache - dvec, 0.0).mean())
            evals += _stream_eval_count(1, 1)
            tau = (1 + eps) ** tau_idx
            f_cur = f.L0 - float(cache.mean())
            need = (tau - f_cur) / max(k - len(members), 1)
            if gain >= need:
                members.append(int(idx))
                cache = np.minimum(cache, dvec)
                rejections = 0
            else:
                rejections += 1
                if rejections >= T:
                    tau_idx -= 1
                    rejections = 0
                    if (1 + eps) ** tau_idx < m_seen / (2 * k):
                        done = True  # threshold exhausted
                        break
        if done:
            break
    value = f.L0 - float(cache.mean())
    return OptResult(members, value, [value], evals)


def salsa(
    f: SubmodularFunction, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64, mode: str = "host",
    s_max: Optional[int] = None, mesh=None,
    data_axes: Sequence[str] = ("data",),
) -> OptResult:
    """Salsa [20], simplified: an ensemble of dense-threshold passes.

    The full Salsa interleaves several threshold policies tuned to an OPT
    guess. We run, per OPT guess on the (1+ε) grid, a *dense* policy that
    accepts element e into sieve S when Δ(e|S) ≥ r·OPT_guess/k with r
    following the original schedule (1/2 for the first ⌈k/2⌉ members,
    1/(2e) after — so k=1 still applies the early rate), and return the best
    sieve. Single pass, same memory as SieveStreaming. The grid is grow-only
    (old OPT guesses are never dropped); under capacity pressure the sieve
    table evicts the lowest exponent (see :mod:`repro.core.streaming`).
    """
    return _run_sieve(f, k, eps, "salsa", order, seed, block_size, mode,
                      s_max, mesh=mesh, data_axes=data_axes)


OPTIMIZERS = {
    "greedy": greedy,
    "lazy_greedy": lazy_greedy,
    "stochastic_greedy": stochastic_greedy,
    "sieve_streaming": sieve_streaming,
    "sieve_streaming_pp": sieve_streaming_pp,
    "three_sieves": three_sieves,
    "salsa": salsa,
}
