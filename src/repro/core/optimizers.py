"""Submodular maximizers built on the multiset evaluation engine.

Every optimizer here evaluates *many* sets per step — the paper's central
observation ("optimizer-aware", §IV-A). Two evaluation styles are used:

* **multiset** — the paper-faithful path: each step packs
  ``{S ∪ {c_1}, …, S ∪ {c_m}}`` and calls the work-matrix engine. O(n·k·l).
* **mincache** — the beyond-paper incremental path: gains against the
  min-distance cache. O(n·l·d) per step (k drops out).

Optimizers:
  greedy               Nemhauser–Wolsey–Fisher (1−1/e); both styles.
  lazy_greedy          CELF lazy evaluation with stale upper bounds.
  stochastic_greedy    Mirzasoleiman et al. sampled candidates.
  sieve_streaming      Badanidiyuru et al. (1/2 − ε), streaming.
  sieve_streaming_pp   Kazemi et al., LB-pruned sieves (1/2 − ε), less memory.
  three_sieves         Buschjäger et al., single adaptive sieve ((1−ε)(1−1/e)
                       w.h.p.), minimal memory.
  salsa                Norouzi-Fard et al. dense-threshold ensemble
                       (simplified: fixed dense schedules, no OPT oracle).

All return an :class:`OptResult` (indices into V, value, trajectory, and the
number of *set-function evaluations* — the paper's cost unit l).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.functions import ExemplarClustering


@dataclasses.dataclass
class OptResult:
    indices: list[int]
    value: float
    trajectory: list[float]
    evaluations: int

    def exemplars(self, V) -> np.ndarray:
        return np.asarray(V)[self.indices]


# ---------------------------------------------------------------------------
# Greedy family
# ---------------------------------------------------------------------------


def greedy(
    f: ExemplarClustering,
    k: int,
    mode: str = "mincache",
    candidates: Optional[np.ndarray] = None,
) -> OptResult:
    """Algorithm 1 of the paper. ``mode`` picks the evaluation style."""
    n = f.n
    cand_idx = np.arange(n) if candidates is None else np.asarray(candidates)
    selected: list[int] = []
    traj: list[float] = []
    evals = 0
    if mode == "mincache":
        cache = f.init_mincache()
        for _ in range(k):
            gains = np.array(f.marginal_gains(f.V[cand_idx], cache))
            evals += len(cand_idx)
            gains[np.isin(cand_idx, selected)] = -np.inf
            j = int(cand_idx[int(np.argmax(gains))])
            selected.append(j)
            cache = f.update_mincache(cache, f.V[j])
            traj.append(f.value_from_mincache(cache))
    elif mode == "multiset":
        for _ in range(k):
            base = f.V[np.asarray(selected, dtype=np.int64)] if selected else \
                jnp.zeros((0, f.dim), f.V.dtype)
            vals = np.array(f.greedy_step_values(base, f.V[cand_idx]))
            evals += len(cand_idx)
            vals[np.isin(cand_idx, selected)] = -np.inf
            j = int(cand_idx[int(np.argmax(vals))])
            selected.append(j)
            traj.append(float(vals.max()))
    else:
        raise ValueError(f"unknown greedy mode {mode!r}")
    return OptResult(selected, traj[-1] if traj else 0.0, traj, evals)


def lazy_greedy(f: ExemplarClustering, k: int, batch: int = 256) -> OptResult:
    """CELF: maintain stale upper bounds (submodularity ⇒ gains only shrink).

    Re-evaluates the top-``batch`` stale candidates at once so the evaluation
    engine still sees multiset-sized problems (optimizer-awareness preserved).
    """
    n = f.n
    cache = f.init_mincache()
    gains = np.asarray(f.marginal_gains(f.V, cache))
    evals = n
    # max-heap of (-upper_bound, index, round_evaluated)
    heap = [(-g, i, 0) for i, g in enumerate(gains)]
    heapq.heapify(heap)
    selected: list[int] = []
    traj: list[float] = []
    for rnd in range(1, k + 1):
        while True:
            top = [heapq.heappop(heap) for _ in range(min(batch, len(heap)))]
            fresh_mask = [t[2] == rnd for t in top]
            if fresh_mask[0]:
                # best candidate is fresh — take it, push the rest back
                _, j, _ = top[0]
                for t in top[1:]:
                    heapq.heappush(heap, t)
                break
            idx = np.array([t[1] for t in top])
            new_gains = np.asarray(f.marginal_gains(f.V[idx], cache))
            evals += len(idx)
            for g, i in zip(new_gains, idx):
                heapq.heappush(heap, (-float(g), int(i), rnd))
        selected.append(int(j))
        cache = f.update_mincache(cache, f.V[j])
        traj.append(f.value_from_mincache(cache))
    return OptResult(selected, traj[-1], traj, evals)


def stochastic_greedy(
    f: ExemplarClustering, k: int, eps: float = 0.05, seed: int = 0
) -> OptResult:
    """Sample ⌈(n/k)·ln(1/ε)⌉ candidates per round; (1−1/e−ε) in expectation."""
    n = f.n
    rng = np.random.default_rng(seed)
    m = min(n, int(math.ceil(n / k * math.log(1.0 / eps))))
    cache = f.init_mincache()
    selected: list[int] = []
    traj: list[float] = []
    evals = 0
    for _ in range(k):
        pool = np.setdiff1d(np.arange(n), np.asarray(selected, dtype=np.int64))
        cand = rng.choice(pool, size=min(m, len(pool)), replace=False)
        gains = np.asarray(f.marginal_gains(f.V[cand], cache))
        evals += len(cand)
        j = int(cand[int(np.argmax(gains))])
        selected.append(j)
        cache = f.update_mincache(cache, f.V[j])
        traj.append(f.value_from_mincache(cache))
    return OptResult(selected, traj[-1], traj, evals)


# ---------------------------------------------------------------------------
# Streaming sieves — all share a vectorized multi-sieve state so that one
# arriving element is evaluated against *all* sieves in a single engine call
# (this is exactly the paper's multiset-parallelized problem).
# ---------------------------------------------------------------------------


class _SieveState:
    """Vectorized state for a dynamic collection of threshold sieves."""

    def __init__(self, f: ExemplarClustering, k: int):
        self.f = f
        self.k = k
        self.thresholds: list[float] = []
        self.caches = np.zeros((0, f.n), np.float32)  # per-sieve min-dist cache
        self.members: list[list[int]] = []

    def add_sieve(self, tau: float):
        self.thresholds.append(tau)
        base = np.asarray(self.f.init_mincache(), np.float32)[None]
        self.caches = np.concatenate([self.caches, base], axis=0)
        self.members.append([])

    def drop(self, keep: np.ndarray):
        self.thresholds = [t for t, m in zip(self.thresholds, keep) if m]
        self.caches = self.caches[keep]
        self.members = [s for s, m in zip(self.members, keep) if m]

    def values(self) -> np.ndarray:
        if not self.thresholds:
            return np.zeros((0,), np.float32)
        return self.f.L0 - self.caches.mean(axis=1)

    def offer(self, idx: int, dvec: np.ndarray, accept_rule) -> np.ndarray:
        """Offer element ``idx`` to every sieve; accept per ``accept_rule``.

        accept_rule(gains, sizes, values) -> bool mask. Returns the mask.
        """
        if not self.thresholds:
            return np.zeros((0,), bool)
        gains = np.maximum(self.caches - dvec[None, :], 0.0).mean(axis=1)
        sizes = np.array([len(m) for m in self.members])
        accept = accept_rule(gains, sizes, self.values()) & (sizes < self.k)
        if accept.any():
            upd = np.minimum(self.caches[accept], dvec[None, :])
            self.caches[accept] = upd
            for si in np.nonzero(accept)[0]:
                self.members[si].append(idx)
        return accept

    def best(self) -> tuple[list[int], float]:
        vals = self.values()
        if len(vals) == 0:
            return [], 0.0
        b = int(np.argmax(vals))
        return self.members[b], float(vals[b])


def _threshold_grid(lo: float, hi: float, eps: float) -> list[float]:
    """{(1+eps)^i} ∩ [lo, hi] (paper refs [4], [19])."""
    if hi <= 0 or lo <= 0:
        return []
    i_lo = math.ceil(math.log(lo) / math.log1p(eps))
    i_hi = math.floor(math.log(hi) / math.log1p(eps))
    return [(1 + eps) ** i for i in range(i_lo, i_hi + 1)]


def _stream(f: ExemplarClustering, order: Optional[Sequence[int]], seed: int) -> Iterable[int]:
    idx = np.arange(f.n)
    if order is None:
        np.random.default_rng(seed).shuffle(idx)
        return idx
    return np.asarray(order)


def sieve_streaming(
    f: ExemplarClustering, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
) -> OptResult:
    """SieveStreaming [4]: thresholds (1+ε)^i ∈ [m, 2km], m = max singleton."""
    st = _SieveState(f, k)
    m_seen = 0.0
    evals = 0
    for idx in _stream(f, order, seed):
        dvec = np.asarray(f.point_distances(f.V[idx]), np.float32)
        singleton = float(np.maximum(f.d_e0 - dvec, 0.0).mean())
        if singleton > m_seen:
            m_seen = singleton
            want = _threshold_grid(m_seen, 2.0 * k * m_seen, eps)
            have = set(st.thresholds)
            keep = np.array([t >= m_seen for t in st.thresholds], bool)
            if len(keep) and not keep.all():
                st.drop(keep)
            for t in want:
                if t not in have:
                    st.add_sieve(t)

        taus = np.array(st.thresholds)
        def rule(gains, sizes, values, taus=taus):
            need = (taus / 2.0 - values) / np.maximum(k - sizes, 1)
            return gains >= need
        st.offer(int(idx), dvec, rule)
        evals += max(len(st.thresholds), 1)
    members, value = st.best()
    return OptResult(members, value, [value], evals)


def sieve_streaming_pp(
    f: ExemplarClustering, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
) -> OptResult:
    """SieveStreaming++ [19]: prune sieves below LB = best current value."""
    st = _SieveState(f, k)
    m_seen, lb = 0.0, 0.0
    evals = 0
    for idx in _stream(f, order, seed):
        dvec = np.asarray(f.point_distances(f.V[idx]), np.float32)
        singleton = float(np.maximum(f.d_e0 - dvec, 0.0).mean())
        m_seen = max(m_seen, singleton)
        lo = max(lb, m_seen)
        want = _threshold_grid(lo, 2.0 * k * m_seen, eps)
        have = set(st.thresholds)
        if st.thresholds:
            keep = np.array([t >= lo / (1 + eps) for t in st.thresholds], bool)
            if not keep.all():
                st.drop(keep)
                have = set(st.thresholds)
        for t in want:
            if t not in have:
                st.add_sieve(t)
        taus = np.array(st.thresholds)
        def rule(gains, sizes, values, taus=taus):
            need = (taus / 2.0 - values) / np.maximum(k - sizes, 1)
            return gains >= need
        st.offer(int(idx), dvec, rule)
        evals += max(len(st.thresholds), 1)
        vals = st.values()
        if len(vals):
            lb = max(lb, float(vals.max()))
    members, value = st.best()
    return OptResult(members, value, [value], evals)


def three_sieves(
    f: ExemplarClustering, k: int, eps: float = 0.1, T: int = 50,
    order: Optional[Sequence[int]] = None, seed: int = 0,
) -> OptResult:
    """ThreeSieves [18]: one sieve, threshold lowered after T rejections."""
    cache = np.asarray(f.init_mincache(), np.float32)
    members: list[int] = []
    evals = 0
    m_seen = 0.0
    tau_idx: Optional[int] = None  # current exponent into the (1+eps) grid
    rejections = 0
    for idx in _stream(f, order, seed):
        dvec = np.asarray(f.point_distances(f.V[idx]), np.float32)
        gain = float(np.maximum(cache - dvec, 0.0).mean())
        evals += 1
        singleton = float(np.maximum(f.d_e0 - dvec, 0.0).mean())
        if singleton > m_seen:
            m_seen = singleton
            hi = k * m_seen
            tau_idx = math.floor(math.log(hi) / math.log1p(eps)) if hi > 0 else None
            rejections = 0
        if tau_idx is None or len(members) >= k:
            continue
        tau = (1 + eps) ** tau_idx
        f_cur = f.L0 - float(cache.mean())
        need = (tau - f_cur) / max(k - len(members), 1)
        if gain >= need:
            members.append(int(idx))
            cache = np.minimum(cache, dvec)
            rejections = 0
        else:
            rejections += 1
            if rejections >= T:
                tau_idx -= 1
                rejections = 0
                if (1 + eps) ** tau_idx < m_seen / (2 * k):
                    break  # threshold exhausted
    value = f.L0 - float(cache.mean())
    return OptResult(members, value, [value], evals)


def salsa(
    f: ExemplarClustering, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
) -> OptResult:
    """Salsa [20], simplified: an ensemble of dense-threshold passes.

    The full Salsa interleaves several threshold policies tuned to an OPT
    guess. We run, per OPT guess on the (1+ε) grid, a *dense* policy that
    accepts element e into sieve S when Δ(e|S) ≥ r·OPT_guess/k with r
    following the original schedule (1/2 early, 1/(2e) late), and return the
    best sieve. Single pass, same memory as SieveStreaming.
    """
    st = _SieveState(f, k)
    m_seen = 0.0
    evals = 0
    early, late = 0.5, 1.0 / (2.0 * math.e)
    for idx in _stream(f, order, seed):
        dvec = np.asarray(f.point_distances(f.V[idx]), np.float32)
        singleton = float(np.maximum(f.d_e0 - dvec, 0.0).mean())
        if singleton > m_seen:
            m_seen = singleton
            want = _threshold_grid(m_seen, 2.0 * k * m_seen, eps)
            have = set(st.thresholds)
            for t in want:
                if t not in have:
                    st.add_sieve(t)
        taus = np.array(st.thresholds)
        def rule(gains, sizes, values, taus=taus):
            r = np.where(sizes < k // 2, early, late)
            return gains >= r * taus / k
        st.offer(int(idx), dvec, rule)
        evals += max(len(st.thresholds), 1)
    members, value = st.best()
    return OptResult(members, value, [value], evals)


OPTIMIZERS = {
    "greedy": greedy,
    "lazy_greedy": lazy_greedy,
    "stochastic_greedy": stochastic_greedy,
    "sieve_streaming": sieve_streaming,
    "sieve_streaming_pp": sieve_streaming_pp,
    "three_sieves": three_sieves,
    "salsa": salsa,
}
