"""Submodular maximizers built on the multiset evaluation engine.

Every optimizer here evaluates *many* sets per step — the paper's central
observation ("optimizer-aware", §IV-A). Three evaluation styles are used:

* **multiset** — the paper-faithful path: each step packs
  ``{S ∪ {c_1}, …, S ∪ {c_m}}`` and calls the work-matrix engine. O(n·k·l).
* **mincache** — the beyond-paper incremental path: gains against the
  min-distance cache. O(n·l·d) per step (k drops out).
* **device** — the mincache recurrence hoisted entirely on device: a
  ``jax.lax.scan`` runs all k greedy rounds inside ONE jitted dispatch, with
  candidate gains, argmax selection, and the cache update never leaving the
  accelerator (no per-round host↔device copies, no per-round dispatch).

The min-distance cache obeys the recurrence

    m_i^(0)   = d(v_i, e0)
    m_i^(t+1) = min(m_i^(t), d(v_i, s_{t+1}))          (s_{t+1} = round-t winner)
    Δ(c | S_t) = |V|⁻¹ Σ_i max(m_i^(t) − d(v_i, c), 0)
    f(S_t)     = L({e0}) − |V|⁻¹ Σ_i m_i^(t)

so each greedy round is one (n × m) distance evaluation plus an O(n) fold of
the winner — the device engine evaluates the fold *inside* the next round's
gain kernel (see ``kernels/marginal_gain.gain_update_eval``), which means the
winner's distance column never materializes in HBM.

Optimizer modes:
  greedy               ``mode="mincache"`` (host reference, alias ``"host"``),
                       ``mode="multiset"`` (paper-faithful), ``mode="device"``.
  stochastic_greedy    ``mode="host"`` reference loop or ``mode="device"``;
                       both consume the same precomputed per-round candidate
                       sample matrix, so selections agree (exactly on the jnp
                       backend; on pallas backends the in-kernel winner fold
                       can differ in the last ulp from the host's jnp update,
                       which may flip a near-tie argmax at reduced precision).
  lazy_greedy          CELF lazy evaluation with stale upper bounds (host).
  sieve_streaming      Badanidiyuru et al. (1/2 − ε), streaming.
  sieve_streaming_pp   Kazemi et al., LB-pruned sieves (1/2 − ε), less memory.
  three_sieves         Buschjäger et al., single adaptive sieve ((1−ε)(1−1/e)
                       w.h.p.), minimal memory.
  salsa                Norouzi-Fard et al. dense-threshold ensemble
                       (simplified: fixed dense schedules, no OPT oracle).

The streaming family consumes the stream in *blocks* of ``block_size``
elements: each block's distances against the ground set are computed in one
engine dispatch (``ExemplarClustering.point_distances_block``) instead of one
dispatch per arriving element, and ``_SieveState.offer`` accepts the whole
block (decisions stay sequential — an accept updates the sieve caches seen by
the next element in the block).

All return an :class:`OptResult` (indices into V, value, trajectory, and the
number of *set-function evaluations* — the paper's cost unit l).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math
from functools import partial
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_mod
from repro.core.functions import ExemplarClustering, gains_formula
from repro.core.precision import resolve as resolve_policy


@dataclasses.dataclass
class OptResult:
    indices: list[int]
    value: float
    trajectory: list[float]
    evaluations: int

    def exemplars(self, V) -> np.ndarray:
        return np.asarray(V)[self.indices]


# ---------------------------------------------------------------------------
# Device-resident stepping engine (tentpole, beyond paper)
# ---------------------------------------------------------------------------

#: Number of times each device engine has been *traced* (not dispatched).
#: A second run with identical shapes/statics must not increment these —
#: that is the "exactly one jitted dispatch for all k rounds" property.
DEVICE_TRACE_COUNTS: collections.Counter = collections.Counter()


@partial(jax.jit, static_argnames=("distance", "policy_name", "block_m",
                                   "backend", "rbf_gamma", "counter_key"))
def _device_select_scan(V, d_e0, cand_rounds, w0, *, distance, policy_name,
                        block_m, backend, rbf_gamma, counter_key):
    """All k greedy rounds in one dispatch: scan over per-round candidates.

    ``cand_rounds`` is (k, m) int32 — row t holds round t's candidate indices
    (greedy broadcasts one row; stochastic greedy pre-samples k rows). The
    carry is ``(mincache, taken-mask, previous winner)``; the winner is folded
    into the cache at the *start* of the next round, so on the Pallas backend
    the fold rides inside the fused gain kernel and the winner's distance
    column never re-materializes in HBM.
    """
    DEVICE_TRACE_COUNTS[counter_key] += 1
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    n = V.shape[0]
    k, m = cand_rounds.shape
    m_pad = ((m + block_m - 1) // block_m) * block_m
    cand_p = jnp.pad(cand_rounds, ((0, 0), (0, m_pad - m)))
    valid = jnp.arange(m_pad) < m
    d_e0f = d_e0.astype(jnp.float32)
    L0 = jnp.mean(d_e0f)
    use_kernel = backend in ("pallas", "pallas_interpret")
    if use_kernel:
        from repro.kernels import ops as kops

    def gains_jnp(cache, C):
        # stream candidates in blocks so the (n, Bm) distance tile stays
        # memory-bounded; gains_formula is shared with the host path, which
        # keeps the per-column reduction (and hence the argmax) identical.
        blocks = C.reshape(-1, block_m, C.shape[-1])
        return jax.lax.map(
            lambda Cb: gains_formula(V, Cb, cache, pair, policy), blocks
        ).reshape(-1)

    def step(carry, cand_t):
        cache, taken, w_prev = carry
        C = V[cand_t]
        if use_kernel:
            # block_m only sizes the jnp streaming block (HBM working set);
            # the kernel tiles its own VMEM blocks and never materializes
            # the (n, m) matrix, so it keeps its default tile size
            gains, cache = kops.fused_gain_update(
                V, C, cache, w_prev, policy=policy, rbf_gamma=rbf_gamma,
                interpret=(backend != "pallas"))
        else:
            dw = pair(V, w_prev[None, :], policy)[:, 0]
            cache = jnp.minimum(cache, dw.astype(jnp.float32))
            gains = gains_jnp(cache, C)
        gains = jnp.where(valid & ~taken[cand_t], gains, -jnp.inf)
        p = jnp.argmax(gains)
        j = cand_t[p]
        # cache currently includes winners 0..t-1 → this is trajectory[t-1]
        val = L0 - jnp.mean(cache)
        return (cache, taken.at[j].set(True), V[j]), (j, val)

    init = (d_e0f, jnp.zeros((n,), bool), w0.astype(V.dtype))
    (cache, _, w_last), (sel, vals) = jax.lax.scan(step, init, cand_p)
    # one final fold for the last trajectory point
    dw = pair(V, w_last[None, :], policy)[:, 0]
    final_val = L0 - jnp.mean(jnp.minimum(cache, dw.astype(jnp.float32)))
    traj = jnp.concatenate([vals[1:], final_val[None]])
    return sel.astype(jnp.int32), traj


def _device_block_m(n: int, m: int) -> int:
    """Candidate block size bounding the (n, Bm) gain tile to ~128 MiB.

    The floor of 8 (one TPU sublane) lets the cap be exceeded only past
    n = 2^22 ground vectors, where chunking V itself is the right tool.
    """
    if n * m <= (1 << 25):
        return m
    return max(8, min(m, (1 << 25) // max(n, 1)))


def _run_device_scan(f: ExemplarClustering, cand_rounds: np.ndarray,
                     counter_key: str, block_m: Optional[int] = None) -> OptResult:
    policy = f.cfg.resolved_policy()
    backend = f.cfg.backend if f.cfg.backend in ("pallas", "pallas_interpret") \
        else "jnp"
    if backend != "jnp" and f.cfg.distance not in dist_mod.MXU_ELIGIBLE:
        raise ValueError(
            f"device mode with a pallas backend supports "
            f"{sorted(dist_mod.MXU_ELIGIBLE)}, got {f.cfg.distance!r}")
    rbf_gamma = dist_mod.RBF_GAMMA \
        if (backend != "jnp" and f.cfg.distance == "rbf") else None
    w0 = f.e0 if f.e0 is not None else jnp.zeros((f.dim,), f.V.dtype)
    k, m = cand_rounds.shape
    if k == 0:
        return OptResult([], 0.0, [], 0)
    bm = block_m if block_m is not None else _device_block_m(f.n, m)
    sel, traj = _device_select_scan(
        f.V, f.d_e0, jnp.asarray(cand_rounds, jnp.int32), w0,
        distance=f.cfg.distance, policy_name=policy.name, block_m=bm,
        backend=backend, rbf_gamma=rbf_gamma, counter_key=counter_key)
    sel = [int(x) for x in np.asarray(sel)]
    traj = [float(x) for x in np.asarray(traj)]
    return OptResult(sel, traj[-1] if traj else 0.0, traj, k * m)


# ---------------------------------------------------------------------------
# Greedy family
# ---------------------------------------------------------------------------


def greedy(
    f: ExemplarClustering,
    k: int,
    mode: str = "mincache",
    candidates: Optional[np.ndarray] = None,
    block_m: Optional[int] = None,
) -> OptResult:
    """Algorithm 1 of the paper. ``mode`` picks the evaluation style:

    ``"mincache"`` (alias ``"host"``) — host loop over rounds, device gains.
    ``"multiset"`` — paper-faithful: pack {S ∪ {c}} ∀c and call the engine.
    ``"device"``  — all k rounds in one jitted ``lax.scan`` dispatch.
    """
    n = f.n
    cand_idx = np.arange(n) if candidates is None else np.asarray(candidates)
    if mode == "host":
        mode = "mincache"
    if mode == "device":
        cand_rounds = np.broadcast_to(cand_idx, (k, len(cand_idx)))
        return _run_device_scan(f, cand_rounds, "greedy", block_m)
    selected: list[int] = []
    traj: list[float] = []
    evals = 0
    if mode == "mincache":
        cache = f.init_mincache()
        for _ in range(k):
            gains = np.array(f.marginal_gains(f.V[cand_idx], cache))
            evals += len(cand_idx)
            gains[np.isin(cand_idx, selected)] = -np.inf
            j = int(cand_idx[int(np.argmax(gains))])
            selected.append(j)
            cache = f.update_mincache(cache, f.V[j])
            traj.append(f.value_from_mincache(cache))
    elif mode == "multiset":
        for _ in range(k):
            base = f.V[np.asarray(selected, dtype=np.int64)] if selected else \
                jnp.zeros((0, f.dim), f.V.dtype)
            vals = np.array(f.greedy_step_values(base, f.V[cand_idx]))
            evals += len(cand_idx)
            vals[np.isin(cand_idx, selected)] = -np.inf
            j = int(cand_idx[int(np.argmax(vals))])
            selected.append(j)
            traj.append(float(vals.max()))
    else:
        raise ValueError(f"unknown greedy mode {mode!r}")
    return OptResult(selected, traj[-1] if traj else 0.0, traj, evals)


def lazy_greedy(f: ExemplarClustering, k: int, batch: int = 256) -> OptResult:
    """CELF: maintain stale upper bounds (submodularity ⇒ gains only shrink).

    Re-evaluates the top-``batch`` stale candidates at once so the evaluation
    engine still sees multiset-sized problems (optimizer-awareness preserved).
    """
    n = f.n
    cache = f.init_mincache()
    gains = np.asarray(f.marginal_gains(f.V, cache))
    evals = n
    # max-heap of (-upper_bound, index, round_evaluated)
    heap = [(-g, i, 0) for i, g in enumerate(gains)]
    heapq.heapify(heap)
    selected: list[int] = []
    traj: list[float] = []
    for rnd in range(1, k + 1):
        while True:
            top = [heapq.heappop(heap) for _ in range(min(batch, len(heap)))]
            fresh_mask = [t[2] == rnd for t in top]
            if fresh_mask[0]:
                # best candidate is fresh — take it, push the rest back
                _, j, _ = top[0]
                for t in top[1:]:
                    heapq.heappush(heap, t)
                break
            idx = np.array([t[1] for t in top])
            new_gains = np.asarray(f.marginal_gains(f.V[idx], cache))
            evals += len(idx)
            for g, i in zip(new_gains, idx):
                heapq.heappush(heap, (-float(g), int(i), rnd))
        selected.append(int(j))
        cache = f.update_mincache(cache, f.V[j])
        traj.append(f.value_from_mincache(cache))
    return OptResult(selected, traj[-1], traj, evals)


def stochastic_greedy(
    f: ExemplarClustering, k: int, eps: float = 0.05, seed: int = 0,
    mode: str = "host", block_m: Optional[int] = None,
) -> OptResult:
    """Sample ⌈(n/k)·ln(1/ε)⌉ candidates per round; (1−1/e−ε) in expectation.

    All k rounds' candidate samples are drawn up front (so the host and
    device paths consume identical randomness); already-selected candidates
    are masked at scoring time. Each round draws k extra candidates so that
    after masking at most k selected ones, at least the required m fresh
    candidates remain — no round can degenerate to an all-masked argmax.
    ``evaluations`` therefore counts k·min(n, m+k) scored candidates, a +k
    per-round overdraw relative to the pool-sampling formulation.
    """
    n = f.n
    rng = np.random.default_rng(seed)
    m = min(n, int(math.ceil(n / k * math.log(1.0 / eps))))
    m_draw = min(n, m + k)
    samples = np.stack(
        [rng.choice(n, size=m_draw, replace=False) for _ in range(k)])
    if mode == "device":
        return _run_device_scan(f, samples, "stochastic_greedy", block_m)
    if mode != "host":
        raise ValueError(f"unknown stochastic_greedy mode {mode!r}")
    cache = f.init_mincache()
    selected: list[int] = []
    traj: list[float] = []
    evals = 0
    for t in range(k):
        cand = samples[t]
        gains = np.array(f.marginal_gains(f.V[cand], cache))
        evals += len(cand)
        gains[np.isin(cand, selected)] = -np.inf
        j = int(cand[int(np.argmax(gains))])
        selected.append(j)
        cache = f.update_mincache(cache, f.V[j])
        traj.append(f.value_from_mincache(cache))
    return OptResult(selected, traj[-1], traj, evals)


# ---------------------------------------------------------------------------
# Streaming sieves — all share a vectorized multi-sieve state so that one
# arriving element is evaluated against *all* sieves in a single engine call
# (this is exactly the paper's multiset-parallelized problem). The stream is
# consumed in blocks: one device dispatch fetches the distances of B elements
# (a packed multiset evaluation), and the accept logic replays them in order.
# ---------------------------------------------------------------------------


class _SieveState:
    """Vectorized state for a dynamic collection of threshold sieves."""

    def __init__(self, f: ExemplarClustering, k: int):
        self.f = f
        self.k = k
        self.thresholds: list[float] = []
        self.caches = np.zeros((0, f.n), np.float32)  # per-sieve min-dist cache
        self.members: list[list[int]] = []

    def add_sieve(self, tau: float):
        self.thresholds.append(tau)
        base = np.asarray(self.f.init_mincache(), np.float32)[None]
        self.caches = np.concatenate([self.caches, base], axis=0)
        self.members.append([])

    def drop(self, keep: np.ndarray):
        self.thresholds = [t for t, m in zip(self.thresholds, keep) if m]
        self.caches = self.caches[keep]
        self.members = [s for s, m in zip(self.members, keep) if m]

    def values(self) -> np.ndarray:
        if not self.thresholds:
            return np.zeros((0,), np.float32)
        return self.f.L0 - self.caches.mean(axis=1)

    def _offer_one(self, idx: int, dvec: np.ndarray, accept_rule) -> np.ndarray:
        gains = np.maximum(self.caches - dvec[None, :], 0.0).mean(axis=1)
        sizes = np.array([len(m) for m in self.members])
        accept = accept_rule(gains, sizes, self.values()) & (sizes < self.k)
        if accept.any():
            upd = np.minimum(self.caches[accept], dvec[None, :])
            self.caches[accept] = upd
            for si in np.nonzero(accept)[0]:
                self.members[si].append(idx)
        return accept

    def offer(self, idx, dvec: np.ndarray, accept_rule) -> np.ndarray:
        """Offer one element — or a block of B — to every sieve.

        ``idx`` is an int (with ``dvec`` of shape (n,)) or a (B,) index array
        (with ``dvec`` of shape (B, n), the block's packed distance rows from
        one engine dispatch). Block decisions are sequential: an accept
        updates the caches consulted for the next element. Returns the accept
        mask — (S,) for a single element, (B, S) for a block.
        """
        dmat = np.asarray(dvec, np.float32)
        if dmat.ndim == 1:
            if not self.thresholds:
                return np.zeros((0,), bool)
            return self._offer_one(int(idx), dmat, accept_rule)
        idxs = np.atleast_1d(np.asarray(idx))
        if not self.thresholds:
            return np.zeros((len(idxs), 0), bool)
        return np.stack([
            self._offer_one(int(i), row, accept_rule)
            for i, row in zip(idxs, dmat)
        ])

    def best(self) -> tuple[list[int], float]:
        vals = self.values()
        if len(vals) == 0:
            return [], 0.0
        b = int(np.argmax(vals))
        return self.members[b], float(vals[b])


def _threshold_grid(lo: float, hi: float, eps: float) -> list[float]:
    """{(1+eps)^i} ∩ [lo, hi] (paper refs [4], [19])."""
    if hi <= 0 or lo <= 0:
        return []
    i_lo = math.ceil(math.log(lo) / math.log1p(eps))
    i_hi = math.floor(math.log(hi) / math.log1p(eps))
    return [(1 + eps) ** i for i in range(i_lo, i_hi + 1)]


def _stream(f: ExemplarClustering, order: Optional[Sequence[int]], seed: int) -> Iterable[int]:
    idx = np.arange(f.n)
    if order is None:
        np.random.default_rng(seed).shuffle(idx)
        return idx
    return np.asarray(order)


def _stream_blocks(f: ExemplarClustering, order: Optional[Sequence[int]],
                   seed: int, block: int):
    """Yield (indices, distance rows, singleton gains) per stream block.

    One engine dispatch per block computes the (B, n) distances of the next B
    stream elements against the ground set — the batched replacement for the
    per-element ``point_distances`` round-trip.
    """
    idx = np.asarray(_stream(f, order, seed))
    d_e0 = np.asarray(f.d_e0, np.float32)
    for s in range(0, len(idx), block):
        ib = idx[s:s + block]
        dmat = np.asarray(f.point_distances_block(f.V[ib]), np.float32)
        singles = np.maximum(d_e0[None, :] - dmat, 0.0).mean(axis=1)
        yield ib, dmat, singles


def _static_grid_segments(blocks, rebuild_grid):
    """Split stream blocks into segments over which the threshold grid is
    static: ``rebuild_grid(m_seen)`` fires whenever a new max singleton
    arrives, then the run of elements up to the next new-max is yielded as
    one (indices, distance rows) pair for a single blocked ``offer``.
    """
    m_seen = 0.0
    for ib, dmat, singles in blocks:
        b, B = 0, len(ib)
        while b < B:
            if singles[b] > m_seen:
                m_seen = float(singles[b])
                rebuild_grid(m_seen)
            e = b + 1
            while e < B and singles[e] <= m_seen:
                e += 1
            yield ib[b:e], dmat[b:e]
            b = e


def sieve_streaming(
    f: ExemplarClustering, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64,
) -> OptResult:
    """SieveStreaming [4]: thresholds (1+ε)^i ∈ [m, 2km], m = max singleton."""
    st = _SieveState(f, k)
    evals = 0

    def rebuild(m_seen):
        want = _threshold_grid(m_seen, 2.0 * k * m_seen, eps)
        have = set(st.thresholds)
        keep = np.array([t >= m_seen for t in st.thresholds], bool)
        if len(keep) and not keep.all():
            st.drop(keep)
        for t in want:
            if t not in have:
                st.add_sieve(t)

    blocks = _stream_blocks(f, order, seed, block_size)
    for seg_idx, seg_d in _static_grid_segments(blocks, rebuild):
        taus = np.array(st.thresholds)

        def rule(gains, sizes, values, taus=taus):
            need = (taus / 2.0 - values) / np.maximum(k - sizes, 1)
            return gains >= need

        st.offer(seg_idx, seg_d, rule)
        evals += len(seg_idx) * max(len(st.thresholds), 1)
    members, value = st.best()
    return OptResult(members, value, [value], evals)


def sieve_streaming_pp(
    f: ExemplarClustering, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64,
) -> OptResult:
    """SieveStreaming++ [19]: prune sieves below LB = best current value.

    LB moves after every accept, so sieve management stays per-element; the
    distance fetch is still one dispatch per block.
    """
    st = _SieveState(f, k)
    m_seen, lb = 0.0, 0.0
    evals = 0
    for ib, dmat, singles in _stream_blocks(f, order, seed, block_size):
        for bi, idx in enumerate(ib):
            m_seen = max(m_seen, float(singles[bi]))
            lo = max(lb, m_seen)
            want = _threshold_grid(lo, 2.0 * k * m_seen, eps)
            have = set(st.thresholds)
            if st.thresholds:
                keep = np.array([t >= lo / (1 + eps) for t in st.thresholds], bool)
                if not keep.all():
                    st.drop(keep)
                    have = set(st.thresholds)
            for t in want:
                if t not in have:
                    st.add_sieve(t)
            taus = np.array(st.thresholds)

            def rule(gains, sizes, values, taus=taus):
                need = (taus / 2.0 - values) / np.maximum(k - sizes, 1)
                return gains >= need

            st.offer(int(idx), dmat[bi], rule)
            evals += max(len(st.thresholds), 1)
            vals = st.values()
            if len(vals):
                lb = max(lb, float(vals.max()))
    members, value = st.best()
    return OptResult(members, value, [value], evals)


def three_sieves(
    f: ExemplarClustering, k: int, eps: float = 0.1, T: int = 50,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64,
) -> OptResult:
    """ThreeSieves [18]: one sieve, threshold lowered after T rejections."""
    cache = np.asarray(f.init_mincache(), np.float32)
    members: list[int] = []
    evals = 0
    m_seen = 0.0
    tau_idx: Optional[int] = None  # current exponent into the (1+eps) grid
    rejections = 0
    done = False
    for ib, dmat, singles in _stream_blocks(f, order, seed, block_size):
        for bi, idx in enumerate(ib):
            dvec = dmat[bi]
            gain = float(np.maximum(cache - dvec, 0.0).mean())
            evals += 1
            if singles[bi] > m_seen:
                m_seen = float(singles[bi])
                hi = k * m_seen
                tau_idx = math.floor(math.log(hi) / math.log1p(eps)) if hi > 0 else None
                rejections = 0
            if tau_idx is None or len(members) >= k:
                continue
            tau = (1 + eps) ** tau_idx
            f_cur = f.L0 - float(cache.mean())
            need = (tau - f_cur) / max(k - len(members), 1)
            if gain >= need:
                members.append(int(idx))
                cache = np.minimum(cache, dvec)
                rejections = 0
            else:
                rejections += 1
                if rejections >= T:
                    tau_idx -= 1
                    rejections = 0
                    if (1 + eps) ** tau_idx < m_seen / (2 * k):
                        done = True  # threshold exhausted
                        break
        if done:
            break
    value = f.L0 - float(cache.mean())
    return OptResult(members, value, [value], evals)


def salsa(
    f: ExemplarClustering, k: int, eps: float = 0.1,
    order: Optional[Sequence[int]] = None, seed: int = 0,
    block_size: int = 64,
) -> OptResult:
    """Salsa [20], simplified: an ensemble of dense-threshold passes.

    The full Salsa interleaves several threshold policies tuned to an OPT
    guess. We run, per OPT guess on the (1+ε) grid, a *dense* policy that
    accepts element e into sieve S when Δ(e|S) ≥ r·OPT_guess/k with r
    following the original schedule (1/2 early, 1/(2e) late), and return the
    best sieve. Single pass, same memory as SieveStreaming.
    """
    st = _SieveState(f, k)
    evals = 0
    early, late = 0.5, 1.0 / (2.0 * math.e)

    def rebuild(m_seen):
        want = _threshold_grid(m_seen, 2.0 * k * m_seen, eps)
        have = set(st.thresholds)
        for t in want:
            if t not in have:
                st.add_sieve(t)

    blocks = _stream_blocks(f, order, seed, block_size)
    for seg_idx, seg_d in _static_grid_segments(blocks, rebuild):
        taus = np.array(st.thresholds)

        def rule(gains, sizes, values, taus=taus):
            r = np.where(sizes < k // 2, early, late)
            return gains >= r * taus / k

        st.offer(seg_idx, seg_d, rule)
        evals += len(seg_idx) * max(len(st.thresholds), 1)
    members, value = st.best()
    return OptResult(members, value, [value], evals)


OPTIMIZERS = {
    "greedy": greedy,
    "lazy_greedy": lazy_greedy,
    "stochastic_greedy": stochastic_greedy,
    "sieve_streaming": sieve_streaming,
    "sieve_streaming_pp": sieve_streaming_pp,
    "three_sieves": three_sieves,
    "salsa": salsa,
}
