"""Floating-point precision policies for submodular evaluation.

The paper studies FP16 vs FP32 evaluation on GPUs (§V-B). On TPU the native
low-precision format is bfloat16, so the framework exposes three policies and
always accumulates Gram-matrix contractions in float32
(``preferred_element_type``), which is strictly more accurate than the paper's
all-FP16 path while keeping the low-precision memory/bandwidth benefits.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Compute/accumulate dtype pair for distance evaluation.

    Attributes:
      name: human-readable policy name.
      compute_dtype: dtype in which payload (V, S) is stored and multiplied.
      accum_dtype: dtype for contraction accumulation and reductions.
    """

    name: str
    compute_dtype: jnp.dtype
    accum_dtype: jnp.dtype

    @property
    def itemsize(self) -> int:
        return jnp.dtype(self.compute_dtype).itemsize


FP32 = PrecisionPolicy("fp32", jnp.float32, jnp.float32)
BF16 = PrecisionPolicy("bf16", jnp.bfloat16, jnp.float32)
FP16 = PrecisionPolicy("fp16", jnp.float16, jnp.float32)
# Paper-faithful FP16: accumulate in fp16 as well (the CUDA kernel's native path).
FP16_STRICT = PrecisionPolicy("fp16_strict", jnp.float16, jnp.float16)

POLICIES = {p.name: p for p in (FP32, BF16, FP16, FP16_STRICT)}


def resolve(policy: "str | PrecisionPolicy") -> PrecisionPolicy:
    if isinstance(policy, PrecisionPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError as e:
        raise ValueError(
            f"unknown precision policy {policy!r}; options: {sorted(POLICIES)}"
        ) from e
