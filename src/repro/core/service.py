"""Streaming ingestion service: async queue in, exemplars out.

The companion Industry 4.0 deployment (Honysz et al., 2021) runs the sieve
family against live sensor streams; this module is that serving surface for
the device-resident sieve engine (:mod:`repro.core.streaming`). Producers
``offer`` arbitrary vectors (not ground-set indices — the ground set V is the
fixed *evaluation* reference the submodular function scores against);
a single worker drains the queue in blocks and feeds the engine one scan
dispatch per block; consumers ``snapshot`` the current best sieve at any
point of the stream.

Flow control:

* **Offer batching** — the worker takes whatever is queued (up to
  ``block_size``) per engine dispatch, so a burst of producers amortizes to
  one device round-trip per block while a trickle still gets per-element
  latency. Block boundaries cannot change results: sieve decisions are
  per-element sequential regardless of blocking.
* **Backpressure** — the queue is bounded by ``max_pending``; ``offer``
  awaits when the engine falls behind, propagating slow-down to producers
  instead of buffering without bound.
* **Snapshot consistency** — engine access is serialized by a lock shared
  between the worker and ``snapshot``, so a snapshot always observes a
  block-aligned engine state (never a half-applied block).

The engine itself is synchronous JAX; dispatches run in a thread
(``asyncio.to_thread``) so the event loop keeps accepting offers while the
device works.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.functions import ExemplarClustering
from repro.core.streaming import make_sieve_engine


@dataclasses.dataclass
class SieveSnapshot:
    """Point-in-time view of the service's best sieve."""

    indices: list[int]      #: stream ids of the best sieve's members
    exemplars: np.ndarray   #: their vectors, (len(indices), dim)
    value: float            #: f-value of the best sieve
    n_offered: int          #: elements accepted into the queue so far
    n_ingested: int         #: elements the engine has consumed
    n_accepted: int         #: elements accepted by at least one sieve
    evaluations: int        #: engine-boundary evaluation count
    pending: int            #: elements still queued (backpressure depth)


class StreamIngestionService:
    """Async wrapper turning the sieve engine into a serving surface.

    Use as an async context manager::

        async with StreamIngestionService(f, k=8) as svc:
            for x in stream:
                await svc.offer(x)          # backpressure-aware
            snap = await svc.snapshot()     # current best exemplars

    Stream ids are assigned in ``offer`` order and are the ``indices`` the
    snapshot reports; the service retains accepted elements' vectors (pruned
    to the live member tables at snapshot time) so exemplars can be returned
    for elements that are not ground-set rows.
    """

    def __init__(self, f: ExemplarClustering, k: int, eps: float = 0.1,
                 variant: str = "sieve", mode: str = "device",
                 block_size: int = 64, s_max: Optional[int] = None,
                 max_pending: int = 1024, mesh=None,
                 data_axes: Sequence[str] = ("data",)):
        # ``mesh`` / ``mode="device_sharded"`` wrap the mesh-sharded engine:
        # the cache table shards, but the member slots / sizes / active mask
        # a snapshot reads are replicated table state, so ``snapshot`` still
        # gathers the best sieve's members ONCE — not per shard
        self._engine = make_sieve_engine(f, k, eps, variant=variant,
                                         mode=mode, s_max=s_max,
                                         block_size=block_size, mesh=mesh,
                                         data_axes=data_axes)
        self._dim = f.dim
        self._block = block_size
        self._max_pending = max_pending
        self._ids = itertools.count()
        self._vecs: dict[int, np.ndarray] = {}
        self._n_offered = 0
        self._n_ingested = 0
        self._n_accepted = 0
        self._queue: Optional[asyncio.Queue] = None
        self._lock: Optional[asyncio.Lock] = None
        self._task: Optional[asyncio.Task] = None
        self._error: Optional[BaseException] = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "StreamIngestionService":
        if self._task is not None:
            raise RuntimeError("service already started")
        self._queue = asyncio.Queue(self._max_pending)
        self._lock = asyncio.Lock()
        self._task = asyncio.create_task(self._worker())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop the worker; ``drain=True`` ingests queued elements first."""
        if self._task is None:
            return
        try:
            if drain:
                await self.drain()
        finally:  # a failed worker must still be cancelled, not leaked
            self._task.cancel()
            await asyncio.gather(self._task, return_exceptions=True)
            self._task = None

    async def __aenter__(self) -> "StreamIngestionService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc == (None, None, None))

    def _check(self):
        if self._task is None:
            raise RuntimeError("service not started (use 'async with' or "
                               "await start())")
        if self._error is not None:
            raise RuntimeError("ingestion worker failed") from self._error

    # -- producer side -------------------------------------------------------

    async def offer(self, x) -> int:
        """Enqueue one element; awaits (backpressure) while the queue is
        full. Returns the assigned stream id."""
        self._check()
        x = np.asarray(x, np.float32).reshape(self._dim)
        i = next(self._ids)
        await self._queue.put((i, x))
        self._n_offered += 1
        return i

    async def offer_batch(self, X: Sequence) -> list[int]:
        return [await self.offer(x) for x in np.asarray(X, np.float32)]

    async def drain(self) -> None:
        """Wait until every queued element has been ingested."""
        self._check()
        await self._queue.join()
        self._check()

    # -- consumer side -------------------------------------------------------

    async def snapshot(self) -> SieveSnapshot:
        """Best sieve right now — members, vectors, value, flow counters.

        Valid while running and after ``stop`` (the engine state persists)."""
        if self._lock is None:
            raise RuntimeError("service was never started")
        if self._error is not None:
            raise RuntimeError("ingestion worker failed") from self._error
        async with self._lock:
            members, value = await asyncio.to_thread(self._engine.best)
            live = await asyncio.to_thread(self._engine.member_ids)
            evals = self._engine.evaluations()
        keep = set(live)
        self._vecs = {i: v for i, v in self._vecs.items() if i in keep}
        exemplars = (np.stack([self._vecs[i] for i in members])
                     if members else np.zeros((0, self._dim), np.float32))
        return SieveSnapshot(
            indices=members, exemplars=exemplars, value=value,
            n_offered=self._n_offered, n_ingested=self._n_ingested,
            n_accepted=self._n_accepted, evaluations=evals,
            pending=self._queue.qsize())

    # -- worker --------------------------------------------------------------

    async def _worker(self) -> None:
        while True:
            batch = [await self._queue.get()]
            while len(batch) < self._block:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            try:
                if self._error is None:  # after a failure: drain-only, so
                    ids = np.fromiter(   # join() completes and _check raises
                        (i for i, _ in batch), np.int64, len(batch))
                    X = np.stack([x for _, x in batch])
                    async with self._lock:
                        accepted = await asyncio.to_thread(
                            self._engine.offer, ids, X)
                    for (i, x), acc in zip(batch, np.asarray(accepted)):
                        if acc:
                            self._vecs[i] = x
                            self._n_accepted += 1
                    self._n_ingested += len(batch)
            except asyncio.CancelledError:
                raise
            except BaseException as e:  # surface on the next offer/drain
                self._error = e
            finally:
                for _ in batch:
                    self._queue.task_done()
