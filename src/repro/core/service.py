"""Serving surfaces: streaming ingestion and batched selection requests.

Two async front ends live here:

* :class:`StreamIngestionService` — queue in, exemplars out, over the
  device-resident sieve engine (one scan dispatch per stream block).
* :class:`SelectionService` — many concurrent *selection* requests (each its
  own (V, k) problem), bucketed by jit signature and solved B-at-a-time
  through :func:`repro.core.engine.run_selection_batch` — ONE batched scan
  dispatch per bucket, per-request demux, results identical to the
  unbatched engine.

Streaming ingestion service: async queue in, exemplars out.

The companion Industry 4.0 deployment (Honysz et al., 2021) runs the sieve
family against live sensor streams; this module is that serving surface for
the device-resident sieve engine (:mod:`repro.core.streaming`). Producers
``offer`` arbitrary vectors (not ground-set indices — the ground set V is the
fixed *evaluation* reference the submodular function scores against);
a single worker drains the queue in blocks and feeds the engine one scan
dispatch per block; consumers ``snapshot`` the current best sieve at any
point of the stream.

Flow control:

* **Offer batching** — the worker takes whatever is queued (up to
  ``block_size``) per engine dispatch, so a burst of producers amortizes to
  one device round-trip per block while a trickle still gets per-element
  latency. Block boundaries cannot change results: sieve decisions are
  per-element sequential regardless of blocking.
* **Backpressure** — the queue is bounded by ``max_pending``; ``offer``
  awaits when the engine falls behind, propagating slow-down to producers
  instead of buffering without bound.
* **Snapshot consistency** — engine access is serialized by a lock shared
  between the worker and ``snapshot``, so a snapshot always observes a
  block-aligned engine state (never a half-applied block).

The engine itself is synchronous JAX; dispatches run in a thread
(``asyncio.to_thread``) so the event loop keeps accepting offers while the
device works.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import math
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contract
from repro.core.engine import OptResult
from repro.core.evaluator import EvalConfig
from repro.core.functions import FUNCTIONS, ExemplarClustering
from repro.core.streaming import make_batched_sieve_engine, make_sieve_engine


@dataclasses.dataclass
class SieveSnapshot:
    """Point-in-time view of the service's best sieve."""

    indices: list[int]      #: stream ids of the best sieve's members
    exemplars: np.ndarray   #: their vectors, (len(indices), dim)
    value: float            #: f-value of the best sieve
    n_offered: int          #: elements accepted into the queue so far
    n_ingested: int         #: elements the engine has consumed
    n_accepted: int         #: elements accepted by at least one sieve
    evaluations: int        #: engine-boundary evaluation count
    pending: int            #: elements still queued (backpressure depth)


class StreamIngestionService:
    """Async wrapper turning the sieve engine into a serving surface.

    Use as an async context manager::

        async with StreamIngestionService(f, k=8) as svc:
            for x in stream:
                await svc.offer(x)          # backpressure-aware
            snap = await svc.snapshot()     # current best exemplars

    Stream ids are assigned in ``offer`` order and are the ``indices`` the
    snapshot reports; the service retains accepted elements' vectors (pruned
    to the live member tables at snapshot time) so exemplars can be returned
    for elements that are not ground-set rows.
    """

    def __init__(self, f: ExemplarClustering, k: int, eps: float = 0.1,
                 variant: str = "sieve", mode: str = "device",
                 block_size: int = 64, s_max: Optional[int] = None,
                 max_pending: int = 1024, mesh=None,
                 data_axes: Sequence[str] = ("data",),
                 overlap: bool = True):
        # ``mesh`` / ``mode="device_sharded"`` wrap the mesh-sharded engine:
        # the cache table shards, but the member slots / sizes / active mask
        # a snapshot reads are replicated table state, so ``snapshot`` still
        # gathers the best sieve's members ONCE — not per shard
        self._engine = make_sieve_engine(f, k, eps, variant=variant,
                                         mode=mode, s_max=s_max,
                                         block_size=block_size, mesh=mesh,
                                         data_axes=data_axes,
                                         overlap=overlap)
        self._dim = f.dim
        self._block = block_size
        self._max_pending = max_pending
        self._ids = itertools.count()
        self._vecs: dict[int, np.ndarray] = {}
        self._n_offered = 0
        self._n_ingested = 0
        self._n_accepted = 0
        self._queue: Optional[asyncio.Queue] = None
        self._sem: Optional[asyncio.Semaphore] = None
        self._lock: Optional[asyncio.Lock] = None
        self._task: Optional[asyncio.Task] = None
        self._error: Optional[BaseException] = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "StreamIngestionService":
        if self._task is not None:
            raise RuntimeError("service already started")
        # Backpressure lives in the semaphore, not the queue: ``offer``
        # suspends on acquire() BEFORE any state is touched, so a producer
        # cancelled mid-wait leaves no assigned id and no counter bump.
        self._queue = asyncio.Queue()
        self._sem = asyncio.Semaphore(self._max_pending)
        self._lock = asyncio.Lock()
        self._task = asyncio.create_task(self._worker())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop the worker; ``drain=True`` ingests queued elements first."""
        if self._task is None:
            return
        try:
            if drain:
                await self.drain()
        finally:  # a failed worker must still be cancelled, not leaked
            self._task.cancel()
            await asyncio.gather(self._task, return_exceptions=True)
            self._task = None

    async def __aenter__(self) -> "StreamIngestionService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc == (None, None, None))

    def _check(self):
        if self._task is None:
            raise RuntimeError("service not started (use 'async with' or "
                               "await start())")
        if self._error is not None:
            raise RuntimeError("ingestion worker failed") from self._error

    # -- producer side -------------------------------------------------------

    async def offer(self, x) -> int:
        """Enqueue one element; awaits (backpressure) while the queue is
        full. Returns the assigned stream id."""
        self._check()
        x = np.asarray(x, np.float32).reshape(self._dim)
        await self._sem.acquire()   # only suspension point — see start()
        i = next(self._ids)
        self._n_offered += 1
        self._queue.put_nowait((i, x))
        return i

    async def offer_batch(self, X: Sequence) -> list[int]:
        return [await self.offer(x) for x in np.asarray(X, np.float32)]

    async def drain(self) -> None:
        """Wait until every queued element has been ingested."""
        self._check()
        await self._queue.join()
        self._check()

    # -- consumer side -------------------------------------------------------

    async def snapshot(self) -> SieveSnapshot:
        """Best sieve right now — members, vectors, value, flow counters.

        Valid while running and after ``stop`` (the engine state persists)."""
        if self._lock is None:
            raise RuntimeError("service was never started")
        if self._error is not None:
            raise RuntimeError("ingestion worker failed") from self._error
        async with self._lock:
            # Read, prune and gather in ONE thread hop while holding the
            # engine lock: the live-member set, the retention map and the
            # flow counters are all observed against the same block-aligned
            # engine state. Pruning outside the lock used a stale live set —
            # a vector accepted by a concurrent worker block could be
            # deleted, and the next snapshot's gather raised KeyError.
            (members, value, evals, exemplars, offered, ingested,
             accepted) = await asyncio.to_thread(self._snapshot_sync)
        return SieveSnapshot(
            indices=members, exemplars=exemplars, value=value,
            n_offered=offered, n_ingested=ingested,
            n_accepted=accepted, evaluations=evals,
            pending=self._queue.qsize())

    def _snapshot_sync(self):
        """Consistent read of engine + retention state (runs in a thread,
        under the engine lock; blocks only on the gathered members)."""
        members, value = self._engine.best()
        keep = set(self._engine.member_ids())
        self._vecs = {i: v for i, v in self._vecs.items() if i in keep}
        exemplars = (np.stack([self._vecs[i] for i in members])
                     if members else np.zeros((0, self._dim), np.float32))
        return (members, value, self._engine.evaluations(), exemplars,
                self._n_offered, self._n_ingested, self._n_accepted)

    # -- worker --------------------------------------------------------------

    async def _worker(self) -> None:
        while True:
            batch = [await self._queue.get()]
            while len(batch) < self._block:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            try:
                if self._error is None:  # after a failure: drain-only, so
                    async with self._lock:  # join() completes, _check raises
                        # ONE thread hop covers engine mutation AND the
                        # retention-map/counter writes. A to_thread await
                        # that gets cancelled still runs its thread to
                        # completion, so the engine cannot end up holding
                        # accepted members whose vectors were never
                        # retained (KeyError at the next snapshot gather).
                        await asyncio.to_thread(self._ingest, batch)
            except asyncio.CancelledError:
                raise
            except BaseException as e:  # surface on the next offer/drain
                self._error = e
            finally:
                for _ in batch:
                    self._queue.task_done()
                    self._sem.release()

    def _ingest(self, batch) -> None:
        """Synchronous block ingest: dispatch + retention, one atomic unit
        with respect to both the engine lock and task cancellation."""
        ids = np.fromiter((i for i, _ in batch), np.int64, len(batch))
        X = np.stack([x for _, x in batch])
        accepted = self._engine.offer(ids, X)
        for (i, x), acc in zip(batch, np.asarray(accepted)):
            if acc:
                self._vecs[i] = x
                self._n_accepted += 1
        self._n_ingested += len(batch)

# ---------------------------------------------------------------------------
# Multi-stream ingestion: P partitions, one batched dispatch, two-tier merge
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiStreamSnapshot:
    """Point-in-time view across all stream partitions plus the merge tier.

    ``indices``/``exemplars``/``value`` describe the MERGED selection — the
    per-partition exemplar sets re-streamed through a second sieve
    (SieveStreaming composability: each partition's member set is a subset
    of the merge stream with ≤ k elements, so the merged sieve's
    (1/2−ε)·OPT guarantee over the union implies
    ``value ≥ (1/2−ε)·max_p stream_values[p]`` — the runtime certificate
    ``certified`` checks, with ``bound`` the certified floor).
    """

    indices: list[int]          #: merged best members (global stream ids)
    exemplars: np.ndarray       #: their vectors, (len(indices), dim)
    value: float                #: f-value of the merged best sieve
    stream_values: list[float]  #: per-partition best-sieve values
    stream_members: list[list[int]]  #: per-partition best-sieve members
    bound: float                #: (1/2−ε)·max_p stream_values[p]
    certified: bool             #: value ≥ bound (float32 tolerance)
    n_offered: int
    n_ingested: int
    n_accepted: int
    evaluations: int            #: partition-engine evals (merge excluded)
    pending: int


class MultiStreamIngestionService:
    """Many concurrent stream partitions behind ONE batched sieve dispatch.

    The aggregate serving surface: producers ``offer(x, stream=p)`` into P
    independent logical streams (omitting ``stream`` round-robins by
    assigned id); a single worker drains the shared queue, groups elements
    by partition, and advances ALL partitions' sieve tables with one
    :class:`repro.core.streaming.BatchedSieveEngine` dispatch per block.
    ``snapshot`` reports each partition's best sieve AND a two-tier merge:
    the per-partition exemplars re-streamed through a second sieve, with
    the certified ``(1/2−ε)``-composed bound (see
    :class:`MultiStreamSnapshot`).

    Concurrency discipline is :class:`StreamIngestionService`'s: semaphore
    backpressure with atomic id assignment, one thread hop per ingest
    (engine mutation + retention writes cancellation-atomic), snapshots
    reading engine + retention state under the lock.
    """

    def __init__(self, f: ExemplarClustering, k: int, n_streams: int,
                 eps: float = 0.1, variant: str = "sieve",
                 block_size: int = 32, s_max: Optional[int] = None,
                 max_pending: int = 4096, overlap: bool = True):
        self._engine = make_batched_sieve_engine(
            f, k, eps, n_streams, variant=variant, s_max=s_max,
            block_size=block_size, overlap=overlap)
        self._f = f
        self._k = k
        self._eps = float(eps)
        self._variant = variant
        self._dim = f.dim
        self._P = int(n_streams)
        self._block = block_size
        self._max_pending = max_pending
        self._ids = itertools.count()
        self._vecs: dict[int, np.ndarray] = {}
        self._n_offered = 0
        self._n_ingested = 0
        self._n_accepted = 0
        # the merge tier: a fresh single-stream sieve per snapshot would
        # retrace per ragged merge length; ONE lazily-built device engine
        # shape (fixed block) is reused and re-initialized instead
        self._merge_block = 32
        self._queue: Optional[asyncio.Queue] = None
        self._sem: Optional[asyncio.Semaphore] = None
        self._lock: Optional[asyncio.Lock] = None
        self._task: Optional[asyncio.Task] = None
        self._error: Optional[BaseException] = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "MultiStreamIngestionService":
        if self._task is not None:
            raise RuntimeError("service already started")
        self._queue = asyncio.Queue()
        self._sem = asyncio.Semaphore(self._max_pending)
        self._lock = asyncio.Lock()
        self._task = asyncio.create_task(self._worker())
        return self

    async def stop(self, drain: bool = True) -> None:
        if self._task is None:
            return
        try:
            if drain:
                await self.drain()
        finally:
            self._task.cancel()
            await asyncio.gather(self._task, return_exceptions=True)
            self._task = None

    async def __aenter__(self) -> "MultiStreamIngestionService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc == (None, None, None))

    def _check(self):
        if self._task is None:
            raise RuntimeError("service not started (use 'async with' or "
                               "await start())")
        if self._error is not None:
            raise RuntimeError("ingestion worker failed") from self._error

    # -- producer side -------------------------------------------------------

    async def offer(self, x, stream: Optional[int] = None) -> int:
        """Enqueue one element into partition ``stream`` (default:
        round-robin by assigned id). Returns the global stream id."""
        self._check()
        x = np.asarray(x, np.float32).reshape(self._dim)
        if stream is not None and not 0 <= stream < self._P:
            raise ValueError(
                f"stream must lie in [0, {self._P}), got {stream}")
        await self._sem.acquire()   # only suspension point (see offer above)
        i = next(self._ids)
        self._n_offered += 1
        p = i % self._P if stream is None else int(stream)
        self._queue.put_nowait((p, i, x))
        return i

    async def drain(self) -> None:
        self._check()
        await self._queue.join()
        self._check()

    # -- consumer side -------------------------------------------------------

    async def snapshot(self) -> MultiStreamSnapshot:
        """Per-partition bests + the two-tier merged selection, consistent
        against one block-aligned engine state."""
        if self._lock is None:
            raise RuntimeError("service was never started")
        if self._error is not None:
            raise RuntimeError("ingestion worker failed") from self._error
        async with self._lock:
            snap = await asyncio.to_thread(self._snapshot_sync)
        snap.pending = self._queue.qsize()
        return snap

    def _snapshot_sync(self) -> MultiStreamSnapshot:
        bests = self._engine.best_all()
        keep = set(self._engine.member_ids())
        self._vecs = {i: v for i, v in self._vecs.items() if i in keep}
        evals = self._engine.evaluations()
        merged, value = self._merge(bests)
        exemplars = (np.stack([self._vecs[i] for i in merged])
                     if merged else np.zeros((0, self._dim), np.float32))
        peak = max((v for _, v in bests), default=0.0)
        bound = (0.5 - self._eps) * peak
        tol = 1e-5 * max(abs(value), abs(bound), 1e-30)
        return MultiStreamSnapshot(
            indices=merged, exemplars=exemplars, value=value,
            stream_values=[v for _, v in bests],
            stream_members=[m for m, _ in bests],
            bound=bound, certified=bool(value >= bound - tol),
            n_offered=self._n_offered, n_ingested=self._n_ingested,
            n_accepted=self._n_accepted, evaluations=evals, pending=0)

    def _merge(self, bests) -> tuple[list[int], float]:
        """Two-tier merge: stream the union of per-partition exemplars
        through a second sieve. Every partition's member set is ≤ k elements
        of the merge stream, so SieveStreaming's (1/2−ε)·OPT guarantee over
        the union certifies value ≥ (1/2−ε)·max_p value_p at runtime."""
        ids = [i for members, _ in bests for i in members]
        if not ids:
            return [], 0.0
        vecs = np.stack([self._vecs[i] for i in ids])
        eng = make_sieve_engine(
            self._f, self._k, self._eps, variant=self._variant,
            mode="device", block_size=self._merge_block, overlap=False)
        eng.offer(np.asarray(ids, np.int64), vecs)
        return eng.best()

    # -- worker --------------------------------------------------------------

    async def _worker(self) -> None:
        budget = self._P * self._block
        while True:
            batch = [await self._queue.get()]
            while len(batch) < budget:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            try:
                if self._error is None:
                    async with self._lock:
                        await asyncio.to_thread(self._ingest, batch)
            except asyncio.CancelledError:
                raise
            except BaseException as e:
                self._error = e
            finally:
                for _ in batch:
                    self._queue.task_done()
                    self._sem.release()

    def _ingest(self, batch) -> None:
        """Group the drained batch by partition and advance ALL partitions
        with the batched engine (ONE dispatch per block row). Runs in a
        thread under the lock — cancellation-atomic like the single-stream
        service's ingest."""
        parts: list[list] = [[] for _ in range(self._P)]
        for p, i, x in batch:
            parts[p].append((i, x))
        idxs = [np.asarray([i for i, _ in part], np.int64)
                for part in parts]
        Xs = [np.stack([x for _, x in part]) if part
              else np.zeros((0, self._dim), np.float32) for part in parts]
        masks = self._engine.offer(idxs, Xs)
        for p in range(self._P):
            for (i, x), acc in zip(parts[p], masks[p]):
                if acc:
                    self._vecs[i] = x
                    self._n_accepted += 1
        self._n_ingested += len(batch)


# ---------------------------------------------------------------------------
# Batched selection serving
# ---------------------------------------------------------------------------


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def _stochastic_samples(n: int, k: int, eps: float, seed: int) -> np.ndarray:
    """Per-round candidate samples, bit-identical to
    :func:`repro.core.optimizers.stochastic_greedy`'s draw so a served
    stochastic request returns exactly what the direct call would."""
    rng = np.random.default_rng(seed)
    m = min(n, int(math.ceil(n / k * math.log(1.0 / eps))))
    m_draw = min(n, m + k)
    return np.stack(
        [rng.choice(n, size=m_draw, replace=False) for _ in range(k)])


@dataclasses.dataclass
class _SelectionRequest:
    """One queued tenant request plus the future its result resolves."""

    X: np.ndarray           #: (n, d) ground set, float32
    k: int
    fn: str
    params: tuple           #: sorted (name, value) extra function kwargs
    kind: str               #: "dense" | "stochastic" | "lazy"
    seed: int               #: stochastic sampling seed (per request)
    eps: float              #: stochastic sampling rate
    top_b: int              #: lazy re-score width
    future: asyncio.Future = dataclasses.field(repr=False)

    def signature(self) -> tuple:
        """Jit-signature bucket key — requests sharing it can ride one
        batched dispatch.

        Dense and lazy bucket by ``next_pow2(k)`` (the scan length is
        padded up and ragged k is masked per request), so k=3 and k=4
        tenants share a warm jit cache entry. Stochastic buckets by EXACT
        (k, eps): the per-round sample width m depends on both, so they
        enter the dispatch shape. Seeds do NOT enter the key — samples are
        per-request payload, not signature.
        """
        n, d = self.X.shape
        if self.kind == "stochastic":
            k_sig: tuple = ("exact", self.k, self.eps)
        else:
            k_sig = ("pow2", _next_pow2(self.k))
        return (n, d, self.fn, self.params, self.kind, k_sig, self.top_b)


class SelectionService:
    """Multi-tenant selection front end: many concurrent (V, k) requests,
    one batched engine dispatch per signature bucket.

    Use as an async context manager::

        async with SelectionService(cfg, max_batch=64) as svc:
            results = await asyncio.gather(
                *[svc.submit(X_t, k=4) for X_t in tenants])

    Request lifecycle: ``submit`` validates + enqueues (awaiting while the
    bounded queue is full — backpressure), the worker drains whatever is
    queued, groups requests by jit signature (:meth:`_SelectionRequest.\
    signature`), pads each bucket's batch up to a power of two with inert
    ``k_eff=0`` slots, runs ONE :func:`repro.core.engine.\
    run_selection_batch` dispatch per bucket in a thread, and demuxes
    per-request :class:`~repro.core.engine.OptResult`\\ s back through the
    futures. Results are identical to per-request ``run_selection`` /
    ``stochastic_greedy`` calls — batching changes throughput, not output.
    """

    def __init__(self, cfg: Optional[EvalConfig] = None, *,
                 max_batch: int = 64, max_pending: int = 1024,
                 linger_s: float = 0.0, plan: str = "device",
                 mesh=None, data_axes: Sequence[str] = ("data",)):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if plan not in ("device", "device_sharded", "device_sharded_pool"):
            raise ValueError(
                f"unknown batched execution plan {plan!r}; the service "
                f"serves 'device', 'device_sharded' or 'device_sharded_pool'")
        self._cfg = cfg if cfg is not None else EvalConfig()
        self._max_batch = max_batch
        self._max_pending = max_pending
        self._linger_s = linger_s
        # ``plan``/``mesh``: same-signature buckets dispatch ONCE across all
        # mesh devices on the sharded plans — state is (B, n/p) per device
        self._plan = plan
        self._mesh = mesh
        self._data_axes = tuple(data_axes)
        #: dispatches: batched engine calls issued; batched_requests: live
        #: requests they carried; padded_slots: inert k_eff=0 fill (the
        #: amortization ratio is batched_requests / dispatches);
        #: staged_buckets: dispatches whose padded stacks were device-put
        #: WHILE the previous bucket's dispatch ran (issue-and-go overlap).
        self.stats = {"requests": 0, "dispatches": 0,
                      "batched_requests": 0, "padded_slots": 0,
                      "staged_buckets": 0}
        self._queue: Optional[asyncio.Queue] = None
        self._task: Optional[asyncio.Task] = None
        self._error: Optional[BaseException] = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "SelectionService":
        if self._task is not None:
            raise RuntimeError("service already started")
        self._queue = asyncio.Queue(self._max_pending)
        self._task = asyncio.create_task(self._worker())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop the worker; ``drain=True`` serves queued requests first."""
        if self._task is None:
            return
        try:
            if drain and self._error is None:
                await self._queue.join()
        finally:
            self._task.cancel()
            await asyncio.gather(self._task, return_exceptions=True)
            self._task = None

    async def __aenter__(self) -> "SelectionService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc == (None, None, None))

    def _check(self):
        if self._task is None:
            raise RuntimeError("service not started (use 'async with' or "
                               "await start())")
        if self._error is not None:
            raise RuntimeError("selection worker failed") from self._error

    # -- producer side -------------------------------------------------------

    async def submit(self, X, k: int, *, fn: str = "exemplar",
                     kind: str = "dense", seed: int = 0, eps: float = 0.05,
                     top_b: int = 0, **params):
        """Submit one selection request; awaits until served.

        Returns the request's :class:`~repro.core.engine.OptResult`.
        ``params`` are extra function-constructor kwargs (e.g. ``lam`` for
        graph_cut) and enter the bucket signature.
        """
        self._check()
        if kind not in ("dense", "stochastic", "lazy"):
            raise ValueError(f"unknown strategy kind {kind!r}")
        if fn not in FUNCTIONS:
            raise ValueError(f"unknown function {fn!r}; registered: "
                             f"{sorted(FUNCTIONS)}")
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"X must be (n, d), got shape {X.shape}")
        if not 0 <= k <= X.shape[0]:
            raise ValueError(
                f"cannot select k={k} exemplars from n={X.shape[0]}")
        if k == 0:
            self.stats["requests"] += 1
            return OptResult([], 0.0, [], 0)
        req = _SelectionRequest(
            X=X, k=int(k), fn=fn, params=tuple(sorted(params.items())),
            kind=kind, seed=int(seed), eps=float(eps), top_b=int(top_b),
            future=asyncio.get_running_loop().create_future())
        await self._queue.put(req)      # backpressure point
        self.stats["requests"] += 1
        try:
            return await req.future
        finally:
            self._check()

    # -- worker --------------------------------------------------------------

    async def _worker(self) -> None:
        while True:
            batch = [await self._queue.get()]
            if self._linger_s > 0:      # let a burst accumulate
                await asyncio.sleep(self._linger_s)
            while True:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            try:
                buckets: dict[tuple, list[_SelectionRequest]] = {}
                for req in batch:
                    buckets.setdefault(req.signature(), []).append(req)
                chunks = [reqs[lo:lo + self._max_batch]
                          for reqs in buckets.values()
                          for lo in range(0, len(reqs), self._max_batch)]
                # Issue-and-go (PR 9's ingestion overlap, applied to
                # serving): dispatch the current bucket as a task, then
                # stage the NEXT bucket's padded stacks (host stacking +
                # jax.device_put — async on accelerators) in a second
                # thread while that dispatch occupies the device. The
                # dispatches themselves stay strictly sequential.
                staged = None
                for i, chunk in enumerate(chunks):
                    serving = asyncio.create_task(
                        self._serve_bucket(chunk, staged))
                    staged = None
                    if i + 1 < len(chunks):
                        try:
                            staged = await asyncio.to_thread(
                                self._stage_bucket, chunks[i + 1])
                        except asyncio.CancelledError:
                            raise
                        except BaseException:
                            staged = None  # staging is an optimization:
                            # the serve path rebuilds inline on fallback
                    await serving
            except asyncio.CancelledError:
                raise
            except BaseException as e:  # worker-level fault: fail fast
                self._error = e
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(e)
            finally:
                for _ in batch:
                    self._queue.task_done()

    async def _serve_bucket(self, reqs: list["_SelectionRequest"],
                            staged: Optional[dict] = None) -> None:
        try:
            results = await asyncio.to_thread(self._run_bucket, reqs, staged)
        except asyncio.CancelledError:
            raise
        except BaseException as e:      # bucket-level fault: this bucket's
            for req in reqs:            # tenants see it; others proceed
                if not req.future.done():
                    req.future.set_exception(e)
            return
        for req, res in zip(reqs, results):
            if not req.future.done():
                req.future.set_result(res)

    def _build_bucket(self, reqs: list["_SelectionRequest"]):
        """Deterministic bucket assembly: padded function stack, ragged ks,
        per-request stochastic samples, scan length. Shared by the inline
        dispatch path and the ahead-of-dispatch staging path (the seeded
        sample draw makes both produce identical payloads)."""
        r0 = reqs[0]
        n = r0.X.shape[0]
        fs = [FUNCTIONS[r.fn](jnp.asarray(r.X), self._cfg,
                              **dict(r.params)) for r in reqs]
        ks = [r.k for r in reqs]
        pad = min(self._max_batch, _next_pow2(len(reqs))) - len(reqs)
        fs += [fs[0]] * pad                    # inert slots: k_eff = 0
        ks += [0] * pad
        cand = None
        if r0.kind == "stochastic":
            k_scan = r0.k                      # exact-k bucket
            rows = [_stochastic_samples(n, r.k, r.eps, r.seed)
                    for r in reqs]
            cand = np.stack(rows + [rows[0]] * pad)
        else:
            k_scan = _next_pow2(max(ks))       # ragged k, padded scan
        return fs, ks, cand, k_scan, pad

    def _stage_bucket(self, reqs: list["_SelectionRequest"]) -> dict:
        """Assemble one bucket and issue its host→device transfers (runs in
        a thread while the PREVIOUS bucket's dispatch holds the device)."""
        from repro.core import engine as eng
        fs, ks, cand, k_scan, pad = self._build_bucket(reqs)
        payload = eng.stage_selection_batch(
            fs, plan=self._plan, mesh=self._mesh,
            data_axes=self._data_axes)
        return {"reqs": reqs, "fs": fs, "ks": ks, "cand": cand,
                "k_scan": k_scan, "pad": pad, "payload": payload}

    @contract(
        "service.bucket_dispatch",
        runtime_only=True,
        claim="every signature bucket rides ONE run_selection_batch "
              "dispatch (pow2-padded with inert k_eff=0 slots); the traced "
              "artifact is engine.select_scan_batched's, audited there — "
              "this contract's own check is the runtime service round trip "
              "(N concurrent tenants, 1 trace, bucket-count dispatches)")
    def _run_bucket(self, reqs: list["_SelectionRequest"],
                    staged: Optional[dict] = None):
        """Synchronous batched dispatch for one signature bucket (runs in a
        thread; JAX work must not block the event loop). ``staged`` is a
        payload :meth:`_stage_bucket` pre-transferred for exactly these
        requests; anything else rebuilds inline."""
        from repro.core import engine as eng
        r0 = reqs[0]
        if staged is not None and staged["reqs"] is reqs:
            fs, ks, cand, k_scan, pad = (staged["fs"], staged["ks"],
                                         staged["cand"], staged["k_scan"],
                                         staged["pad"])
            payload = staged["payload"]
            self.stats["staged_buckets"] += 1
        else:
            fs, ks, cand, k_scan, pad = self._build_bucket(reqs)
            payload = None
        res = eng.run_selection_batch(
            fs, kind=r0.kind, k=k_scan, ks=ks, cand_rounds=cand,
            top_b=r0.top_b, counter_key=f"serve_{r0.kind}",
            plan=self._plan, mesh=self._mesh, data_axes=self._data_axes,
            staged=payload)
        self.stats["dispatches"] += 1
        self.stats["batched_requests"] += len(reqs)
        self.stats["padded_slots"] += pad
        return res[:len(reqs)]
