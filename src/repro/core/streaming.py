"""Device-resident streaming sieve engine (tentpole, beyond paper).

The sieve family (SieveStreaming [4], SieveStreaming++ [19], Salsa [20])
maintains a *grid* of threshold sieves τ = (1+ε)^i and offers every arriving
stream element to all of them. After PR 1–2 moved the greedy family on device,
the sieves were the last optimizer class whose inner loop lived on host:
one Python/numpy accept decision per element per block. This module replaces
that loop with a device-resident engine: the per-sieve state lives on the
accelerator and each stream block of B elements is consumed by ONE jitted
``jax.lax.scan`` over elements — singleton gain, grid rebuild, per-sieve
accept rule, cache fold, and member bookkeeping all in the scan body.

Design: the **fixed-capacity sieve table**. Grid growth (a new max singleton
widens the threshold window) is shape-dynamic on host but must be shape-static
on device, so — the same way PR 2 turned CELF's heap into carried stale
bounds — the dynamic sieve collection becomes a table of ``S_max`` slots:

* Sieves are keyed by the **integer exponent** i of their threshold
  τ = (1+ε)^i (never by float equality of τ — the former
  ``if t not in have`` float dedupe could duplicate or miss a sieve when
  ``(1+eps)**i`` round-tripped differently across rebuilds).
* Exponent i lives in slot ``i mod S_max``. The live window
  [i_lo, i_hi] = [⌈log m / log(1+ε)⌉, ⌊log(2km) / log(1+ε)⌋] has width
  ≤ log(2k)/log(1+ε) + 1 independent of the stream, so with
  ``S_max ≥ width + 2`` every live exponent owns a distinct slot.
* A grid "rebuild" is a **masked activation**: slots whose assigned exponent
  changed are reset (cache ← seed, size ← 0, members ← −1) in-place inside
  the scan body; slots whose exponent survives keep their state — exactly the
  host semantics of dropping below-window sieves and adding new ones.
* Salsa's grid is grow-only (old sieves are never dropped), so its exponent
  span is stream-dependent; its capacity default adds headroom, and when the
  span does exceed ``S_max`` the slot collision evicts the lowest (stalest)
  exponent — a well-defined capacity rule the host mirror shares, so parity
  holds by construction even under eviction.

Function generality: the table rows carry whatever (n,)-vec cache the
objective's :mod:`repro.core.functions` protocol defines — the element step
reads gains through :func:`~repro.core.functions.sieve_gain_rows`, folds
accepts through :func:`~repro.core.functions.sieve_fold_rows`, and values
sieves through ``stat_rows``/``value_from_stat``, so one table definition
serves every :data:`~repro.core.functions.SIEVE_ELIGIBLE` objective
(exemplar's min-cache, facility location's max-cache dual, saturated
coverage's capped-sum cache). The sieve math itself (grid exponents,
thresholds, accept rules) only assumes monotone gains, which eligibility
guarantees. Graph cut is excluded: its gain needs the winner-indexed
redundancy penalty, which a stream element's cache rows alone cannot carry.

Parity: :func:`_element_step` is the ONE definition of the per-element
transition, written in pure ``jax.numpy``. The host mirror jits it per
element (the honest per-element dispatch round-trip the device engine
replaces); the device engine runs the identical function inside the per-block
scan. On kernel backends (``SieveSpec.backend``) the step's gains route
through the fused table × element Pallas kernel
(:func:`repro.kernels.ops.sieve_gains`) under the function's min/max
template — in BOTH plans, so the parity argument is unchanged. Both consume
distance rows from the same ``point_distances_block`` executable, so host
and device see bitwise-identical inputs and — all float reductions being the
same HLO — make identical accept decisions, select identical members, and
report identical evaluation counts.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contract
from repro.core import functions as fx
from repro.core.engine import DEVICE_TRACE_COUNTS
from repro.core.functions import FnSpec

VARIANTS = ("sieve", "pp", "salsa")

#: Slot-exponent value meaning "never assigned" — far below any reachable
#: grid exponent (f32 singleton values bound |i| ≲ 1000 for ε ≥ 1e-3).
_EXP_UNSET = -(1 << 30)


class SieveSpec(NamedTuple):
    """Static (hashable → jit-static) configuration of a sieve table."""

    k: int
    eps: float
    s_max: int
    variant: str        # "sieve" | "pp" | "salsa"
    log1p_eps: float    # np.float32(log1p(eps)) — the ONE grid-log constant
    #: scoring backend for the element step's gains: "jnp" runs the plain
    #: (S_max, n) protocol reduction; "pallas"/"pallas_interpret" run the
    #: fused table × element kernel (:func:`repro.kernels.ops.sieve_gains`)
    #: under the function's min/max template. Part of the spec (not the
    #: engine) so the host mirror and the device scan share ONE definition
    #: per backend — parity by construction either way.
    backend: str = "jnp"
    #: the submodular objective the table rows cache — must be
    #: :data:`~repro.core.functions.SIEVE_ELIGIBLE`.
    fn: FnSpec = FnSpec()


class SieveState(NamedTuple):
    """Device-resident state of the fixed-capacity sieve table.

    Inactive slots carry stale arrays; every consumer masks with ``active``.
    ``members`` rows are stream ids in arrival order, -1 beyond ``sizes``.
    """

    caches: jax.Array    # (S_max, n) f32 per-sieve cache rows (fn semantics)
    slot_exp: jax.Array  # (S_max,) i32 threshold exponent i (τ = (1+ε)^i)
    active: jax.Array    # (S_max,) bool
    sizes: jax.Array     # (S_max,) i32 member counts
    members: jax.Array   # (S_max, k) i32 member slots
    m_seen: jax.Array    # () f32 max singleton gain seen
    lb: jax.Array        # () f32 best-value lower bound (pp only)
    evals: jax.Array     # () i32 engine-boundary evaluation count


def make_spec(k: int, eps: float, variant: str,
              s_max: Optional[int] = None,
              backend: str = "jnp",
              fn: FnSpec = FnSpec()) -> SieveSpec:
    if variant not in VARIANTS:
        raise ValueError(f"unknown sieve variant {variant!r}; one of {VARIANTS}")
    if k < 1:
        raise ValueError(f"sieve streaming needs k >= 1, got k={k}")
    if not 0.0 < eps < 1.0:
        raise ValueError(f"eps must lie in (0, 1), got {eps}")
    if backend not in ("jnp", "pallas", "pallas_interpret"):
        raise ValueError(
            f"unknown sieve backend {backend!r}; "
            f"'jnp', 'pallas' or 'pallas_interpret'")
    if fn.name not in fx.SIEVE_ELIGIBLE:
        raise ValueError(
            f"function {fn.name!r} is not sieve-streamable — threshold "
            f"sieves need monotone gains from the cache rows alone; "
            f"eligible: {sorted(fx.SIEVE_ELIGIBLE)}")
    if backend != "jnp" and fx.kernel_template(fn) is None:
        # no kernel form (saturated coverage's capped gain): the engine is
        # still valid, the step just scores through the jnp protocol path —
        # the same silent normalization the selection engine applies
        backend = "jnp"
    cap = s_max if s_max is not None else default_capacity(k, eps, variant)
    width = grid_width_bound(k, eps)
    if cap < width + 2:
        raise ValueError(
            f"s_max={cap} cannot hold the live threshold window "
            f"(width ≤ {width}, +2 slack required)")
    return SieveSpec(k, float(eps), int(cap), variant,
                     float(np.float32(np.log1p(np.float32(eps)))), backend,
                     fn)


def grid_width_bound(k: int, eps: float) -> int:
    """Max #live exponents in [⌈log m/L⌉, ⌊log 2km/L⌋]: ⌊log(2k)/L⌋ + 1."""
    return int(math.floor(math.log(2 * k) / math.log1p(eps))) + 1


def default_capacity(k: int, eps: float, variant: str) -> int:
    """Slot capacity: the live-window bound plus slack; Salsa's grow-only
    grid gets headroom for a 16x max-singleton drift before the capacity
    eviction rule starts firing."""
    cap = grid_width_bound(k, eps) + 2
    if variant == "salsa":
        cap += int(math.ceil(math.log(16.0) / math.log1p(eps)))
    return max(cap, 4)


def init_state(n: int, spec: SieveSpec) -> SieveState:
    """Zeroed table. Cache rows are dead until a slot's first claim resets
    them to the function's seed, so the init value never reaches a live
    gain or accept — zeros keep the born-sharded layout trivial."""
    S, k = spec.s_max, spec.k
    return SieveState(
        caches=jnp.zeros((S, n), jnp.float32),
        slot_exp=jnp.full((S,), _EXP_UNSET, jnp.int32),
        active=jnp.zeros((S,), bool),
        sizes=jnp.zeros((S,), jnp.int32),
        members=jnp.full((S, k), -1, jnp.int32),
        m_seen=jnp.float32(0.0),
        lb=jnp.float32(0.0),
        evals=jnp.int32(0),
    )


def _element_step(spec: SieveSpec, seed, v0, state: SieveState, idx, dvec,
                  valid, *, row_aux, mean_rows=None, table_gains=None):
    """The per-element sieve-table transition — ONE definition, pure jnp.

    The host mirror jits this per element; the device engine scans it per
    block. ``valid=False`` (block padding) makes the whole step a no-op.
    ``seed``/``v0``/``row_aux`` are the function's empty-set cache row, its
    empty-set baseline value, and its static per-row auxiliary (saturation
    caps). Returns ``(new_state, accepted_anywhere)``.

    The two optional callbacks are the step's only reductions over the
    ground-set axis, injectable so the mesh-sharded engine can run the
    *identical* transition on (S_max, n/p) cache shards: ``mean_rows(M)``
    is the trailing-axis mean (sharded: per-shard row sums psum'd and
    normalized by the global n — exactly how selection gains shard) and
    ``table_gains(table, dvec)`` the kernel-backend fused table × element
    gain under the function's min/max template (sharded:
    :func:`repro.kernels.ops.sieve_gains` with the global ``n_total``
    normalizer, partials psum'd). Defaults are the single-device
    reductions. Everything else in the step — thresholds, slot bookkeeping,
    member tables — is O(S_max)/O(k) state that stays replicated.
    """
    k, S = spec.k, spec.s_max
    fn = spec.fn
    L = spec.log1p_eps
    caches, slot_exp, active, sizes, members, m_seen, lb, evals = state
    if mean_rows is None:
        mean_rows = lambda M: jnp.mean(M, axis=-1)  # noqa: E731

    def values_of(table):
        return fx.value_from_stat(
            fn, v0, mean_rows(fx.stat_rows(fn, table, row_aux)))

    # singleton gain Δ(e | ∅) — the grid anchor m = max singleton seen.
    # Kernel backends score the whole table in ONE fused pass up front:
    # row 0 is the seed (the empty-set cache, whose gain IS the singleton),
    # rows 1: are the pre-rebuild sieve caches. A slot the rebuild below
    # claims is reset to exactly the seed, so its post-rebuild gain is the
    # singleton — ``where(claim, single, ...)`` recovers the post-rebuild
    # gains without a second kernel pass.
    use_kernel = spec.backend != "jnp"
    if use_kernel:
        if table_gains is None:
            from repro.kernels import ops as kops

            tmpl = fx.kernel_template(fn)
            table_gains = partial(
                kops.sieve_gains, fold=tmpl[0], score_affine=tmpl[1],
                interpret=(spec.backend != "pallas"))

        g_all = table_gains(
            jnp.concatenate([seed[None, :], caches], axis=0), dvec)
        single, gains_pre = g_all[0], g_all[1:]
    else:
        single = mean_rows(
            fx.sieve_gain_rows(fn, seed[None, :], dvec, row_aux))[0]
    new_max = valid & (single > m_seen)
    m_seen = jnp.where(new_max, single, m_seen)

    # grid rebuild: SieveStreaming/Salsa rebuild only on a new max; ++
    # re-derives its window every element because LB moves after accepts
    if spec.variant == "pp":
        rebuild = valid & (m_seen > 0.0)
        lo = jnp.maximum(lb, m_seen)
    else:
        rebuild = new_max
        lo = m_seen
    tiny = jnp.float32(1e-38)  # log(0) guard; rebuild is False while m=0
    i_lo = jnp.ceil(jnp.log(jnp.maximum(lo, tiny)) / L).astype(jnp.int32)
    i_hi = jnp.floor(
        jnp.log(jnp.maximum(2.0 * k * m_seen, tiny)) / L).astype(jnp.int32)

    # masked activation: exponent i lives in slot i mod S_max; a slot whose
    # assigned exponent changed is reset, one whose exponent survives keeps
    # its cache/members (the host rebuild's keep-and-add, shape-statically)
    slots = jnp.arange(S, dtype=jnp.int32)
    wanted_exp = i_lo + jnp.mod(slots - i_lo, S)
    wanted = wanted_exp <= i_hi
    claim = rebuild & wanted & ((slot_exp != wanted_exp) | ~active)
    if spec.variant == "sieve":
        active = jnp.where(rebuild, wanted, active)       # window replaces
    elif spec.variant == "salsa":
        active = active | (rebuild & wanted)              # grow-only
    else:  # pp: LB prune τ ≥ lo/(1+ε) ⇔ i ≥ i_lo − 1, then activation
        active = jnp.where(rebuild, active & (slot_exp >= i_lo - 1), active)
        active = active | claim
    slot_exp = jnp.where(claim, wanted_exp, slot_exp)
    caches = jnp.where(claim[:, None], seed[None, :], caches)
    sizes = jnp.where(claim, 0, sizes)
    members = jnp.where(claim[:, None], -1, members)

    # offer to every sieve: marginal gain vs each (post-rebuild) cache, one
    # accept rule
    if use_kernel:
        gains = jnp.where(claim, single, gains_pre)
    else:
        gains = mean_rows(fx.sieve_gain_rows(fn, caches, dvec, row_aux))
    taus = jnp.exp(slot_exp.astype(jnp.float32) * L)
    if spec.variant == "salsa":
        # dense-threshold schedule: rate 1/2 for the first ⌈k/2⌉ members,
        # 1/(2e) after — (k+1)//2, so k=1 still gets the early rate
        rate = jnp.where(sizes < (k + 1) // 2, 0.5, 1.0 / (2.0 * math.e))
        need = rate * taus / k
    else:
        values = values_of(caches)
        need = (taus / 2.0 - values) / jnp.maximum(k - sizes, 1)
    accept = valid & active & (sizes < k) & (gains >= need)
    caches = fx.sieve_fold_rows(fn, caches, dvec, accept)
    members = jnp.where(
        accept[:, None] & (jnp.arange(k)[None, :] == sizes[:, None]),
        idx, members)
    sizes = sizes + accept.astype(jnp.int32)
    if spec.variant == "pp":
        vals_new = values_of(caches)
        lb = jnp.maximum(lb, jnp.max(jnp.where(active, vals_new, -jnp.inf)))

    # engine-boundary accounting: one engine call scores the element against
    # every live sieve (min. 1 — the singleton gain is always computed)
    n_active = jnp.sum(active).astype(jnp.int32)
    evals = evals + jnp.where(valid, jnp.maximum(n_active, 1), 0)
    state = SieveState(caches, slot_exp, active, sizes, members, m_seen, lb,
                       evals)
    return state, jnp.any(accept)


@partial(jax.jit, static_argnames=("spec",))
def _element_step_jit(state, seed, idx, dvec, valid, *, spec, row_aux=None):
    seedf = seed.astype(jnp.float32)
    aux = jnp.zeros_like(seedf) if row_aux is None \
        else row_aux.astype(jnp.float32)
    v0 = jnp.mean(fx.stat_rows(spec.fn, seedf, aux))
    return _element_step(spec, seedf, v0, state, idx, dvec, valid,
                         row_aux=aux)


@contract(
    "streaming.offer_scan",
    donate=("state",),
    claim="one dispatch consumes a whole stream block: ONE lax.scan over "
          "its elements, collective-free, the sieve table updated in place "
          "per element — and the carried SieveState is DONATED, so the "
          "(S_max, n) table buffers alias block-to-block instead of copying")
@partial(jax.jit, static_argnames=("spec", "counter_key"),
         donate_argnums=(0,))
def _offer_block_scan(state, seed, row_aux, idxb, dmatb, validb, *, spec,
                      counter_key):
    """Consume a stream block: ONE jitted ``lax.scan`` over its elements.

    The ``state`` carry is donated: every leaf of the incoming SieveState
    aliases the matching output leaf, so the table is updated in place and
    the caller MUST rebind (``self.state = ...``) rather than reuse the
    argument — which :class:`DeviceSieveEngine` does."""
    DEVICE_TRACE_COUNTS[counter_key] += 1
    seedf = seed.astype(jnp.float32)
    auxf = row_aux.astype(jnp.float32)
    v0 = jnp.mean(fx.stat_rows(spec.fn, seedf, auxf))

    def step(st, xs):
        idx, dvec, valid = xs
        return _element_step(spec, seedf, v0, st, idx, dvec, valid,
                             row_aux=auxf)

    return jax.lax.scan(step, state, (idxb, dmatb, validb))


@partial(jax.jit, static_argnames=("fn",))
def _table_values(caches, seed, row_aux, *, fn: FnSpec):
    """Per-sieve f-values — shared by both engines' ``best`` so equal caches
    yield bit-equal values."""
    seedf = seed.astype(jnp.float32)
    auxf = row_aux.astype(jnp.float32)
    v0 = jnp.mean(fx.stat_rows(fn, seedf, auxf))
    return fx.value_from_stat(
        fn, v0, jnp.mean(fx.stat_rows(fn, caches, auxf), axis=-1))


@partial(jax.jit, static_argnames=("fn", "n_total"))
def _table_values_padded(caches, seed, row_aux, *, fn: FnSpec, n_total: int):
    """f-values of a padded (mesh-sharded) table: the function's pad
    sentinels make padding rows contribute exactly 0 to every stat sum
    (exemplar: seed/cache 0; facility location: seed/aux +inf mask;
    saturated coverage: cap 0 self-masks), so the sums are exact and only
    the normalizer must be the real n. Runs on the global sharded arrays —
    the partitioner turns the row sums into one small cross-device reduce,
    so ``best`` never gathers the (S_max, n) table to one device."""
    seedf = seed.astype(jnp.float32)
    auxf = row_aux.astype(jnp.float32)
    v0 = jnp.sum(fx.stat_rows(fn, seedf, auxf)) / n_total
    mean_stat = jnp.sum(fx.stat_rows(fn, caches, auxf), axis=-1) / n_total
    return fx.value_from_stat(fn, v0, mean_stat)


# ---------------------------------------------------------------------------
# Mesh-sharded block consumption: the (S_max, n) sieve cache table (and the
# cache seed, the row auxiliary, and per-element distance rows) column-shard
# over the mesh's data axes, taking per-device streaming state from
# O(S_max·n) to O(S_max·n/p). The scan body is the IDENTICAL _element_step
# with its two ground-set reductions swapped for psum'd per-shard partials —
# the same collective shape as the selection engine's sharded gains (2–3
# psums of O(S_max) bytes per element, distances computed shard-locally so
# the (B, n) block never exists anywhere).
# ---------------------------------------------------------------------------

_SHARDED_OFFER_CACHE: dict = {}


def _state_specs(axes):
    from jax.sharding import PartitionSpec as P

    return SieveState(
        caches=P(None, axes), slot_exp=P(None), active=P(None),
        sizes=P(None), members=P(None, None), m_seen=P(), lb=P(), evals=P())


@contract(
    "streaming.offer_scan[sharded]",
    factory=True,
    collective_kinds=("psum",),
    donate=("state",),
    claim="one dispatch per stream block; each element's table update "
          "costs O(S_max) psum'd scalars per reduction — collective bytes "
          "scale with the sieve table, never the ground set — and the "
          "sharded SieveState carry is DONATED (the per-device table shard "
          "aliases in place; V/seed/aux stay resident, never donated)")
def make_sharded_offer_scan(mesh, data_axes, *, spec: SieveSpec,
                            n_total: int, distance: str, policy_name: str,
                            counter_key: str):
    """Build (and cache) the jitted mesh-sharded per-block sieve scan.

    Returns ``fn(state, V_sh, seed_sh, aux_sh, Xb, idxb, validb) -> (state,
    accepted)`` where the state's ``caches`` (and ``V_sh``/``seed_sh``/
    ``aux_sh``) shard over ``data_axes`` and every other state leaf is
    replicated. Distance rows are computed *inside* the shard_map against
    the local V tile (each entry depends only on its own ground row, so
    per-entry arithmetic matches ``point_distances_block`` exactly).
    """
    from repro.core import distances as dist_mod
    from repro.core.precision import resolve as resolve_policy
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    axes = tuple(data_axes)
    key = (mesh, axes, spec, n_total, distance, policy_name, counter_key)
    if key in _SHARDED_OFFER_CACHE:
        return _SHARDED_OFFER_CACHE[key]
    policy = resolve_policy(policy_name)
    pair = dist_mod.resolve_pairwise(distance)
    use_kernel = spec.backend != "jnp"
    if use_kernel:
        from repro.kernels import ops as kops

        tmpl = fx.kernel_template(spec.fn)

    def local_consume(state, V_loc, seed_loc, aux_loc, Xb, idxb, validb):
        seedf = seed_loc.astype(jnp.float32)
        auxf = aux_loc.astype(jnp.float32)
        v0 = jax.lax.psum(
            jnp.sum(fx.stat_rows(spec.fn, seedf, auxf)), axes) / n_total
        dmat_loc = pair(V_loc, Xb, policy).T.astype(jnp.float32)

        def mean_rows(M):
            return jax.lax.psum(jnp.sum(M, axis=-1), axes) / n_total

        table_gains = None
        if use_kernel:

            def table_gains(table, dvec):
                part = kops.sieve_gains(
                    table, dvec, n_total=n_total,
                    fold=tmpl[0], score_affine=tmpl[1],
                    interpret=(spec.backend != "pallas"))
                return jax.lax.psum(part, axes)

        def step(st, xs):
            idx, dvec, valid = xs
            return _element_step(spec, seedf, v0, st, idx, dvec, valid,
                                 row_aux=auxf, mean_rows=mean_rows,
                                 table_gains=table_gains)

        return jax.lax.scan(step, state, (idxb, dmat_loc, validb))

    specs = _state_specs(axes)
    smapped = shard_map(
        local_consume,
        mesh=mesh,
        in_specs=(specs, P(axes, None), P(axes), P(axes), P(None, None),
                  P(None), P(None)),
        out_specs=(specs, P(None)),
        check_rep=False,
    )

    # donate ONLY the state carry: V/seed/aux are the function's resident
    # shards, reused by every block (and shared with sharded selection runs)
    @partial(jax.jit, donate_argnums=(0,))
    def run(state, V_sh, seed_sh, aux_sh, Xb, idxb, validb):
        DEVICE_TRACE_COUNTS[counter_key] += 1
        return smapped(state, V_sh, seed_sh, aux_sh, Xb, idxb, validb)

    _SHARDED_OFFER_CACHE[key] = run
    return run


# ---------------------------------------------------------------------------
# Batched multi-stream consumption: P independent sieve tables advance through
# ONE scan dispatch per block — the streaming analogue of the selection
# engine's batched multi-tenant dispatch. The scan still runs over the B
# elements of the block; each step advances all P partitions with a vmap of
# the IDENTICAL _element_step (its jnp reductions are trailing-axis-wise, so
# every partition's arithmetic is bit-identical to its own unbatched engine).
# Kernel backends score all P tables in ONE grid-over-P fused kernel launch
# (vmap cannot batch a pallas_call), injected through the step's table_gains
# hook — the gains math is the same kernel body, batched like
# gain_eval_batched.
# ---------------------------------------------------------------------------


@contract(
    "streaming.offer_scan_batched",
    donate=("states",),
    claim="P independent stream partitions advance through ONE dispatch per "
          "block: a lax.scan over the block's elements whose step vmaps the "
          "identical element transition over partitions (kernel backends "
          "score all P tables in one grid-over-P fused launch); the batched "
          "SieveState carry is donated, so P tables alias in place")
@partial(jax.jit, static_argnames=("spec", "counter_key"),
         donate_argnums=(0,))
def _offer_block_scan_batched(states, seed, row_aux, idxb, dmatb, validb, *,
                              spec, counter_key):
    """Consume one block across P partitions: ONE jitted ``lax.scan``.

    ``states`` is a (P, …)-batched :class:`SieveState`; ``idxb``/``validb``
    are (B, P) and ``dmatb`` (B, P, n) — element-major so the scan runs over
    the block axis exactly like :func:`_offer_block_scan`. Returns
    ``(states, accepted (B, P))``. The carry is donated (callers rebind).
    """
    DEVICE_TRACE_COUNTS[counter_key] += 1
    seedf = seed.astype(jnp.float32)
    auxf = row_aux.astype(jnp.float32)
    v0 = jnp.mean(fx.stat_rows(spec.fn, seedf, auxf))
    use_kernel = spec.backend != "jnp"
    if use_kernel:
        from repro.kernels import ops as kops

        tmpl = fx.kernel_template(spec.fn)

    def step(sts, xs):
        idx, dmat, valid = xs            # (P,), (P, n), (P,)
        if use_kernel:
            # ONE batched kernel launch scores seed+table rows of all P
            # partitions; each partition's _element_step then receives its
            # own precomputed (S_max+1,) gains through the table_gains hook
            # (the hook is called exactly once per step, on the same
            # seed-stacked table the unbatched path builds).
            tables = jnp.concatenate(
                [jnp.broadcast_to(seedf, (idx.shape[0], 1, seedf.shape[0])),
                 sts.caches], axis=1)
            g_all = kops.sieve_gains_batched(
                tables, dmat, fold=tmpl[0], score_affine=tmpl[1],
                interpret=(spec.backend != "pallas"))

            def elem(st, i, dv, va, g):
                return _element_step(spec, seedf, v0, st, i, dv, va,
                                     row_aux=auxf,
                                     table_gains=lambda _t, _d: g)

            return jax.vmap(elem)(sts, idx, dmat, valid, g_all)

        def elem(st, i, dv, va):
            return _element_step(spec, seedf, v0, st, i, dv, va,
                                 row_aux=auxf)

        return jax.vmap(elem)(sts, idx, dmat, valid)

    return jax.lax.scan(step, states, (idxb, dmatb, validb))


class _SieveEngineBase:
    """Block handling and state access shared by both execution plans.

    ``offer`` chunks the payload to ``block_size`` and pads ragged tails, so
    BOTH plans run the distance executable at the one (block_size, n) shape
    — the bitwise-parity invariant is structural, not backend luck — and
    every block reuses one traced executable. Padded elements carry
    ``valid=False`` (their step is a no-op by construction).

    ``overlap=True`` (the default) makes the block boundary sync-free:
    ``offer`` stages block t+1's padded payload with ``jax.device_put`` and
    issues its scan while block t's scan is still running — JAX's async
    dispatch pipelines them, and the only host syncs are the final accept
    masks (tiny (B,) bools, fetched once per ``offer`` call after every
    block has been issued) plus the lazy evaluation-counter fold at
    :meth:`evaluations`. ``max_in_flight`` bounds the pipeline depth so a
    long offer cannot stage an unbounded number of payload blocks on
    device. ``overlap=False`` restores the serialized baseline (block on
    each block's mask + fold its evals before staging the next) — kept so
    the overlap win stays benchmarkable.
    """

    def __init__(self, f, spec: SieveSpec, block_size: int = 64,
                 overlap: bool = True, max_in_flight: int = 4):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.f = f
        self.spec = spec
        self.block_size = block_size
        self.overlap = overlap
        self.max_in_flight = max_in_flight
        # the function's protocol arrays the element step consumes: the
        # empty-set cache row and the static per-row auxiliary
        self._seed = jnp.asarray(f.cache_seed, jnp.float32)
        self._aux = jnp.asarray(f.row_aux, jnp.float32)
        self.state = self._initial_state()
        # device state counts in int32; folding into a Python int at drain
        # points keeps unbounded streams (the service's live-sensor case)
        # exact. The fold is LAZY under overlap: each element adds at most
        # S_max evals, so int32 headroom covers tens of millions of
        # elements between drains — and every read path drains.
        self._evals = 0

    _I32 = np.iinfo(np.int32)

    def _validate_ids(self, idx) -> np.ndarray:
        """Stream ids live in the int32 member table; ids outside its range
        (the service's unbounded int64 counter can exceed it on long-lived
        streams) must raise, not silently wrap into colliding member ids."""
        idx = np.atleast_1d(np.asarray(idx))
        if idx.size and (int(idx.max()) > self._I32.max
                         or int(idx.min()) < self._I32.min):
            raise OverflowError(
                f"stream ids must fit the int32 member table "
                f"([{self._I32.min}, {self._I32.max}]); got range "
                f"[{int(idx.min())}, {int(idx.max())}]")
        return idx.astype(np.int32)

    def _initial_state(self) -> SieveState:
        """Hook: the mesh-sharded engine builds the table *born sharded* —
        the (S_max, n) zeros must never materialize on one device in the
        regime the mesh exists for."""
        return init_state(self.f.n, self.spec)

    def _stage_block(self, Xb, nb: int):
        """Pad one block to ``block_size`` rows and start its host→device
        transfer. For host-resident payloads the pad happens in numpy and
        ``jax.device_put`` issues an async copy — under overlap, block t+1
        stages while block t's scan runs. Device-resident payloads pad on
        device (no transfer to hide)."""
        B = self.block_size
        if isinstance(Xb, np.ndarray):
            Xp = np.zeros((B, Xb.shape[1]), Xb.dtype)
            Xp[:nb] = Xb
            return jax.device_put(Xp)
        return jnp.pad(Xb, ((0, B - nb), (0, 0)))

    def offer(self, idx, X) -> np.ndarray:
        idx = self._validate_ids(idx)
        if not isinstance(X, jax.Array):
            X = np.atleast_2d(np.asarray(X, np.float32))
        else:
            X = jnp.atleast_2d(X)
        B = self.block_size
        handles: list = []          # (accept handle, live count) per block
        inflight: list = []         # un-awaited handles (depth bound)
        for s in range(0, len(idx), B):
            ib, Xb = idx[s:s + B], X[s:s + B]
            nb = len(ib)
            payload = self._block_payload(self._stage_block(Xb, nb))
            idxp = np.full(B, -1, np.int32)
            idxp[:nb] = ib
            valid = np.zeros(B, bool)
            valid[:nb] = True
            acc = self._consume(idxp, payload, valid)
            handles.append((acc, nb))
            if not self.overlap:
                # serialized baseline: block on this block's mask and fold
                # its evals before staging the next — the pre-overlap cost
                jax.block_until_ready(acc)
                self._fold_evals()
            else:
                inflight.append(acc)
                if len(inflight) > self.max_in_flight:
                    jax.block_until_ready(inflight.pop(0))
        out = [np.asarray(acc)[:nb] for acc, nb in handles]
        return np.concatenate(out) if out else np.zeros(0, bool)

    def _fold_evals(self) -> None:
        """Drain the device-resident int32 evaluation counter into the exact
        Python accumulator. A host sync — called per block only on the
        serialized path; under overlap it runs lazily at read points."""
        e = int(np.asarray(self.state.evals))
        if e:
            self._evals += e
            self.state = self.state._replace(
                evals=jnp.zeros_like(self.state.evals))

    def best(self) -> tuple[list[int], float]:
        """Members and value of the best live sieve ([], 0.0 when none).

        Member slots, sizes and the active mask are replicated table state:
        one host fetch each regardless of mesh width — never a per-shard
        gather."""
        active = np.asarray(self.state.active)
        if not active.any():
            return [], 0.0
        vals = np.asarray(self._values())
        vals = np.where(active, vals, -np.inf)
        b = int(np.argmax(vals))
        size = int(np.asarray(self.state.sizes)[b])
        return [int(i) for i in np.asarray(self.state.members)[b, :size]], \
            float(vals[b])

    def _values(self) -> jax.Array:
        return _table_values(self.state.caches, self._seed, self._aux,
                             fn=self.spec.fn)

    def evaluations(self) -> int:
        self._fold_evals()
        return self._evals

    def member_ids(self) -> list[int]:
        """Ids present in any live sieve's member table (service retention)."""
        st = self.state
        live = np.asarray(st.active)[:, None] & (
            np.arange(self.spec.k)[None, :] < np.asarray(st.sizes)[:, None])
        return sorted({int(i) for i in np.asarray(st.members)[live]})

    def _distance_rows(self, X) -> jax.Array:
        # both engines consume rows from the SAME jitted executable — host
        # and device decisions see bitwise-identical distances
        return self.f.point_distances_block(X).astype(jnp.float32)

    def _block_payload(self, X) -> jax.Array:
        """What ``offer`` hands ``_consume`` per padded block: distance rows
        by default; the mesh-sharded engine passes the raw vectors through
        and computes distances shard-locally inside its scan."""
        return self._distance_rows(X)

    def _consume(self, idxp, payload, valid):
        """Advance the engine by one padded block; returns the accept mask —
        a host array (mirror) or an un-synced device value (device plans)."""
        raise NotImplementedError


class HostSieveMirror(_SieveEngineBase):
    """The exact array-semantics mirror: one dispatch per element.

    Runs the identical :func:`_element_step` the device scan runs, but jitted
    per element — the per-element host↔device round-trip the device engine
    exists to amortize, and the parity reference for it.
    """

    def _consume(self, idxp, dmat, valid) -> np.ndarray:
        accepted = np.zeros(len(idxp), bool)
        for b in range(len(idxp)):
            if not valid[b]:  # padded no-op step: state provably unchanged
                continue
            self.state, acc = _element_step_jit(
                self.state, self._seed, jnp.int32(idxp[b]), dmat[b], True,
                spec=self.spec, row_aux=self._aux)
            accepted[b] = bool(acc)
        return accepted


class DeviceSieveEngine(_SieveEngineBase):
    """Device-resident sieve table: one scan dispatch per stream block.

    State never leaves the device between blocks (beyond the accept mask
    and the evaluation-counter fold the block boundary reads anyway).

    ``mesh`` column-shards the (S_max, n) cache table — and the cache seed,
    the row auxiliary, and each element's distance row — over the mesh's
    ``data_axes``, cutting per-device streaming state to O(S_max·n/p): the
    pod-scale ground-set regime. The scan body is the identical
    :func:`_element_step`; only its two ground-set reductions become
    psum'd per-shard partials (the sieve-gain kernel already normalizes by
    an explicit global n, so per-shard table tiles psum exactly like
    selection gains). Thresholds, sizes, member slots, and the evaluation
    counter stay replicated, so ``best``/``member_ids``/snapshots read
    them with one fetch — never a per-shard gather."""

    def __init__(self, f, spec: SieveSpec, block_size: int = 64,
                 mesh=None, data_axes: Sequence[str] = ("data",),
                 overlap: bool = True, max_in_flight: int = 4):
        from jax.sharding import NamedSharding, PartitionSpec as P

        # mesh geometry first: _SieveEngineBase.__init__ asks the
        # _initial_state hook for the table, which must be born sharded
        self.mesh = mesh
        if mesh is not None:
            axes = tuple(data_axes)
            self._axes = axes
            ndev = 1
            for a in axes:
                ndev *= mesh.shape[a]
            self._n_pad = ((f.n + ndev - 1) // ndev) * ndev
            self._n_total = f.n
            self._shardings = SieveState(
                *[NamedSharding(mesh, s) for s in _state_specs(axes)])
        super().__init__(f, spec, block_size, overlap=overlap,
                         max_in_flight=max_in_flight)
        self._counter_key = f"sieve_{spec.variant}"
        if mesh is None:
            return
        self._counter_key = f"sieve_{spec.variant}_sharded"
        # padding rows carry the function's pad sentinels (exemplar: seed 0
        # so relu(0 − d) = 0; facility location: seed/aux +inf so pad gains
        # and stats vanish; saturated coverage: cap 0 self-masks) — exact
        # under the real-n normalizer. The padded placement itself is the
        # selection engine's (cached on f), so a sieve engine and a sharded
        # selection run on the same mesh share ONE resident copy of V's
        # shards.
        from repro.core.distributed import _placed_sharded

        entry = _placed_sharded(f, mesh, self._axes, replicated_pool=False)
        self._V_sh = entry["V_sh"]
        self._seed_sh = entry["seed_sh"]
        self._aux_sh = entry["aux_sh"]
        self._offer_fn = make_sharded_offer_scan(
            mesh, self._axes, spec=spec, n_total=f.n,
            distance=f.cfg.distance, policy_name=f.cfg.resolved_policy().name,
            counter_key=self._counter_key)

    def _initial_state(self) -> SieveState:
        if self.mesh is None:
            return super()._initial_state()
        # jit with out_shardings lays the zeros out sharded from birth: the
        # full (S_max, n_pad) table never exists on any single device
        return jax.jit(
            lambda: init_state(self._n_pad, self.spec),
            out_shardings=self._shardings)()

    def _block_payload(self, X) -> jax.Array:
        if self.mesh is None:
            return self._distance_rows(X)
        # raw vectors pass through replicated; distance rows are computed
        # shard-locally inside the scan, so no (B, n) block ever exists
        return jnp.asarray(X)

    def _values(self) -> jax.Array:
        if self.mesh is None:
            return _table_values(self.state.caches, self._seed, self._aux,
                                 fn=self.spec.fn)
        return _table_values_padded(self.state.caches, self._seed_sh,
                                    self._aux_sh, fn=self.spec.fn,
                                    n_total=self._n_total)

    def _consume(self, idxp, payload, valid):
        # the scan donates the state carry: the pre-call ``self.state``
        # buffers are consumed by the dispatch and the rebind below is the
        # only live reference — the table aliases in place, never copies.
        # The accept mask is returned as a DEVICE value (no host sync);
        # ``offer`` drains masks after the whole pipeline is issued.
        if self.mesh is None:
            self.state, acc = _offer_block_scan(
                self.state, self._seed, self._aux, jnp.asarray(idxp),
                payload, jnp.asarray(valid), spec=self.spec,
                counter_key=self._counter_key)
        else:
            self.state, acc = self._offer_fn(
                self.state, self._V_sh, self._seed_sh, self._aux_sh,
                payload, jnp.asarray(idxp), jnp.asarray(valid))
        return acc


class BatchedSieveEngine:
    """P independent stream partitions advanced by ONE dispatch per block.

    The streaming analogue of ``run_selection_batch``: each partition owns a
    full fixed-capacity sieve table (a (P, …)-batched :class:`SieveState`),
    and one :func:`_offer_block_scan_batched` dispatch per block advances
    all of them — the per-partition transition is the IDENTICAL
    :func:`_element_step` under ``vmap`` (its reductions are trailing-axis-
    wise), so every partition's members, values, and evaluation counts are
    bit-identical to a standalone :class:`DeviceSieveEngine` fed the same
    sub-stream. Kernel backends score all P tables in one grid-over-P fused
    launch (:func:`repro.kernels.ops.sieve_gains_batched`).

    Shares the overlapped-offer pipeline semantics of
    :class:`_SieveEngineBase`: staged payloads, donated state carry, deferred
    accept masks, lazy evaluation fold.
    """

    _I32 = np.iinfo(np.int32)

    def __init__(self, f, spec: SieveSpec, n_streams: int,
                 block_size: int = 64, overlap: bool = True,
                 max_in_flight: int = 4):
        if n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {n_streams}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.f = f
        self.spec = spec
        self.n_streams = int(n_streams)
        self.block_size = block_size
        self.overlap = overlap
        self.max_in_flight = max_in_flight
        self._seed = jnp.asarray(f.cache_seed, jnp.float32)
        self._aux = jnp.asarray(f.row_aux, jnp.float32)
        st0 = init_state(f.n, spec)
        self.states = jax.tree.map(
            lambda a: jnp.stack([a] * self.n_streams), st0)
        self._evals = np.zeros(self.n_streams, np.int64)
        self._counter_key = f"sieve_{spec.variant}_batched"

    def offer(self, idx_parts: Sequence, X_parts: Sequence
              ) -> list[np.ndarray]:
        """Offer per-partition element runs (ragged; empty allowed) and
        return per-partition accept masks. Partitions shorter than the
        longest run ride the shared blocks as ``valid=False`` padding."""
        P, B, d = self.n_streams, self.block_size, self.f.dim
        if len(idx_parts) != P or len(X_parts) != P:
            raise ValueError(
                f"expected {P} partition runs, got "
                f"{len(idx_parts)}/{len(X_parts)}")
        idxs = [_SieveEngineBase._validate_ids(self, i) for i in idx_parts]
        Xs = [np.asarray(x, np.float32).reshape(-1, d) for x in X_parts]
        for p, (i, x) in enumerate(zip(idxs, Xs)):
            if len(i) != len(x):
                raise ValueError(
                    f"partition {p}: {len(i)} ids vs {len(x)} vectors")
        L = max((len(i) for i in idxs), default=0)
        handles: list = []
        inflight: list = []
        for s in range(0, L, B):
            idxp = np.full((B, P), -1, np.int32)
            valid = np.zeros((B, P), bool)
            Xb = np.zeros((P, B, d), np.float32)
            nbs = []
            for p in range(P):
                part = idxs[p][s:s + B]
                nb = len(part)
                nbs.append(nb)
                if nb:
                    idxp[:nb, p] = part
                    valid[:nb, p] = True
                    Xb[p, :nb] = Xs[p][s:s + B]
            # ONE distance dispatch for the whole (P, B) block — the same
            # jitted executable the unbatched engines use, at P·B rows —
            # then element-major layout for the scan
            Xd = jax.device_put(Xb.reshape(P * B, d))
            dmat = self.f.point_distances_block(Xd).astype(jnp.float32)
            dmatb = dmat.reshape(P, B, -1).transpose(1, 0, 2)
            self.states, acc = _offer_block_scan_batched(
                self.states, self._seed, self._aux, jnp.asarray(idxp),
                dmatb, jnp.asarray(valid), spec=self.spec,
                counter_key=self._counter_key)
            handles.append((acc, nbs))
            if not self.overlap:
                jax.block_until_ready(acc)
                self._fold_evals()
            else:
                inflight.append(acc)
                if len(inflight) > self.max_in_flight:
                    jax.block_until_ready(inflight.pop(0))
        out: list[list] = [[] for _ in range(P)]
        for acc, nbs in handles:
            a = np.asarray(acc)                      # (B, P)
            for p, nb in enumerate(nbs):
                if nb:
                    out[p].append(a[:nb, p])
        return [np.concatenate(o) if o else np.zeros(0, bool) for o in out]

    def _fold_evals(self) -> None:
        e = np.asarray(self.states.evals)
        if e.any():
            self._evals += e.astype(np.int64)
            self.states = self.states._replace(
                evals=jnp.zeros_like(self.states.evals))

    def evaluations(self, p: Optional[int] = None) -> int:
        self._fold_evals()
        return int(self._evals.sum()) if p is None else int(self._evals[p])

    def _values(self) -> np.ndarray:
        """(P, S_max) per-sieve f-values — one dispatch for all partitions
        (the flattened table rides the same jitted ``_table_values``)."""
        P, S, n = self.states.caches.shape
        vals = _table_values(self.states.caches.reshape(P * S, n),
                             self._seed, self._aux, fn=self.spec.fn)
        return np.asarray(vals).reshape(P, S)

    def best_all(self) -> list[tuple[list[int], float]]:
        """Per-partition (members, value) of each best live sieve."""
        active = np.asarray(self.states.active)
        sizes = np.asarray(self.states.sizes)
        members = np.asarray(self.states.members)
        vals = np.where(active, self._values(), -np.inf)
        out = []
        for p in range(self.n_streams):
            if not active[p].any():
                out.append(([], 0.0))
                continue
            b = int(np.argmax(vals[p]))
            size = int(sizes[p, b])
            out.append(([int(i) for i in members[p, b, :size]],
                        float(vals[p][b])))
        return out

    def member_ids(self) -> list[int]:
        """Ids live in any partition's member tables (service retention)."""
        st = self.states
        live = np.asarray(st.active)[:, :, None] & (
            np.arange(self.spec.k)[None, None, :]
            < np.asarray(st.sizes)[:, :, None])
        return sorted({int(i) for i in np.asarray(st.members)[live]})


def make_batched_sieve_engine(f, k: int, eps: float, n_streams: int,
                              variant: str = "sieve",
                              s_max: Optional[int] = None,
                              block_size: int = 64,
                              backend: Optional[str] = None,
                              overlap: bool = True,
                              max_in_flight: int = 4) -> BatchedSieveEngine:
    """Build the P-partition batched sieve engine (see
    :class:`BatchedSieveEngine`). ``backend=None`` inherits ``f.cfg.backend``
    exactly like :func:`make_sieve_engine`."""
    if backend is None:
        backend = f.cfg.backend \
            if f.cfg.backend in ("pallas", "pallas_interpret") else "jnp"
    spec = make_spec(k, eps, variant, s_max, backend=backend, fn=f.spec)
    return BatchedSieveEngine(f, spec, n_streams, block_size=block_size,
                              overlap=overlap, max_in_flight=max_in_flight)


def make_sieve_engine(f, k: int, eps: float, variant: str = "sieve",
                      mode: str = "device", s_max: Optional[int] = None,
                      block_size: int = 64,
                      backend: Optional[str] = None,
                      mesh=None,
                      data_axes: Sequence[str] = ("data",),
                      overlap: bool = True,
                      max_in_flight: int = 4) -> _SieveEngineBase:
    """Build a sieve engine under an execution plan (``host`` | ``device`` |
    ``device_sharded``), mirroring the selection engine's strategy×plan
    composition. The engine streams whatever SIEVE_ELIGIBLE objective ``f``
    carries (``f.spec``); ineligible functions raise at construction. Both
    plans take ``block_size`` — it shapes the (padded) distance dispatch, so
    host and device engines built with the same value run the same
    executables.

    ``backend`` picks the element step's scoring path (``None`` inherits
    ``f.cfg.backend``): kernel backends run the fused table × element gain
    under the function's min/max template
    (:func:`repro.kernels.ops.sieve_gains`) instead of the plain jnp
    reduction — in BOTH plans, so parity stays structural. A function with
    no kernel template silently scores through jnp (the same normalization
    the selection engine applies).

    ``mesh`` (or ``mode="device_sharded"``, which defaults to a 1-D mesh
    over all local devices) column-shards the sieve cache table over
    ``data_axes`` — see :class:`DeviceSieveEngine`. The host mirror is a
    per-element reference and does not shard.
    """
    if backend is None:
        backend = f.cfg.backend \
            if f.cfg.backend in ("pallas", "pallas_interpret") else "jnp"
    spec = make_spec(k, eps, variant, s_max, backend=backend, fn=f.spec)
    if mode == "device_sharded":
        from repro.core.distributed import _resolve_mesh

        mesh = _resolve_mesh(mesh, tuple(data_axes))
        mode = "device"
    if mode == "host":
        if mesh is not None:
            raise ValueError(
                "the host mirror is the per-element reference; it does not "
                "take a mesh")
        return HostSieveMirror(f, spec, block_size=block_size,
                               overlap=overlap, max_in_flight=max_in_flight)
    if mode == "device":
        return DeviceSieveEngine(f, spec, block_size=block_size, mesh=mesh,
                                 data_axes=data_axes, overlap=overlap,
                                 max_in_flight=max_in_flight)
    raise ValueError(f"unknown streaming mode {mode!r}; 'host', 'device' "
                     f"or 'device_sharded'")
