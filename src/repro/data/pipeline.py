"""Data pipeline: deterministic batching + submodular coreset curation.

The curation hook is the paper's technique as a first-class training feature:
a sliding window of candidate examples is embedded, an exemplar coreset is
selected by submodular maximization (the multiset evaluation engine does the
heavy lifting), and only the exemplars are emitted as training batches. At
pod scale the selection runs with the ground set sharded over the data axes
(see repro.core.distributed).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import EvalConfig, ExemplarClustering, greedy
from repro.core.optimizers import OPTIMIZERS
from repro.data.synthetic import TopicTokenStream


def hashed_embedding(tokens: np.ndarray, dim: int = 64,
                     vocab: int = 50_304, seed: int = 7) -> np.ndarray:
    """Deterministic bag-of-tokens random-projection embedding (n, dim).

    Cheap enough to run in the input pipeline; the trainer can swap in model
    activations via `Curator(embed_fn=...)`.
    """
    rng = np.random.default_rng(seed)
    proj = rng.normal(0, 1 / np.sqrt(dim), size=(vocab, dim)).astype(np.float32)
    counts = np.zeros((tokens.shape[0], vocab), np.float32)
    for i, row in enumerate(tokens):
        u, c = np.unique(row, return_counts=True)
        counts[i, u] = c
    counts /= np.maximum(counts.sum(1, keepdims=True), 1)
    return counts @ proj


@dataclasses.dataclass
class CurationConfig:
    window: int = 256          # candidate pool size
    select: int = 64           # exemplars kept per window
    optimizer: str = "greedy"  # any of repro.core.OPTIMIZERS
    embed_dim: int = 64
    enabled: bool = True


class Curator:
    """Window → embed → submodular select → curated examples."""

    def __init__(self, ccfg: CurationConfig, vocab: int,
                 eval_cfg: EvalConfig = EvalConfig(), embed_fn=None):
        self.ccfg = ccfg
        self.vocab = vocab
        self.eval_cfg = eval_cfg
        self.embed_fn = embed_fn or (
            lambda toks: hashed_embedding(toks, ccfg.embed_dim, vocab))
        self.last_value: float = 0.0

    def select(self, tokens: np.ndarray) -> np.ndarray:
        """tokens (W, L) → indices of the selected coreset (k,)."""
        emb = self.embed_fn(tokens)
        f = ExemplarClustering(jnp.asarray(emb), self.eval_cfg)
        opt = OPTIMIZERS[self.ccfg.optimizer]
        res = opt(f, self.ccfg.select)
        self.last_value = res.value
        return np.asarray(res.indices, dtype=np.int64)


def token_batches(
    vocab: int,
    batch_size: int,
    seq_len: int,
    steps: int,
    seed: int = 0,
    curation: Optional[CurationConfig] = None,
    topic_skew: float = 4.0,
    stream: Optional[TopicTokenStream] = None,
) -> Iterator[dict]:
    """Yields {tokens, labels} batches; curated if a CurationConfig is given."""
    stream = stream or TopicTokenStream(vocab, seed=seed)
    curator = (Curator(curation, vocab)
               if curation and curation.enabled else None)
    emitted = 0
    while emitted < steps:
        if curator is None:
            toks, _ = stream.sample(batch_size, seq_len,
                                    topic_skew=topic_skew)
            chosen = toks
        else:
            pool, _ = stream.sample(curation.window, seq_len,
                                    topic_skew=topic_skew)
            idx = curator.select(pool[:, :seq_len])
            chosen = pool[idx]
        for s in range(0, len(chosen) - batch_size + 1, batch_size):
            if emitted >= steps:
                return
            b = chosen[s:s + batch_size]
            yield {
                "tokens": jnp.asarray(b[:, :seq_len]),
                "labels": jnp.asarray(b[:, 1:seq_len + 1]),
            }
            emitted += 1
