"""Synthetic data: clustered vectors (the paper's workload) + token corpora.

The paper evaluates on randomly generated problems (§V). ``blobs`` gives the
clustered version (so selection quality is measurable); ``uniform`` matches
the paper's setting. The token corpus is a topic-mixture Markov stream so
submodular curation has real signal: windows drawn from few topics are
redundant, and exemplar selection prefers topic-diverse subsets.
"""
from __future__ import annotations

import numpy as np


def uniform_problem(n: int, dim: int, seed: int = 0,
                    low: float = 0.0, high: float = 1.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(low, high, size=(n, dim)).astype(np.float32)


def blobs(n: int, dim: int, centers: int = 8, spread: float = 0.15,
          seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    mu = rng.uniform(-1, 1, size=(centers, dim)).astype(np.float32)
    labels = rng.integers(0, centers, size=n)
    X = mu[labels] + rng.normal(0, spread, size=(n, dim)).astype(np.float32)
    return X.astype(np.float32), labels


class TopicTokenStream:
    """Markov token stream with latent topics (for curation experiments)."""

    def __init__(self, vocab_size: int, n_topics: int = 16, seed: int = 0,
                 topic_sharpness: float = 40.0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.n_topics = n_topics
        # each topic concentrates probability on a subset of the vocabulary
        logits = rng.normal(0, 1, size=(n_topics, vocab_size))
        boost = rng.random((n_topics, vocab_size)) < (64.0 / vocab_size)
        logits = logits + topic_sharpness * boost
        self.probs = np.exp(logits - logits.max(1, keepdims=True))
        self.probs /= self.probs.sum(1, keepdims=True)
        self.rng = rng

    def sample(self, n_seqs: int, seq_len: int,
               topic_skew: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (n, seq_len+1), topics (n,)). Skew >1 → redundant."""
        alpha = np.ones(self.n_topics) / topic_skew
        weights = self.rng.dirichlet(alpha)
        topics = self.rng.choice(self.n_topics, size=n_seqs, p=weights)
        toks = np.stack([
            self.rng.choice(self.vocab, size=seq_len + 1, p=self.probs[t])
            for t in topics
        ])
        return toks.astype(np.int32), topics
