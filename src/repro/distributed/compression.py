"""Gradient/communication compression primitives.

``ef_int8_psum`` — error-feedback int8 all-reduce: tensors are quantized to
int8 against a *global* scale (one scalar pmax), summed over the axis in
int32, and dequantized; the per-device quantization residual is carried to
the next call (error feedback), so the compression bias vanishes over steps
instead of accumulating. Wire bytes drop 4× vs f32 (8× vs f64) — this is the
cross-pod trick for the slow inter-pod links.

``bf16_psum`` — plain bf16-cast reduction (2× wire reduction, no state).

Both are shard_map-composable and tested against exact reductions.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp


def bf16_psum(x: jax.Array, axis) -> jax.Array:
    return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(jnp.float32)


def ef_int8_psum(x: jax.Array, err: jax.Array, axis):
    """Error-feedback int8 all-reduce (inside shard_map).

    Args:
      x: local fp32 contribution.
      err: carried quantization residual from the previous call (same shape).
      axis: mesh axis name(s) to reduce over.

    Returns (reduced fp32 array, new residual).
    """
    target = x + err
    local_max = jnp.max(jnp.abs(target))
    scale = jax.lax.pmax(local_max, axis) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_err = target - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis)  # int32 on the wire sum
    return total.astype(jnp.float32) * scale, new_err
