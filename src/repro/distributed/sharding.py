"""Logical-axis sharding rules with divisibility fallback.

Every tensor in the model stack annotates its dims with *logical* names
(``"batch"``, ``"tp"``, ``"fsdp"``, …). A :class:`MeshRules` object maps those
to physical mesh axes and — crucially for heterogeneous architectures — drops
or relocates axes that don't divide (e.g. gemma3's 1 KV head cannot be
16-way tensor-parallel, so the ``"tp"`` assignment falls through to the
head_dim dimension, which *is* divisible).

Dim spec format: each tensor dim is a tuple of logical names tried in
priority rounds; round p tries every dim's p-th alternative. ``None`` skips a
round, so ``(None, "tp")`` means "take the model axis only if no earlier dim
claimed it" — the fallback mechanism.

Example (GQA KV cache, kv_heads=1 on a (data=16, model=16) mesh)::

    dims = (("batch",), (), ("tp",), ((None, "tp")))
    # round 0: batch→data; tp on kv_heads fails (1 % 16 != 0)
    # round 1: head_dim claims "model" instead → P("data", None, None, "model")
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# A dim spec: tuple of (logical name | None) tried in priority rounds.
DimSpec = Sequence[Optional[str]]


def default_logical_rules(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """Map logical names → physical mesh axes, for any of our meshes."""
    axes = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = ("model",) if "model" in axes else ()
    return {
        "batch": dp,          # activations' batch dim
        "fsdp": dp,           # parameter / optimizer-state sharding (ZeRO-3)
        "pod": ("pod",) if "pod" in axes else (),
        "data": ("data",) if "data" in axes else (),
        "tp": tp,             # tensor parallel (heads / mlp / vocab / experts)
        "sp": ("data",) if "data" in axes else (),  # sequence/context parallel
        "expert": tp,         # expert parallel shares the model axis
    }


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    logical: dict[str, tuple[str, ...]]

    @classmethod
    def for_mesh(cls, mesh: Mesh) -> "MeshRules":
        return cls(mesh, default_logical_rules(mesh))

    def _axis_size(self, phys: tuple[str, ...]) -> int:
        return math.prod(self.mesh.shape[a] for a in phys)

    def spec(self, shape: Sequence[int], dims: Sequence[DimSpec]) -> PartitionSpec:
        """Build a PartitionSpec with the priority-round fallback algorithm."""
        if len(shape) != len(dims):
            raise ValueError(f"shape {shape} vs dims {dims} length mismatch")
        out: list = [None] * len(shape)
        used: set[str] = set()
        rounds = max((len(d) for d in dims), default=0)
        for p in range(rounds):
            for i, alts in enumerate(dims):
                if out[i] is not None or p >= len(alts) or alts[p] is None:
                    continue
                phys = self.logical.get(alts[p], ())
                phys = tuple(a for a in phys if a in self.mesh.shape)
                if not phys or any(a in used for a in phys):
                    continue
                if shape[i] % self._axis_size(phys) != 0:
                    continue
                out[i] = phys if len(phys) > 1 else phys[0]
                used.update(phys)
        return PartitionSpec(*out)

    def sharding(self, shape, dims) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, dims))

    def constraint(self, x: jax.Array, dims: Sequence[DimSpec]) -> jax.Array:
        """with_sharding_constraint using the rule system; no-op off-mesh."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x.shape, dims))
        )


class NoRules:
    """Identity stand-in used for single-device smoke tests."""

    def constraint(self, x, dims):
        return x

    def spec(self, shape, dims):
        return PartitionSpec()


def shard_activation(rules, x, kind: str):
    """Common activation constraint shorthands."""
    if rules is None or isinstance(rules, NoRules):
        return x
    table = {
        "tokens": ((("batch",), ("sp",))),
        "embed": (("batch",), ("sp",), (None,)),
        "heads": (("batch",), (None,), ("tp",), ((None, "tp"))),
        "logits": (("batch",), (None,), ("tp",)),
    }
    return rules.constraint(x, table[kind])
