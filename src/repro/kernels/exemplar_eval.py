"""Pallas TPU kernel for multiset exemplar-clustering evaluation.

This is the TPU-native version of the paper's GPU kernel (Algorithm 3):

* The CUDA kernel assigns one *thread* per work-matrix cell ``W[j,i]``; here
  one *grid step* computes a ``(Bl × Bn)`` tile of cells.
* Shared-memory staging of ``v_i`` becomes a ``BlockSpec``-driven HBM→VMEM
  copy of a ``(Bn, d)`` tile of V (double-buffered by the Pallas pipeline).
* The per-thread scalar loop ``min over s ∈ S_j`` becomes, per k-step, an MXU
  contraction ``(Bn, d) · (d, Bl)`` through the Gram identity
  ``‖v−s‖² = ‖v‖² + ‖s‖² − 2⟨v,s⟩`` — see DESIGN.md §2.

Two data layouts (kernel *variants*):

* ``loop`` — S stays ``(l, k, d)``; the kernel loops over k, issuing one
  ``(Bn,d)·(d,Bl)`` matmul per step with a running elementwise min.
* ``flat`` — S is pre-transposed to ``(k, l, d)`` ("k-major"). This is the
  TPU analogue of the paper's round-robin interleave (§IV-B-2): vector
  *lanes* hold consecutive sets for a fixed k, so a single
  ``(Bn, d)·(d, k·Bl)`` matmul computes every (set, k) pair at once and the
  min over k is a clean sublane reduction of a ``(Bn, k, Bl)`` tile.

Two reduction modes:

* ``fused`` (beyond paper) — the row-sum over n is accumulated across grid
  steps directly in the output block; W never reaches HBM.
* ``two_pass`` (paper-faithful) — W tiles are written to HBM and reduced by a
  second pass, exactly like the paper's ``W·1`` GEMV.

Grid: ``(l_tiles, n_tiles)`` with n innermost so the fused accumulator block
(indexed by the l tile only) stays resident while n streams past.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import PrecisionPolicy

_BIG = 3.0e38  # +inf stand-in that survives fp32 math


def _maybe_rbf(d2, rbf_gamma):
    if rbf_gamma is None:
        return d2
    return 2.0 * (1.0 - jnp.exp(-rbf_gamma * d2))


def _sq_norms(x, accum_dtype):
    xa = x.astype(accum_dtype)
    return jnp.sum(xa * xa, axis=-1)


def _dist_tile(v, s, policy: PrecisionPolicy, rbf_gamma):
    """(Bn, d)×(B, d) → (Bn, B) squared distances via the MXU."""
    g = jax.lax.dot_general(
        v, s, (((1,), (1,)), ((), ())),
        preferred_element_type=policy.accum_dtype,
    )
    vn = _sq_norms(v, policy.accum_dtype)
    sn = _sq_norms(s, policy.accum_dtype)
    d2 = jnp.maximum(vn[:, None] + sn[None, :] - 2.0 * g, 0.0)
    return _maybe_rbf(d2, rbf_gamma)


# ---------------------------------------------------------------------------
# fused kernels
# ---------------------------------------------------------------------------


def _fused_loop_kernel(v_ref, s_ref, len_ref, e0_ref, out_ref, *,
                       k: int, n_total: int, policy: PrecisionPolicy,
                       rbf_gamma, unroll: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...].astype(policy.compute_dtype)          # (Bn, d)
    e0 = e0_ref[...].astype(policy.accum_dtype)          # (Bn, 1)
    lens = len_ref[...][:, 0]                            # (Bl,)
    bl = lens.shape[0]
    bn = v.shape[0]
    minval = jnp.broadcast_to(e0, (bn, bl))              # seed with d(v, e0)

    def body(kk, minval):
        s = s_ref[:, kk, :].astype(policy.compute_dtype)  # (Bl, d)
        d2 = _dist_tile(v, s, policy, rbf_gamma)          # (Bn, Bl)
        valid = (kk < lens)[None, :]
        d2 = jnp.where(valid, d2, _BIG)
        return jnp.minimum(minval, d2.astype(policy.accum_dtype))

    if k <= unroll:
        for kk in range(k):
            minval = body(kk, minval)
    else:
        minval = jax.lax.fori_loop(0, k, body, minval)

    partial = jnp.sum(minval.astype(jnp.float32), axis=0) / n_total  # (Bl,)
    out_ref[...] += partial[:, None]


def _fused_flat_kernel(v_ref, s_ref, len_ref, e0_ref, out_ref, *,
                       k: int, n_total: int, policy: PrecisionPolicy,
                       rbf_gamma):
    """S tile is (k, Bl, d) "k-major": one matmul for all (set, k) pairs."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...].astype(policy.compute_dtype)          # (Bn, d)
    s3 = s_ref[...].astype(policy.compute_dtype)         # (k, Bl, d)
    kk, bl, d = s3.shape
    bn = v.shape[0]
    s2 = s3.reshape(kk * bl, d)                          # merge leading dims
    d2 = _dist_tile(v, s2, policy, rbf_gamma)            # (Bn, k·Bl)
    d2 = d2.reshape(bn, kk, bl)                          # lane dim (Bl) intact
    lens = len_ref[...][:, 0]                            # (Bl,)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (1, kk, bl), 1)
    valid = kidx < lens[None, None, :]
    d2 = jnp.where(valid, d2, _BIG)
    dmin = jnp.min(d2, axis=1)                           # (Bn, Bl)
    e0 = e0_ref[...].astype(d2.dtype)                    # (Bn, 1)
    dmin = jnp.minimum(dmin, e0)
    partial = jnp.sum(dmin.astype(jnp.float32), axis=0) / n_total
    out_ref[...] += partial[:, None]


# ---------------------------------------------------------------------------
# two-pass (paper-faithful) kernel: materialize W tiles
# ---------------------------------------------------------------------------


def _two_pass_kernel(v_ref, s_ref, len_ref, e0_ref, w_ref, *,
                     k: int, n_total: int, policy: PrecisionPolicy,
                     rbf_gamma, unroll: int):
    v = v_ref[...].astype(policy.compute_dtype)
    e0 = e0_ref[...].astype(policy.accum_dtype)
    lens = len_ref[...][:, 0]
    bl = lens.shape[0]
    bn = v.shape[0]
    minval = jnp.broadcast_to(e0, (bn, bl))

    def body(kk, minval):
        s = s_ref[:, kk, :].astype(policy.compute_dtype)
        d2 = _dist_tile(v, s, policy, rbf_gamma)
        valid = (kk < lens)[None, :]
        d2 = jnp.where(valid, d2, _BIG)
        return jnp.minimum(minval, d2.astype(policy.accum_dtype))

    if k <= unroll:
        for kk in range(k):
            minval = body(kk, minval)
    else:
        minval = jax.lax.fori_loop(0, k, body, minval)
    # W[j, i] = min-dist / n (paper eq. 5) — note transpose: out rows are sets
    w_ref[...] = (minval.T / n_total).astype(w_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------


def fused_eval(
    V: jax.Array,            # (n_pad, d_pad)
    S: jax.Array,            # loop: (l_pad, k, d_pad); flat: (k, l_pad, d_pad)
    lengths: jax.Array,      # (l_pad, 1) int32
    d_e0: jax.Array,         # (n_pad, 1) float32 (already transformed)
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_l: int,
    variant: str = "flat",
    rbf_gamma: Optional[float] = None,
    unroll: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Returns (l_pad, 1) float32 sums L(S_j ∪ {e0})."""
    if variant == "flat":
        k, l_pad, d_pad = S.shape
        s_spec = pl.BlockSpec((k, block_l, d_pad), lambda i, j: (0, i, 0))
        kern = functools.partial(
            _fused_flat_kernel, k=k, n_total=n_total, policy=policy,
            rbf_gamma=rbf_gamma)
    elif variant == "loop":
        l_pad, k, d_pad = S.shape
        s_spec = pl.BlockSpec((block_l, k, d_pad), lambda i, j: (i, 0, 0))
        kern = functools.partial(
            _fused_loop_kernel, k=k, n_total=n_total, policy=policy,
            rbf_gamma=rbf_gamma, unroll=unroll)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    n_pad = V.shape[0]
    grid = (l_pad // block_l, n_pad // block_n)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, V.shape[1]), lambda i, j: (j, 0)),
            s_spec,
            pl.BlockSpec((block_l, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l_pad, 1), jnp.float32),
        interpret=interpret,
    )(V, S, lengths, d_e0)


def two_pass_eval(
    V: jax.Array,            # (n_pad, d_pad)
    S: jax.Array,            # (l_pad, k, d_pad)
    lengths: jax.Array,      # (l_pad, 1)
    d_e0: jax.Array,         # (n_pad, 1)
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_l: int,
    rbf_gamma: Optional[float] = None,
    unroll: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Paper-faithful: materialize W (l_pad, n_pad) in HBM; caller reduces."""
    l_pad, k, d_pad = S.shape
    n_pad = V.shape[0]
    grid = (l_pad // block_l, n_pad // block_n)
    kern = functools.partial(
        _two_pass_kernel, k=k, n_total=n_total, policy=policy,
        rbf_gamma=rbf_gamma, unroll=unroll)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_l, k, d_pad), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_l, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(V, S, lengths, d_e0)
