"""Pallas TPU kernels for optimizer-aware greedy marginal gains (beyond paper).

For Greedy, every candidate set shares the base S, so with a per-element
cache the marginal gain collapses to one (n × m) distance matrix (a single
Gram matmul) + a ReLU/sum epilogue, fused here so the distance matrix never
reaches HBM. Grid ``(m_tiles, n_tiles)`` with n innermost, accumulating into
the (Bm, 1) output block.

ONE kernel template serves the whole function zoo (see
:func:`repro.core.functions.kernel_template`), parameterized by the fold
direction and an in-tile affine of the distance:

* ``fold="min"`` — the exemplar min-distance cache
  ``m_i = min_{s∈S∪{e0}} d(v_i, s)``:

      Δ(c_j | S) = |V|⁻¹ Σ_i relu(m_i − d(v_i, c_j))

* ``fold="max"`` + ``affine=(α, β)`` — the max-similarity dual (facility
  location's cache; graph cut scores through it against a static baseline):

      Δ(c_j | S) = |V|⁻¹ Σ_i relu((α + β·d(v_i, c_j)) − c_i)

  The similarity s = relu(α + β·d) needs no inner relu in-tile: the cache is
  ≥ 0, so relu(relu(x) − c) ≡ relu(x − c). Padding rows carry +inf cache
  sentinels (relu(s − inf) = 0) — see ``_pad_gain_operands`` in
  :mod:`repro.kernels.ops`.

Two gain kernels:

* :func:`gain_eval` — gains against a given cache (one greedy round's scoring).
* :func:`gain_update_eval` — the fused *gain + cache-update* step used by the
  device-resident greedy engine. The previous round's winner ``w`` rides along
  as an extra (1, d) operand; the epilogue recomputes ``d(v_i, w)`` in-tile,
  folds it into the cache (min: ``m_i ← min(m_i, d_iw)``; max:
  ``c_i ← max(c_i, relu(α + β·d_iw))``) and scores the current round's gains
  against the *updated* cache — so the winner's distance column never
  re-materializes in HBM (only the (n,) cache itself, which is required
  state, is written back). A (1, 1) ``w_valid`` operand gates the fold:
  round 0 has no previous winner, and unlike the idempotent min fold the max
  fold must NOT re-apply a seed row.

Both gain kernels also come in a *batched* variant (:func:`gain_eval_batched`,
:func:`gain_update_eval_batched`) whose grid grows a leading axis over B
independent requests — ``(B, m_tiles, n_tiles)`` — so a multi-tenant bucket
amortizes ONE kernel launch. Per-request tile partitioning, block shapes, and
accumulation order are identical to the unbatched kernels, which keeps batched
selections bit-compatible with the unbatched engine; ragged-k masking rides in
the per-request ``w_valid`` gate, so padded requests never fold.

A third kernel serves the streaming sieve engine:

* :func:`sieve_gain_eval` — the fused gain of a whole sieve cache *table*
  against one stream element's distance row: for every table row r,
  ``|V|⁻¹ Σ_i relu(T[r, i] − dvec[i])`` (min) or
  ``|V|⁻¹ Σ_i relu((α + β·dvec[i]) − T[r, i])`` (max). The (S, n)
  intermediate the jnp scan body materializes per element never exists;
  table tiles stream past the resident (Bs, 1) accumulator exactly like
  :func:`gain_eval` streams V tiles past the gain block. No matmul (the
  distances are already computed) — this is a VPU reduction kernel, fused
  for HBM traffic.

All kernels normalize by an explicit ``n_total`` rather than ``V.shape[0]``:
passed the *global* ground-set size, they are callable on one row-shard of a
mesh-sharded V (cache sharded alongside), and the per-shard outputs are exact
gain partials that an O(m) ``psum`` turns into the global gains — the
contract the ``device_sharded`` execution plan in :mod:`repro.core.engine`
builds on.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import PrecisionPolicy
from repro.kernels.exemplar_eval import _dist_tile


def _score_tile(cache, d2, n_total: int, fold: str, affine):
    """Scoring epilogue shared by the gain kernels.

    min: |V|⁻¹ Σ relu(m_i − d_ij) — the relu runs in the distance dtype
    (matches ref.marginal_gain_ref). max: |V|⁻¹ Σ relu((α + β·d_ij) − c_i)
    — the affine runs in the distance dtype, the subtraction against the
    float32 cache in float32 (matches the jnp promotion in
    ``functions.gains_rows``). Accumulation is always float32.
    """
    if fold == "min":
        g = jnp.maximum(cache.astype(d2.dtype) - d2, 0.0)
    else:
        a, b = affine
        g = jnp.maximum((a + b * d2) - cache.astype(jnp.float32), 0.0)
    return jnp.sum(g.astype(jnp.float32), axis=0) / n_total


def _fold_tile(cache, dw, fold: str, affine):
    """Winner fold of a float32 cache tile against the winner's distance
    column ``dw`` (computed in-tile at policy precision)."""
    if fold == "min":
        return jnp.minimum(cache, dw.astype(jnp.float32))
    a, b = affine
    return jnp.maximum(cache, jnp.maximum(a + b * dw.astype(jnp.float32), 0.0))


def _gain_kernel(v_ref, c_ref, cache_ref, out_ref, *,
                 n_total: int, policy: PrecisionPolicy, rbf_gamma,
                 fold: str, affine):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...].astype(policy.compute_dtype)      # (Bn, d)
    c = c_ref[...].astype(policy.compute_dtype)      # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    partial = _score_tile(cache_ref[...], d2, n_total, fold, affine)
    out_ref[...] += partial[:, None]


def gain_eval(
    V: jax.Array,          # (n_pad, d_pad)
    C: jax.Array,          # (m_pad, d_pad)
    cache: jax.Array,      # (n_pad, 1) float32 (transformed if rbf)
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    fold: str = "min",
    affine: Optional[tuple] = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns (m_pad, 1) float32 marginal gains."""
    n_pad, d_pad = V.shape
    m_pad = C.shape[0]
    grid = (m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_kernel, n_total=n_total, policy=policy, rbf_gamma=rbf_gamma,
        fold=fold, affine=affine)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(V, C, cache)


def _gain_update_kernel(v_ref, c_ref, cache_ref, w_ref, wv_ref,
                        gain_ref, cache_out_ref,
                        *, n_total: int, policy: PrecisionPolicy, rbf_gamma,
                        fold: str, affine):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        gain_ref[...] = jnp.zeros_like(gain_ref)

    v = v_ref[...].astype(policy.compute_dtype)      # (Bn, d)
    w = w_ref[...].astype(policy.compute_dtype)      # (1, d) previous winner
    cache = cache_ref[...].astype(jnp.float32)       # (Bn, 1)
    dw = _dist_tile(v, w, policy, rbf_gamma)         # (Bn, 1)
    # w_valid gates the fold (round 0 has no winner; the max fold is not
    # idempotent, so an ungated seed row would corrupt the cache)
    new_cache = jnp.where(wv_ref[0, 0] > 0,
                          _fold_tile(cache, dw, fold, affine), cache)
    cache_out_ref[...] = new_cache                   # idempotent across m tiles

    c = c_ref[...].astype(policy.compute_dtype)      # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    partial = _score_tile(new_cache, d2, n_total, fold, affine)
    gain_ref[...] += partial[:, None]


def gain_update_eval(
    V: jax.Array,          # (n_pad, d_pad)
    C: jax.Array,          # (m_pad, d_pad)
    cache: jax.Array,      # (n_pad, 1) float32 — cache *before* the winner
    winner: jax.Array,     # (1, d_pad) — previous round's winning candidate
    w_valid: jax.Array,    # (1, 1) float32 — 0 disables the fold (round 0)
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    fold: str = "min",
    affine: Optional[tuple] = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused greedy step: fold ``winner`` into the cache, score all candidates.

    Returns ``(gains (m_pad, 1), new_cache (n_pad, 1))`` — both float32.
    """
    n_pad, d_pad = V.shape
    m_pad = C.shape[0]
    grid = (m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_update_kernel, n_total=n_total, policy=policy,
        rbf_gamma=rbf_gamma, fold=fold, affine=affine)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d_pad), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ),
        interpret=interpret,
    )(V, C, cache, winner, w_valid)


def _gain_kernel_batched(v_ref, c_ref, cache_ref, out_ref, *,
                         n_total: int, policy: PrecisionPolicy, rbf_gamma,
                         fold: str, affine):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[0].astype(policy.compute_dtype)        # (Bn, d)
    c = c_ref[0].astype(policy.compute_dtype)        # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    partial = _score_tile(cache_ref[0], d2, n_total, fold, affine)
    out_ref[...] += partial[None, :, None]


def gain_eval_batched(
    V: jax.Array,          # (B, n_pad, d_pad)
    C: jax.Array,          # (B, m_pad, d_pad)
    cache: jax.Array,      # (B, n_pad, 1) float32
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    fold: str = "min",
    affine: Optional[tuple] = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched :func:`gain_eval` — B independent requests, ONE kernel launch.

    The grid grows a leading batch axis: ``(B, m_tiles, n_tiles)`` with n
    still innermost, so each (b, i) output block accumulates over its own
    request's V tiles exactly as the unbatched kernel does — per-request tile
    partitioning and accumulation order are identical, which is what makes
    batched selections bit-compatible with the unbatched engine. Returns
    (B, m_pad, 1) float32 gains.

    Under the batched-sharded plans this runs INSIDE shard_map on each
    device's (B, n_loc, d) row shard: the grid is (B, m_tiles,
    local-n_tiles), ``n_total`` stays the GLOBAL ground-set size so each
    shard's normalized gain tile is an exact psum partial (zero-padded rows
    score exact-zero partials), and the per-shard outputs stack into the
    round's single O(B·m) collective — same template as the unbatched
    sharded path, with the batch axis riding the grid and the payload.
    """
    B, n_pad, d_pad = V.shape
    m_pad = C.shape[1]
    grid = (B, m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_kernel_batched, n_total=n_total, policy=policy,
        rbf_gamma=rbf_gamma, fold=fold, affine=affine)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_m, d_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_n, 1), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, 1), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m_pad, 1), jnp.float32),
        interpret=interpret,
    )(V, C, cache)


def _gain_update_kernel_batched(v_ref, c_ref, cache_ref, w_ref, wv_ref,
                                gain_ref, cache_out_ref,
                                *, n_total: int, policy: PrecisionPolicy,
                                rbf_gamma, fold: str, affine):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        gain_ref[...] = jnp.zeros_like(gain_ref)

    v = v_ref[0].astype(policy.compute_dtype)        # (Bn, d)
    w = w_ref[0].astype(policy.compute_dtype)        # (1, d) request's winner
    cache = cache_ref[0].astype(jnp.float32)         # (Bn, 1)
    dw = _dist_tile(v, w, policy, rbf_gamma)         # (Bn, 1)
    # per-request w_valid gate: requests whose previous round was masked
    # (ragged k) or round 0 must not fold
    new_cache = jnp.where(wv_ref[0, 0, 0] > 0,
                          _fold_tile(cache, dw, fold, affine), cache)
    cache_out_ref[...] = new_cache[None]             # idempotent across m tiles

    c = c_ref[0].astype(policy.compute_dtype)        # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    partial = _score_tile(new_cache, d2, n_total, fold, affine)
    gain_ref[...] += partial[None, :, None]


def gain_update_eval_batched(
    V: jax.Array,          # (B, n_pad, d_pad)
    C: jax.Array,          # (B, m_pad, d_pad)
    cache: jax.Array,      # (B, n_pad, 1) float32 — caches *before* winners
    winner: jax.Array,     # (B, 1, d_pad) — per-request previous winner
    w_valid: jax.Array,    # (B, 1, 1) float32 — per-request fold gate
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    fold: str = "min",
    affine: Optional[tuple] = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batched :func:`gain_update_eval`: per-request fold + score, one launch.

    Every request carries its own winner row and its own ``w_valid`` gate
    (round 0, and rounds past a request's effective k under ragged-k
    masking, pass 0 so the fold is a no-op for that request only). Returns
    ``(gains (B, m_pad, 1), new_cache (B, n_pad, 1))``.
    """
    B, n_pad, d_pad = V.shape
    m_pad = C.shape[1]
    grid = (B, m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_update_kernel_batched, n_total=n_total, policy=policy,
        rbf_gamma=rbf_gamma, fold=fold, affine=affine)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_m, d_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_n, 1), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, 1, d_pad), lambda b, i, j: (b, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_m, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_n, 1), lambda b, i, j: (b, j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, m_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, n_pad, 1), jnp.float32),
        ),
        interpret=interpret,
    )(V, C, cache, winner, w_valid)


def _sieve_gain_kernel(t_ref, dvec_ref, out_ref, *, n_total: int,
                       fold: str, affine):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = t_ref[...].astype(jnp.float32)               # (Bs, Bn) cache rows
    dv = dvec_ref[...].astype(jnp.float32)           # (1, Bn) element row
    if fold == "min":
        g = jnp.maximum(t - dv, 0.0)
    else:
        a, b = affine
        g = jnp.maximum((a + b * dv) - t, 0.0)
    out_ref[...] += (jnp.sum(g, axis=1) / n_total)[:, None]


def sieve_gain_eval(
    T: jax.Array,          # (s_pad, n_pad) float32 cache-table rows
    dvec: jax.Array,       # (1, n_pad) float32 distance row of one element
    *,
    n_total: int,
    block_s: int,
    block_n: int,
    fold: str = "min",
    affine: Optional[tuple] = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns (s_pad, 1) float32 per-row gains.

    Rows are arbitrary per-element caches (live sieves, stale slots, or the
    seed empty-set cache whose gain is the singleton Δ(e | ∅)); callers mask
    rows downstream. Padding contributes exactly 0 in both directions: the
    min template zero-pads rows/columns (``relu(0 − d) = 0`` for d ≥ 0), the
    max template pads them +inf (``relu(s − inf) = 0``, and a +inf dvec
    column drives the affine to −inf before the relu) — :func:`ops.sieve_gains`
    applies the matching sentinel.
    """
    s_pad, n_pad = T.shape
    grid = (s_pad // block_s, n_pad // block_n)
    kern = functools.partial(_sieve_gain_kernel, n_total=n_total,
                             fold=fold, affine=affine)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, 1), jnp.float32),
        interpret=interpret,
    )(T, dvec)


def _sieve_gain_kernel_batched(t_ref, dvec_ref, out_ref, *, n_total: int,
                               fold: str, affine):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = t_ref[0].astype(jnp.float32)                 # (Bs, Bn) cache rows
    dv = dvec_ref[0].astype(jnp.float32)             # (1, Bn) element row
    if fold == "min":
        g = jnp.maximum(t - dv, 0.0)
    else:
        a, b = affine
        g = jnp.maximum((a + b * dv) - t, 0.0)
    out_ref[...] += (jnp.sum(g, axis=1) / n_total)[None, :, None]


def sieve_gain_eval_batched(
    T: jax.Array,          # (P, s_pad, n_pad) float32 per-partition tables
    dvec: jax.Array,       # (P, 1, n_pad) float32 per-partition element rows
    *,
    n_total: int,
    block_s: int,
    block_n: int,
    fold: str = "min",
    affine: Optional[tuple] = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched :func:`sieve_gain_eval` — P partition tables, ONE launch.

    The grid grows a leading partition axis ``(P, s_tiles, n_tiles)``
    mirroring :func:`gain_eval_batched`: each (p, i) output block
    accumulates over its own partition's n tiles in the same order as the
    unbatched kernel, so per-partition gains are bit-identical to P separate
    :func:`sieve_gain_eval` calls. Returns (P, s_pad, 1) float32.
    """
    P, s_pad, n_pad = T.shape
    grid = (P, s_pad // block_s, n_pad // block_n)
    kern = functools.partial(_sieve_gain_kernel_batched, n_total=n_total,
                             fold=fold, affine=affine)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_n), lambda p, i, j: (p, i, j)),
            pl.BlockSpec((1, 1, block_n), lambda p, i, j: (p, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_s, 1), lambda p, i, j: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, s_pad, 1), jnp.float32),
        interpret=interpret,
    )(T, dvec)
