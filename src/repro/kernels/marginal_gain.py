"""Pallas TPU kernels for optimizer-aware greedy marginal gains (beyond paper).

For Greedy, every candidate set shares the base S, so with the min-distance
cache ``m_i = min_{s∈S∪{e0}} d(v_i, s)`` the marginal gain collapses to

    Δ(c_j | S) = |V|⁻¹ Σ_i max(m_i − d(v_i, c_j), 0)

— one (n × m) distance matrix (a single Gram matmul) + a ReLU/sum epilogue,
fused here so the distance matrix never reaches HBM. Grid ``(m_tiles,
n_tiles)`` with n innermost, accumulating into the (Bm, 1) output block.

Two kernels:

* :func:`gain_eval` — gains against a given cache (one greedy round's scoring).
* :func:`gain_update_eval` — the fused *gain + cache-update* step used by the
  device-resident greedy engine. The previous round's winner ``w`` rides along
  as an extra (1, d) operand; the epilogue recomputes ``d(v_i, w)`` in-tile,
  folds it into the cache (``m_i ← min(m_i, d(v_i, w))``) and scores the
  current round's gains against the *updated* cache — so the winner's distance
  column never re-materializes in HBM (only the (n,) cache itself, which is
  required state, is written back).

A third kernel serves the streaming sieve engine:

* :func:`sieve_gain_eval` — the fused relu-mean of a whole sieve cache
  *table* against one stream element's distance row: for every table row r,
  ``|V|⁻¹ Σ_i relu(T[r, i] − dvec[i])``. The (S, n) relu intermediate the
  jnp scan body materializes per element never exists; table tiles stream
  past the resident (Bs, 1) accumulator exactly like :func:`gain_eval`
  streams V tiles past the gain block. No matmul (the distances are already
  computed) — this is a VPU reduction kernel, fused for HBM traffic.

All kernels normalize by an explicit ``n_total`` rather than ``V.shape[0]``:
passed the *global* ground-set size, they are callable on one row-shard of a
mesh-sharded V (cache sharded alongside), and the per-shard outputs are exact
gain partials that an O(m) ``psum`` turns into the global gains — the
contract the ``device_sharded`` execution plan in :mod:`repro.core.engine`
builds on.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import PrecisionPolicy
from repro.kernels.exemplar_eval import _dist_tile


def _relu_sum_tile(cache, d2, n_total: int):
    """Scoring epilogue shared by both kernels: |V|⁻¹ Σ relu(m_i − d_ij).

    The relu runs in the distance dtype (matches ref.marginal_gain_ref), the
    accumulation always in float32.
    """
    g = jnp.maximum(cache.astype(d2.dtype) - d2, 0.0)
    return jnp.sum(g.astype(jnp.float32), axis=0) / n_total


def _gain_kernel(v_ref, c_ref, cache_ref, out_ref, *,
                 n_total: int, policy: PrecisionPolicy, rbf_gamma):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...].astype(policy.compute_dtype)      # (Bn, d)
    c = c_ref[...].astype(policy.compute_dtype)      # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    partial = _relu_sum_tile(cache_ref[...], d2, n_total)
    out_ref[...] += partial[:, None]


def gain_eval(
    V: jax.Array,          # (n_pad, d_pad)
    C: jax.Array,          # (m_pad, d_pad)
    cache: jax.Array,      # (n_pad, 1) float32 (transformed if rbf)
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns (m_pad, 1) float32 marginal gains."""
    n_pad, d_pad = V.shape
    m_pad = C.shape[0]
    grid = (m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_kernel, n_total=n_total, policy=policy, rbf_gamma=rbf_gamma)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(V, C, cache)


def _gain_update_kernel(v_ref, c_ref, cache_ref, w_ref, gain_ref, cache_out_ref,
                        *, n_total: int, policy: PrecisionPolicy, rbf_gamma):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        gain_ref[...] = jnp.zeros_like(gain_ref)

    v = v_ref[...].astype(policy.compute_dtype)      # (Bn, d)
    w = w_ref[...].astype(policy.compute_dtype)      # (1, d) previous winner
    cache = cache_ref[...].astype(jnp.float32)       # (Bn, 1)
    dw = _dist_tile(v, w, policy, rbf_gamma)         # (Bn, 1)
    new_cache = jnp.minimum(cache, dw.astype(jnp.float32))
    cache_out_ref[...] = new_cache                   # idempotent across m tiles

    c = c_ref[...].astype(policy.compute_dtype)      # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    partial = _relu_sum_tile(new_cache, d2, n_total)
    gain_ref[...] += partial[:, None]


def gain_update_eval(
    V: jax.Array,          # (n_pad, d_pad)
    C: jax.Array,          # (m_pad, d_pad)
    cache: jax.Array,      # (n_pad, 1) float32 — cache *before* the winner
    winner: jax.Array,     # (1, d_pad) — previous round's winning candidate
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused greedy step: fold ``winner`` into the cache, score all candidates.

    Returns ``(gains (m_pad, 1), new_cache (n_pad, 1))`` — both float32.
    """
    n_pad, d_pad = V.shape
    m_pad = C.shape[0]
    grid = (m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_update_kernel, n_total=n_total, policy=policy, rbf_gamma=rbf_gamma)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d_pad), lambda i, j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ),
        interpret=interpret,
    )(V, C, cache, winner)


def _sieve_gain_kernel(t_ref, dvec_ref, out_ref, *, n_total: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t = t_ref[...].astype(jnp.float32)               # (Bs, Bn) cache rows
    dv = dvec_ref[...].astype(jnp.float32)           # (1, Bn) element row
    g = jnp.maximum(t - dv, 0.0)
    out_ref[...] += (jnp.sum(g, axis=1) / n_total)[:, None]


def sieve_gain_eval(
    T: jax.Array,          # (s_pad, n_pad) float32 cache-table rows
    dvec: jax.Array,       # (1, n_pad) float32 distance row of one element
    *,
    n_total: int,
    block_s: int,
    block_n: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns (s_pad, 1) float32 per-row relu-mean gains.

    Rows are arbitrary min-distance caches (live sieves, stale slots, or the
    ``d_e0`` empty-set cache whose gain is the singleton Δ(e | ∅)); callers
    mask rows downstream. Zero-padded rows/columns contribute exactly 0
    (``relu(0 − d) = 0`` for d ≥ 0), so padding never changes a gain.
    """
    s_pad, n_pad = T.shape
    grid = (s_pad // block_s, n_pad // block_n)
    kern = functools.partial(_sieve_gain_kernel, n_total=n_total)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, 1), jnp.float32),
        interpret=interpret,
    )(T, dvec)
