"""Pallas TPU kernel for optimizer-aware greedy marginal gains (beyond paper).

For Greedy, every candidate set shares the base S, so with the min-distance
cache ``m_i = min_{s∈S∪{e0}} d(v_i, s)`` the marginal gain collapses to

    Δ(c_j | S) = |V|⁻¹ Σ_i max(m_i − d(v_i, c_j), 0)

— one (n × m) distance matrix (a single Gram matmul) + a ReLU/sum epilogue,
fused here so the distance matrix never reaches HBM. Grid ``(m_tiles,
n_tiles)`` with n innermost, accumulating into the (Bm, 1) output block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import PrecisionPolicy
from repro.kernels.exemplar_eval import _dist_tile


def _gain_kernel(v_ref, c_ref, cache_ref, out_ref, *,
                 n_total: int, policy: PrecisionPolicy, rbf_gamma):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...].astype(policy.compute_dtype)      # (Bn, d)
    c = c_ref[...].astype(policy.compute_dtype)      # (Bm, d)
    d2 = _dist_tile(v, c, policy, rbf_gamma)         # (Bn, Bm)
    cache = cache_ref[...].astype(d2.dtype)          # (Bn, 1)
    g = jnp.maximum(cache - d2, 0.0)                 # relu(m_i − d_ij)
    partial = jnp.sum(g.astype(jnp.float32), axis=0) / n_total
    out_ref[...] += partial[:, None]


def gain_eval(
    V: jax.Array,          # (n_pad, d_pad)
    C: jax.Array,          # (m_pad, d_pad)
    cache: jax.Array,      # (n_pad, 1) float32 (transformed if rbf)
    *,
    n_total: int,
    policy: PrecisionPolicy,
    block_n: int,
    block_m: int,
    rbf_gamma: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns (m_pad, 1) float32 marginal gains."""
    n_pad, d_pad = V.shape
    m_pad = C.shape[0]
    grid = (m_pad // block_m, n_pad // block_n)
    kern = functools.partial(
        _gain_kernel, n_total=n_total, policy=policy, rbf_gamma=rbf_gamma)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(V, C, cache)
