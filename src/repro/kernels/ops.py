"""Jit'd public wrappers around the Pallas kernels.

Handles everything the CUDA host code in the paper handles:

* **kernel configuration** (paper's ``C = (D_g, D_b)`` formula): block sizes
  are chosen from a VMEM budget exactly like the paper chooses ``b_x = min(
  ⌊1024/b_y⌋, ⌊β/γ⌋)`` from the shared-memory budget β — see
  :func:`kernel_config`.
* **padding / layout** (paper's vectorization routine §IV-B-2): d is padded to
  the 128-lane boundary, n/l to block multiples, and the ``flat`` variant
  pre-transposes the multiset to k-major layout (the TPU analogue of
  round-robin interleaving).
* **chunking** (paper §IV-B-3): an optional memory budget splits the multiset.
* **interpret fallback**: on CPU backends the kernels run in interpret mode
  (bit-accurate Python execution of the kernel body).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.evaluator import plan_chunks
from repro.core.precision import PrecisionPolicy, FP32
from repro.kernels import exemplar_eval as _ee
from repro.kernels import marginal_gain as _mg

LANE = 128
SUBLANE = 8
#: default per-operand VMEM budget for the multiset tile (bytes)
VMEM_S_BUDGET = 4 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """TPU analogue of the paper's kernel configuration C = (D_g, D_b)."""

    block_n: int   # ground vectors per tile (paper: b_x)
    block_l: int   # evaluation sets per tile (paper: b_y)

    def grid(self, n_pad: int, l_pad: int) -> tuple[int, int]:
        # paper eq. 8: g_x = ⌈|V|/b_x⌉, g_y = ⌈|S_multi|/b_y⌉
        return (l_pad // self.block_l, n_pad // self.block_n)


def kernel_config(k: int, d_pad: int, policy: PrecisionPolicy,
                  l: int, n: int,
                  s_budget_bytes: int = VMEM_S_BUDGET,
                  mode: str = "traffic_opt") -> KernelConfig:
    """Pick block dims (paper's b_x/b_y computation, then one step further).

    ``mode="paper"`` reproduces the paper's greedy rule: maximize the number
    of sets per block first (b_y), then fill b_x — optimal for reuse of the
    shared-memory-staged V rows on a GPU.

    ``mode="traffic_opt"`` (default, §Perf K4): minimize total HBM traffic
      T(Bn, Bl) = n·d·cs·⌈l/Bl⌉  (V re-read per l-tile row)
                + l·k·d·cs·⌈n/Bn⌉ (S re-read per n-tile column)
    subject to the VMEM working set (V tile + S tile + distance tile). The
    paper's rule fixes Bn=256 and spends all VMEM on Bl, which over-weights
    the V term; for l·k ≫ n the S term dominates and a balanced split is up
    to ~1.9× less traffic (see benchmarks/kernel_roofline.py).
    """
    cs = policy.itemsize
    cap_l = min(512, _round_up(l, SUBLANE))
    if mode == "paper":
        per_set = k * d_pad * cs
        bl = max(s_budget_bytes // per_set, SUBLANE)
        bl = min(bl, cap_l)
        bl = (bl // SUBLANE) * SUBLANE
        return KernelConfig(block_n=min(256, _round_up(n, SUBLANE)), block_l=bl)

    vmem_cap = 3 * s_budget_bytes  # total working-set budget (~12 MiB)
    best, best_t = None, None
    bn_opts = [b for b in (64, 128, 256, 512, 1024)
               if b <= _round_up(n, SUBLANE)] or [SUBLANE]
    bl_opts = [b for b in (8, 16, 32, 64, 128, 256, 512) if b <= cap_l] or [SUBLANE]
    for bn in bn_opts:
        for bl in bl_opts:
            work = (bn * d_pad * cs + bl * k * d_pad * cs  # V + S tiles
                    + bn * bl * k * 4)                     # distance tile
            if work > vmem_cap:
                continue
            traffic = (n * d_pad * cs * math.ceil(l / bl)
                       + l * k * d_pad * cs * math.ceil(n / bn))
            if best_t is None or traffic < best_t:
                best, best_t = (bn, bl), traffic
    if best is None:
        best = (SUBLANE, SUBLANE)
    return KernelConfig(block_n=best[0], block_l=best[1])


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_axis(x: jax.Array, target: int, axis: int,
              value: float = 0.0) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# exemplar_eval
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("policy", "mode", "variant", "interpret", "rbf_gamma",
                     "n_total", "cfgk"),
)
def _exemplar_eval_padded(V, S, lengths, d_e0, *, policy, mode, variant,
                          interpret, rbf_gamma, n_total, cfgk: KernelConfig):
    l = lengths.shape[0]
    k = S.shape[1]
    d_pad = _round_up(S.shape[2], LANE)
    bl, bn = cfgk.block_l, cfgk.block_n
    l_pad = _round_up(l, bl)
    n_pad = _round_up(V.shape[0], bn)

    Vp = _pad_axis(_pad_axis(V, n_pad, 0), d_pad, 1)
    Sp = _pad_axis(_pad_axis(S, l_pad, 0), d_pad, 2)
    lens_p = _pad_axis(lengths.astype(jnp.int32), l_pad, 0)[:, None]
    e0_p = _pad_axis(d_e0.astype(jnp.float32), n_pad, 0)[:, None]

    if mode == "fused":
        if variant == "flat":
            Sp = jnp.transpose(Sp, (1, 0, 2))  # k-major (paper's interleave)
        out = _ee.fused_eval(
            Vp, Sp, lens_p, e0_p, n_total=n_total, policy=policy,
            block_n=bn, block_l=bl, variant=variant, rbf_gamma=rbf_gamma,
            interpret=interpret)
        return out[:l, 0]
    elif mode == "two_pass":
        W = _ee.two_pass_eval(
            Vp, Sp, lens_p, e0_p, n_total=n_total, policy=policy,
            block_n=bn, block_l=bl, rbf_gamma=rbf_gamma, interpret=interpret)
        # second pass: the paper's W·1 row reduction
        return jnp.sum(W, axis=1)[:l]
    raise ValueError(f"unknown mode {mode!r}")


def exemplar_eval(
    V: jax.Array,
    S: jax.Array,            # (l, k, d)
    lengths: jax.Array,      # (l,)
    d_e0: jax.Array,         # (n,)
    *,
    policy: PrecisionPolicy = FP32,
    mode: str = "fused",
    variant: str = "flat",
    interpret: Optional[bool] = None,
    memory_budget_bytes: Optional[int | str] = None,  # int | None | "auto"
    rbf_gamma: Optional[float] = None,
) -> jax.Array:
    """L(S_j ∪ {e0}) for the packed multiset — (l,) float32."""
    if interpret is None:
        interpret = _is_cpu()
    n, d = V.shape
    l, k, _ = S.shape
    d_pad = _round_up(d, LANE)
    cfgk = kernel_config(k, d_pad, policy, l, n)
    chunks = plan_chunks(l, n, k, d, policy, mode, memory_budget_bytes)
    outs = []
    for start, stop in chunks:
        outs.append(
            _exemplar_eval_padded(
                V, S[start:stop], lengths[start:stop], d_e0,
                policy=policy, mode=mode, variant=variant,
                interpret=interpret, rbf_gamma=rbf_gamma, n_total=n,
                cfgk=cfgk,
            )
        )
    return jnp.concatenate(outs) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
# marginal_gain
# ---------------------------------------------------------------------------


def _pad_gain_operands(V, C, cache, block_n, block_m, cache_pad: float = 0.0):
    """Pad V/C/cache to lane- and block-aligned shapes for the gain kernels.

    ``cache_pad`` is the dead-row sentinel for the padded cache entries: 0
    under the min template (relu(0 − d) = 0 for d ≥ 0) and +inf under the
    max template (relu(s − inf) = 0 — a zero-padded V row has *positive*
    similarity to candidates, so only an infinite cache entry keeps pad rows
    inert).
    """
    d_pad = _round_up(V.shape[1], LANE)
    n_pad = _round_up(V.shape[0], block_n)
    m_pad = _round_up(C.shape[0], block_m)
    Vp = _pad_axis(_pad_axis(V, n_pad, 0), d_pad, 1)
    Cp = _pad_axis(_pad_axis(C, m_pad, 0), d_pad, 1)
    cache_p = _pad_axis(cache.astype(jnp.float32), n_pad, 0,
                        value=cache_pad)[:, None]
    return Vp, Cp, cache_p, d_pad


def _pad_gain_operands_batched(V, C, cache, block_n, block_m,
                               cache_pad: float = 0.0):
    """Batched (B-leading) analogue of :func:`_pad_gain_operands` — pads the
    row/candidate/feature axes; the batch axis is never padded here (bucket
    padding is the serving layer's job)."""
    d_pad = _round_up(V.shape[2], LANE)
    n_pad = _round_up(V.shape[1], block_n)
    m_pad = _round_up(C.shape[1], block_m)
    Vp = _pad_axis(_pad_axis(V, n_pad, 1), d_pad, 2)
    Cp = _pad_axis(_pad_axis(C, m_pad, 1), d_pad, 2)
    cache_p = _pad_axis(cache.astype(jnp.float32), n_pad, 1,
                        value=cache_pad)[:, :, None]
    return Vp, Cp, cache_p, d_pad


@functools.partial(
    jax.jit,
    static_argnames=("policy", "interpret", "rbf_gamma", "n_total",
                     "block_n", "block_m", "fold", "score_affine"),
)
def _marginal_gain_padded(V, C, cache, *, policy, interpret, rbf_gamma,
                          n_total, block_n, block_m, fold, score_affine):
    m = C.shape[0]
    Vp, Cp, cache_p, _ = _pad_gain_operands(
        V, C, cache, block_n, block_m,
        cache_pad=float("inf") if fold == "max" else 0.0)
    out = _mg.gain_eval(
        Vp, Cp, cache_p, n_total=n_total, policy=policy,
        block_n=block_n, block_m=block_m, rbf_gamma=rbf_gamma,
        fold=fold, affine=score_affine, interpret=interpret)
    return out[:m, 0]


def marginal_gain(
    V: jax.Array,
    C: jax.Array,
    mincache: jax.Array,
    *,
    policy: PrecisionPolicy = FP32,
    interpret: Optional[bool] = None,
    rbf_gamma: Optional[float] = None,
    block_n: int = 256,
    block_m: int = 256,
    n_total: Optional[int] = None,
    fold: str = "min",
    score_affine: Optional[tuple] = None,
) -> jax.Array:
    """Δ(c_j | S) for all candidates — (m,) float32.

    ``n_total`` overrides the |V| normalizer: pass the *global* ground-set
    size when V is one row-shard of a mesh-sharded ground set, so per-shard
    partial gains ``psum`` to the exact global gains. The two axes compose:
    a 3-D ``V`` of shape (B, n_loc, d) inside shard_map is B tenants' row
    shards scored by ONE grid-over-(B, m_tiles, local-n_tiles) launch whose
    (B, m) output is that shard's exact partial of the round's single
    O(B·m) psum (the batched-sharded plans in core/distributed.py).

    ``fold``/``score_affine`` select the kernel template (see
    :mod:`repro.kernels.marginal_gain`): the default ``"min"`` scores the
    exemplar min-distance cache; ``("max", (α, β))`` scores
    relu((α + β·d) − cache) against a max-similarity cache.

    Batched dispatch: pass ``V (B, n, d)``, ``C (B, m, d)``, and
    ``mincache (B, n)`` and the call routes to the grid-over-B kernel —
    one launch scores all B requests with per-request block shapes identical
    to the unbatched path (bit-compatible per-request gains).
    """
    if interpret is None:
        interpret = _is_cpu()
    if V.ndim == 3:
        n = V.shape[1]
        bn = min(block_n, _round_up(n, SUBLANE))
        bm = min(block_m, _round_up(C.shape[1], SUBLANE))
        return _marginal_gain_padded_batched(
            V, C, mincache, policy=policy, interpret=interpret,
            rbf_gamma=rbf_gamma, n_total=n_total if n_total is not None else n,
            block_n=bn, block_m=bm, fold=fold,
            score_affine=None if score_affine is None else tuple(score_affine))
    n = V.shape[0]
    bn = min(block_n, _round_up(n, SUBLANE))
    bm = min(block_m, _round_up(C.shape[0], SUBLANE))
    return _marginal_gain_padded(
        V, C, mincache, policy=policy, interpret=interpret,
        rbf_gamma=rbf_gamma, n_total=n_total if n_total is not None else n,
        block_n=bn, block_m=bm, fold=fold,
        score_affine=None if score_affine is None else tuple(score_affine))


@functools.partial(
    jax.jit,
    static_argnames=("policy", "interpret", "rbf_gamma", "n_total",
                     "block_n", "block_m", "fold", "score_affine"),
)
def _marginal_gain_padded_batched(V, C, cache, *, policy, interpret,
                                  rbf_gamma, n_total, block_n, block_m,
                                  fold, score_affine):
    m = C.shape[1]
    Vp, Cp, cache_p, _ = _pad_gain_operands_batched(
        V, C, cache, block_n, block_m,
        cache_pad=float("inf") if fold == "max" else 0.0)
    out = _mg.gain_eval_batched(
        Vp, Cp, cache_p, n_total=n_total, policy=policy,
        block_n=block_n, block_m=block_m, rbf_gamma=rbf_gamma,
        fold=fold, affine=score_affine, interpret=interpret)
    return out[:, :m, 0]


@functools.partial(
    jax.jit,
    static_argnames=("policy", "interpret", "rbf_gamma", "n_total",
                     "block_n", "block_m", "fold", "score_affine"),
)
def _fused_gain_update_padded(V, C, cache, winner, w_valid, *, policy,
                              interpret, rbf_gamma, n_total, block_n,
                              block_m, fold, score_affine):
    n, m = V.shape[0], C.shape[0]
    Vp, Cp, cache_p, d_pad = _pad_gain_operands(
        V, C, cache, block_n, block_m,
        cache_pad=float("inf") if fold == "max" else 0.0)
    w_p = _pad_axis(winner[None, :], d_pad, 1)
    wv = jnp.reshape(w_valid.astype(jnp.float32), (1, 1))
    gains, new_cache = _mg.gain_update_eval(
        Vp, Cp, cache_p, w_p, wv, n_total=n_total, policy=policy,
        block_n=block_n, block_m=block_m, rbf_gamma=rbf_gamma,
        fold=fold, affine=score_affine, interpret=interpret)
    return gains[:m, 0], new_cache[:n, 0]


def fused_gain_update(
    V: jax.Array,
    C: jax.Array,
    mincache: jax.Array,
    winner: jax.Array,       # (d,) previous round's winning candidate
    *,
    policy: PrecisionPolicy = FP32,
    interpret: Optional[bool] = None,
    rbf_gamma: Optional[float] = None,
    block_n: int = 256,
    block_m: int = 256,
    n_total: Optional[int] = None,
    fold: str = "min",
    score_affine: Optional[tuple] = None,
    w_valid: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused greedy step (device engine): fold ``winner`` into the cache
    (min: cache ← min(cache, d(·, w)); max: cache ← max(cache, s(·, w))),
    then Δ(c_j | S) against the updated cache. Returns ``(gains, new_cache)``.

    ``n_total`` is the sharding-aware normalizer (see :func:`marginal_gain`):
    with V a row-shard, gains come back divided by the *global* n and the
    updated cache shard stays local — exactly the engine's psum contract.

    ``w_valid`` (traced scalar, default 1) gates the fold: pass 0 on the
    round-0 step where no previous winner exists. The min fold is idempotent
    against its own seed so exemplar callers may omit it, but the max fold
    is not — generic callers must gate.

    Batched dispatch: pass ``V (B, n, d)``, ``C (B, m, d)``,
    ``mincache (B, n)``, ``winner (B, d)``, and ``w_valid (B,)`` — one
    launch folds+scores all B requests; the per-request ``w_valid`` lane
    doubles as the ragged-k gate (a request past its effective k passes 0
    and its cache stays frozen in-kernel).
    """
    if interpret is None:
        interpret = _is_cpu()
    if V.ndim == 3:
        n = V.shape[1]
        bn = min(block_n, _round_up(n, SUBLANE))
        bm = min(block_m, _round_up(C.shape[1], SUBLANE))
        if w_valid is None:
            w_valid = jnp.ones((V.shape[0],), jnp.float32)
        return _fused_gain_update_padded_batched(
            V, C, mincache, winner, w_valid, policy=policy,
            interpret=interpret, rbf_gamma=rbf_gamma,
            n_total=n_total if n_total is not None else n,
            block_n=bn, block_m=bm, fold=fold,
            score_affine=None if score_affine is None else tuple(score_affine))
    n = V.shape[0]
    bn = min(block_n, _round_up(n, SUBLANE))
    bm = min(block_m, _round_up(C.shape[0], SUBLANE))
    if w_valid is None:
        w_valid = jnp.float32(1.0)
    return _fused_gain_update_padded(
        V, C, mincache, winner, w_valid, policy=policy, interpret=interpret,
        rbf_gamma=rbf_gamma, n_total=n_total if n_total is not None else n,
        block_n=bn, block_m=bm, fold=fold,
        score_affine=None if score_affine is None else tuple(score_affine))


@functools.partial(
    jax.jit,
    static_argnames=("policy", "interpret", "rbf_gamma", "n_total",
                     "block_n", "block_m", "fold", "score_affine"),
)
def _fused_gain_update_padded_batched(V, C, cache, winner, w_valid, *,
                                      policy, interpret, rbf_gamma, n_total,
                                      block_n, block_m, fold, score_affine):
    n, m = V.shape[1], C.shape[1]
    Vp, Cp, cache_p, d_pad = _pad_gain_operands_batched(
        V, C, cache, block_n, block_m,
        cache_pad=float("inf") if fold == "max" else 0.0)
    w_p = _pad_axis(winner[:, None, :], d_pad, 2)
    wv = jnp.reshape(w_valid.astype(jnp.float32), (-1, 1, 1))
    gains, new_cache = _mg.gain_update_eval_batched(
        Vp, Cp, cache_p, w_p, wv, n_total=n_total, policy=policy,
        block_n=block_n, block_m=block_m, rbf_gamma=rbf_gamma,
        fold=fold, affine=score_affine, interpret=interpret)
    return gains[:, :m, 0], new_cache[:, :n, 0]


# ---------------------------------------------------------------------------
# sieve_gain — streaming sieve engine's fused table × element scoring
# ---------------------------------------------------------------------------


def sieve_gains(
    table: jax.Array,      # (r, n) float32 per-element cache rows
    dvec: jax.Array,       # (n,) float32 one element's distances to V
    *,
    n_total: Optional[int] = None,
    interpret: Optional[bool] = None,
    block_s: int = 64,
    block_n: int = 512,
    fold: str = "min",
    score_affine: Optional[tuple] = None,
) -> jax.Array:
    """Per-row gains of a cache table vs one stream element — (r,).

    min template (default): row r gets
    ``n_total⁻¹ Σ_i relu(table[r, i] − dvec[i])``; max template
    (``fold="max"``, ``score_affine=(α, β)``):
    ``n_total⁻¹ Σ_i relu((α + β·dvec[i]) − table[r, i])``. Row = a sieve's
    cache → its marginal gain Δ(e | S_r); row = the seed → the singleton
    gain Δ(e | ∅). Unlike the jnp scan body, the (r, n) intermediate never
    reaches HBM. NOT jit-wrapped: the streaming engine traces it inside its
    per-block scan (and the host mirror inside the per-element step), so a
    wrapper jit would only add dispatch layers.

    Column padding matches the template: zeros under min (relu(0 − d) = 0),
    +inf under max for BOTH operands — a zero-padded dvec column would score
    relu(α − t) > 0 against finite rows, while a +inf column drives the
    affine to −inf before the relu.
    """
    if interpret is None:
        interpret = _is_cpu()
    r, n = table.shape
    bs = min(block_s, _round_up(r, SUBLANE))
    bn = min(block_n, _round_up(n, LANE))
    pad = float("inf") if fold == "max" else 0.0
    Tp = _pad_axis(
        _pad_axis(table.astype(jnp.float32), _round_up(r, bs), 0, value=pad),
        _round_up(n, bn), 1, value=pad)
    dp = _pad_axis(dvec.astype(jnp.float32), _round_up(n, bn), 0,
                   value=pad)[None, :]
    out = _mg.sieve_gain_eval(
        Tp, dp, n_total=n_total if n_total is not None else n,
        block_s=bs, block_n=bn, fold=fold,
        affine=None if score_affine is None else tuple(score_affine),
        interpret=interpret)
    return out[:r, 0]


def sieve_gains_batched(
    tables: jax.Array,     # (P, r, n) float32 per-partition cache rows
    dvecs: jax.Array,      # (P, n) float32 per-partition element distances
    *,
    n_total: Optional[int] = None,
    interpret: Optional[bool] = None,
    block_s: int = 64,
    block_n: int = 512,
    fold: str = "min",
    score_affine: Optional[tuple] = None,
) -> jax.Array:
    """Batched :func:`sieve_gains` — P partition tables scored against P
    stream elements in ONE grid-over-P kernel launch; returns (P, r).

    Tile sizes, padding sentinels, and per-partition accumulation order
    match the unbatched wrapper exactly, so each partition's gains are
    bit-identical to its own :func:`sieve_gains` call — the invariant the
    batched multi-stream sieve engine's parity rests on. Like the unbatched
    wrapper it is NOT jit-wrapped (it traces inside the batched per-block
    scan).
    """
    if interpret is None:
        interpret = _is_cpu()
    P, r, n = tables.shape
    bs = min(block_s, _round_up(r, SUBLANE))
    bn = min(block_n, _round_up(n, LANE))
    pad = float("inf") if fold == "max" else 0.0
    Tp = _pad_axis(
        _pad_axis(tables.astype(jnp.float32), _round_up(r, bs), 1,
                  value=pad),
        _round_up(n, bn), 2, value=pad)
    dp = _pad_axis(dvecs.astype(jnp.float32), _round_up(n, bn), 1,
                   value=pad)[:, None, :]
    out = _mg.sieve_gain_eval_batched(
        Tp, dp, n_total=n_total if n_total is not None else n,
        block_s=bs, block_n=bn, fold=fold,
        affine=None if score_affine is None else tuple(score_affine),
        interpret=interpret)
    return out[:, :r, 0]
