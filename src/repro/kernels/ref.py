"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel test sweeps (shapes × dtypes,
``assert_allclose``). They intentionally mirror the *mathematical* definition,
not the kernel's tiling, so a tiling bug cannot cancel out.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionPolicy, FP32


def _pair_sqdist(V, S, policy: PrecisionPolicy):
    Vc = V.astype(policy.compute_dtype)
    Sc = S.astype(policy.compute_dtype)
    g = jax.lax.dot_general(
        Vc, Sc, (((1,), (1,)), ((), ())),
        preferred_element_type=policy.accum_dtype,
    )
    vn = jnp.sum(Vc.astype(policy.accum_dtype) ** 2, -1)
    sn = jnp.sum(Sc.astype(policy.accum_dtype) ** 2, -1)
    return jnp.maximum(vn[:, None] + sn[None, :] - 2.0 * g, 0.0)


def exemplar_eval_ref(
    V: jax.Array,
    S: jax.Array,           # (l, k, d)
    lengths: jax.Array,     # (l,)
    d_e0: jax.Array,        # (n,) final (possibly transformed) dist to e0
    policy: PrecisionPolicy = FP32,
    rbf_gamma: Optional[float] = None,
) -> jax.Array:
    """L(S_j ∪ {e0}) for all j — (l,) float32."""
    n = V.shape[0]
    l, k, d = S.shape
    D = _pair_sqdist(V, S.reshape(l * k, d), policy).reshape(n, l, k)
    if rbf_gamma is not None:
        D = 2.0 * (1.0 - jnp.exp(-rbf_gamma * D))
    mask = jnp.arange(k)[None, :] < lengths[:, None]
    big = jnp.asarray(jnp.finfo(D.dtype).max, D.dtype)
    D = jnp.where(mask[None, :, :], D, big)
    dmin = jnp.minimum(jnp.min(D, axis=-1), d_e0[:, None].astype(D.dtype))
    return (jnp.sum(dmin.astype(jnp.float32), axis=0) / n).astype(jnp.float32)


def work_matrix_ref(
    V: jax.Array, S: jax.Array, lengths: jax.Array, d_e0: jax.Array,
    policy: PrecisionPolicy = FP32, rbf_gamma: Optional[float] = None,
) -> jax.Array:
    """The paper's W — (l, n): min-dist(v_i, S_j ∪ {e0}) / n."""
    n = V.shape[0]
    l, k, d = S.shape
    D = _pair_sqdist(V, S.reshape(l * k, d), policy).reshape(n, l, k)
    if rbf_gamma is not None:
        D = 2.0 * (1.0 - jnp.exp(-rbf_gamma * D))
    mask = jnp.arange(k)[None, :] < lengths[:, None]
    big = jnp.asarray(jnp.finfo(D.dtype).max, D.dtype)
    D = jnp.where(mask[None, :, :], D, big)
    dmin = jnp.minimum(jnp.min(D, axis=-1), d_e0[:, None].astype(D.dtype))
    return (dmin.T / n).astype(jnp.float32)


def marginal_gain_ref(
    V: jax.Array,
    C: jax.Array,           # (m, d) candidates
    mincache: jax.Array,    # (n,)
    policy: PrecisionPolicy = FP32,
    rbf_gamma: Optional[float] = None,
) -> jax.Array:
    """Δ(c_j | S) = |V|⁻¹ Σ_i relu(m_i − d(v_i, c_j)) — (m,) float32."""
    D = _pair_sqdist(V, C, policy)
    if rbf_gamma is not None:
        D = 2.0 * (1.0 - jnp.exp(-rbf_gamma * D))
    g = jnp.maximum(mincache[:, None].astype(D.dtype) - D, 0.0)
    return (jnp.sum(g.astype(jnp.float32), axis=0) / V.shape[0]).astype(jnp.float32)
