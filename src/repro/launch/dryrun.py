import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from placeholder host devices, lowers the real train/serve
step with the real sharding specs, compiles, and records memory analysis,
cost analysis, and the collective schedule for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.configs.shapes import SHAPES, Shape, cell_supported, input_specs  # noqa: E402
from repro.distributed.sharding import MeshRules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import roofline as R  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.train.optimizer import OptimizerConfig, init_opt_state  # noqa: E402
from repro.train import step as St  # noqa: E402


def _cfg_for_cell(arch: str, shape: Shape) -> ModelConfig:
    cfg = C.get_config(arch)
    if cfg.family == "encdec":
        cfg = dataclasses.replace(cfg, max_seq_len=max(shape.seq_len, 2048))
    return cfg


def abstract_state(cfg: ModelConfig, with_opt: bool):
    """Abstract (ShapeDtypeStruct) state + captured dim specs, no allocation."""
    box = {}

    def build(key):
        params, dims = M.init_model(cfg, key)
        box["dims"] = dims
        if with_opt:
            return {"params": params, "opt": init_opt_state(params)}
        return params

    abs_state = jax.eval_shape(build, jax.random.PRNGKey(0))
    return abs_state, box["dims"]


def lower_cell(arch: str, shape: Shape, multi_pod: bool, unroll: bool = False,
               cfg: ModelConfig | None = None, microbatches: int = 1):
    """Returns (lowered, compiled, meta) for one cell."""
    if cfg is None:
        cfg = _cfg_for_cell(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MeshRules.for_mesh(mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        state_abs, dims = abstract_state(cfg, with_opt=True)
        sdims = St.state_dims(dims)
        state_sh = St.tree_shardings(rules, state_abs, sdims)
        batch_abs = specs
        batch_sh = St.tree_shardings(rules, batch_abs, St.batch_dims(cfg, batch_abs))
        step = St.make_train_step(cfg, OptimizerConfig(), rules, unroll=unroll,
                                  microbatches=microbatches)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_abs, batch_abs)
    elif shape.kind == "prefill":
        params_abs, dims = abstract_state(cfg, with_opt=False)
        p_sh = St.tree_shardings(rules, params_abs, dims)
        batch_abs = specs
        batch_sh = St.tree_shardings(rules, batch_abs, St.batch_dims(cfg, batch_abs))
        step = St.make_prefill_step(cfg, rules, cache_len=shape.seq_len, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        params_abs, dims = abstract_state(cfg, with_opt=False)
        p_sh = St.tree_shardings(rules, params_abs, dims)
        batch_abs = specs
        batch_sh = {
            "tokens": St.tree_shardings(
                rules, {"t": batch_abs["tokens"]},
                {"t": (("batch",), (None,))})["t"],
            "caches": St.tree_shardings(
                rules, batch_abs["caches"],
                St.cache_dims_tree(cfg, batch_abs["caches"], rules)),
            "pos": NamedSharding(rules.mesh, P()),
        }
        step = St.make_serve_step(cfg, rules, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(p_sh, batch_sh),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)

    n_params = cfg.approx_params()
    meta = {"arch": arch, "shape": shape.name, "kind": shape.kind,
            "multi_pod": multi_pod, "chips": mesh.size,
            "approx_params": n_params}
    return lowered, meta


def extrapolated_costs(arch: str, shape: Shape, microbatches: int = 1) -> dict:
    """Exact depth-extrapolated FLOPs/bytes/collective bytes (see extrapolate.py)."""
    from repro.launch import extrapolate as X

    base_cfg = _cfg_for_cell(arch, shape)
    real = X.layer_kind_counts(base_cfg)
    counts, flops, bytes_, coll, times = [], [], [], [], []
    for cfg_v, cnt in X.depth_variants(base_cfg):
        t0 = time.time()
        lowered, _ = lower_cell(arch, shape, False, unroll=True, cfg=cfg_v,
                                microbatches=microbatches)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        cb = R.collective_bytes(compiled.as_text())
        counts.append(cnt)
        flops.append(float(cost.get("flops", 0.0)))
        bytes_.append(float(cost.get("bytes accessed", 0.0)))
        coll.append(float(cb["total_bytes"]))
        times.append(round(time.time() - t0, 1))
    return {
        "variant_counts": counts,
        "variant_flops": flops,
        "variant_compile_s": times,
        "real_counts": real,
        "flops": X.solve_and_extrapolate(counts, flops, real),
        "bytes": X.solve_and_extrapolate(counts, bytes_, real),
        "collective_bytes": X.solve_and_extrapolate(counts, coll, real),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             unroll: bool = False, extrapolate: bool = False,
             microbatches: int = 1) -> dict:
    shape = SHAPES[shape_name]
    cfg = C.get_config(arch)
    ok, reason = cell_supported(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    out = pathlib.Path(outdir)
    out.mkdir(parents=True, exist_ok=True)
    tag = mesh_tag + ("_unroll" if unroll else "")
    path = out / f"{arch}__{shape_name}__{tag}.json"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "skipped", "reason": reason}
        path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] SKIP {arch} × {shape_name} ({mesh_tag}): {reason}")
        return rec

    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape, multi_pod, unroll=unroll,
                                   microbatches=microbatches)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = R.collective_bytes(compiled.as_text())
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                       else (shape.seq_len if shape.kind == "prefill" else 1))
        mf = R.model_flops(meta["approx_params"], tokens, shape.kind)
        if extrapolate and not multi_pod:
            xc = extrapolated_costs(arch, shape, microbatches=microbatches)
            terms = R.roofline_terms(
                {"flops": xc["flops"], "bytes accessed": xc["bytes"]},
                int(xc["collective_bytes"]))
            terms["source"] = "depth_extrapolated"
            terms["extrapolation"] = xc
        else:
            terms = R.roofline_terms(cost, coll["total_bytes"])
            terms["source"] = "scanned_cost_analysis (while bodies counted once)"
        hlo_flops_global = terms["device_flops"] * meta["chips"]
        rec = {
            **meta,
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "peak_bytes_per_device": (mem.argument_size_in_bytes
                                          + mem.temp_size_in_bytes),
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
            "collectives": coll,
            "roofline": terms,
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / hlo_flops_global
                                   if hlo_flops_global else None),
            "tokens_per_step": tokens,
        }
        path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] OK   {arch} × {shape_name} ({mesh_tag}) "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"bottleneck={terms['bottleneck']} "
              f"peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
        return rec
    except Exception as e:  # record failures — they are bugs to fix
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] FAIL {arch} × {shape_name} ({mesh_tag}): {e}")
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.ARCH_IDS + ["all"])
    ap.add_argument("--shape", default="all",
                    choices=list(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans (accurate cost_analysis flops)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--extrapolate", action="store_true",
                    help="depth-extrapolated exact roofline terms (single-pod)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = C.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out, unroll=args.unroll,
                               extrapolate=args.extrapolate,
                               microbatches=args.microbatches)
                n_fail += rec.get("status") == "error"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
