"""Depth-scaled cost extrapolation — exact FLOP/byte/collective counts.

XLA's ``cost_analysis`` counts a while-loop body once, so the scanned
production model under-reports FLOPs by the trip count. Unrolling the full
model is exact but compiles for minutes. Instead we exploit linearity:

    cost(model) = a + Σ_kind  n_kind · b_kind

where ``a`` is the depth-independent part (embedding, head, loss, optimizer
state for non-layer params) and ``b_kind`` the per-layer cost of each layer
kind. Lowering 2–3 *shallow unrolled* variants with known layer-count vectors
gives a full-rank linear system; solving it and evaluating at the real counts
reproduces the exact unrolled numbers at a fraction of the compile time
(validated against a fully-unrolled lower in tests).
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from repro.models.config import ModelConfig


def layer_kind_counts(cfg: ModelConfig) -> dict[str, int]:
    counts = dict(Counter(cfg.layer_types()))
    if cfg.encoder_layers:
        counts["enc_attn"] = cfg.encoder_layers
        counts = {"enc_attn": cfg.encoder_layers, "dec_attn": cfg.num_layers}
    return counts


def depth_variants(cfg: ModelConfig) -> list[tuple[ModelConfig, dict[str, int]]]:
    """Shallow variants spanning the per-kind count space."""
    R = dataclasses.replace
    fam = cfg.family
    if fam == "encdec":
        vs = [R(cfg, num_layers=1, encoder_layers=1),
              R(cfg, num_layers=2, encoder_layers=2),
              R(cfg, num_layers=1, encoder_layers=2)]
    elif fam == "ssm":  # xlstm: mlstm + slstm kinds
        vs = [R(cfg, num_layers=1, slstm_period=0),
              R(cfg, num_layers=2, slstm_period=0),
              R(cfg, num_layers=2, slstm_period=2)]  # 1 mlstm + 1 slstm
    elif fam == "hybrid":  # hymba: full + sliding attention kinds
        vs = [R(cfg, num_layers=1, full_attn_layers=()),
              R(cfg, num_layers=2, full_attn_layers=()),
              R(cfg, num_layers=2, full_attn_layers=(0,))]
    elif cfg.local_global_period:  # gemma: local + global kinds
        # period > num_layers → all-local variants (keeps the same layer kind)
        vs = [R(cfg, num_layers=1, local_global_period=99),
              R(cfg, num_layers=2, local_global_period=99),
              R(cfg, num_layers=2, local_global_period=2)]
    else:  # uniform dense / moe / vlm
        vs = [R(cfg, num_layers=1), R(cfg, num_layers=2)]
    return [(v, layer_kind_counts(v)) for v in vs]


def solve_and_extrapolate(
    variant_counts: list[dict[str, int]],
    variant_values: list[float],
    real_counts: dict[str, int],
) -> float:
    """Least-squares solve of cost = a + Σ n_k·b_k, evaluated at real counts."""
    kinds = sorted({k for c in variant_counts for k in c} | set(real_counts))
    A = np.array([[1.0] + [float(c.get(k, 0)) for k in kinds]
                  for c in variant_counts])
    y = np.array(variant_values, dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    x = np.array([1.0] + [float(real_counts.get(k, 0)) for k in kinds])
    return float(coef @ x)
