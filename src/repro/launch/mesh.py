"""Production mesh construction.

NOTE: this module must never touch jax device state at import time — the
mesh is built inside a function so tests/benches keep their 1-device world
and only dryrun.py (which sets XLA_FLAGS first) sees 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic-scaling entry point: any (shape, axes) the launcher asks for."""
    return jax.make_mesh(tuple(shape), tuple(axes))
