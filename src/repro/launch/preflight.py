import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Lower-only pre-flight across all cells — catches structural bugs fast."""
import time  # noqa: E402
import traceback  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.configs.shapes import SHAPES, cell_supported  # noqa: E402
from repro.launch.dryrun import lower_cell  # noqa: E402


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    args = ap.parse_args()
    archs = C.ARCH_IDS if args.arch == "all" else [args.arch]
    n_bad = 0
    for arch in archs:
        cfg = C.get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = cell_supported(cfg, shape)
            if not ok:
                print(f"SKIP {arch} × {sname}: {reason}")
                continue
            for mp in (False, True):
                t0 = time.time()
                try:
                    lower_cell(arch, shape, mp)
                    print(f"OK   {arch} × {sname} mp={mp} "
                          f"({time.time()-t0:.1f}s)")
                except Exception as e:
                    n_bad += 1
                    print(f"FAIL {arch} × {sname} mp={mp}: "
                          f"{type(e).__name__}: {e}")
                    traceback.print_exc(limit=8)
    print(f"preflight: {n_bad} failures")
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
