"""Roofline-term extraction from compiled XLA artifacts.

Hardware model (TPU v5e, per task spec):
  peak bf16 compute 197 TFLOP/s/chip, HBM 819 GB/s/chip, ICI ~50 GB/s/link.

``cost_analysis()`` is per-device (the SPMD module is the per-device
program), so the three terms are computed per device:

  compute_s    = device_flops / 197e12
  memory_s     = device_bytes / 819e9
  collective_s = device_collective_bytes / 50e9

collective bytes are parsed from the post-SPMD optimized HLO: the summed
result sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops (async *-start counted once, *-done skipped).
"""
from __future__ import annotations

import re
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

# result type of an HLO instruction: `%name = <ty> opname(` where <ty> may be
# a tuple `(f32[8,128]{1,0}, f32[8]{0})`
_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)(\.[0-9]+)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-op byte totals from optimized HLO text."""
    out = {op: 0 for op in _COLL_OPS}
    counts = {op: 0 for op in _COLL_OPS}
    for m in _INSTR_RE.finditer(hlo_text):
        ty, opname = m.group(1), m.group(2)
        base = opname
        if base.endswith("-start"):
            base = base[:-6]
        elif base.endswith("-done"):
            continue
        if base in _COLL_OPS:
            out[base] += _shape_bytes(ty)
            counts[base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def roofline_terms(cost: dict, coll_total_bytes: int) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll_total_bytes / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])
    return {
        "device_flops": flops,
        "device_bytes": bytes_acc,
        "device_collective_bytes": float(coll_total_bytes),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": dom[0],
        "bound_s": dom[1],
    }


def model_flops(n_params: int, tokens: int, kind: str) -> float:
    """6·N·D for training; 2·N·D forward-only for prefill/decode."""
    if kind == "train":
        return 6.0 * n_params * tokens
    return 2.0 * n_params * tokens
