"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline/dry-run tables."""
from __future__ import annotations

import argparse
import json
import pathlib

from repro import configs as C
from repro.configs.shapes import SHAPES
from repro.launch.roofline import model_flops


def load(outdir: str) -> list[dict]:
    recs = []
    for p in sorted(pathlib.Path(outdir).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(recs) -> str:
    """Single-pod roofline table (§Roofline), markdown."""
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | MODEL_FLOPS/HLO | peak GiB/dev | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("multi_pod") or r.get("status") == "skipped":
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | "
                        f"— | — | {r.get('error','')[:60]} |")
            continue
        t = r["roofline"]
        # recompute useful ratio with current analytic params
        cfg = C.get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        mf = model_flops(cfg.approx_params(), r["tokens_per_step"],
                         r["kind"])
        ratio = mf / (t["device_flops"] * r["chips"]) if t["device_flops"] else 0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"**{t['bottleneck']}** | {ratio:.3f} | "
            f"{fmt_bytes(r['memory']['peak_bytes_per_device'])} | |")
    for r in recs:
        if r.get("status") == "skipped" and not r.get("multi_pod"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                        f" — | — | {r['reason'][:60]} |")
    return "\n".join(rows)


def dryrun_table(recs) -> str:
    """Both-mesh compile summary (§Dry-run)."""
    rows = ["| arch | shape | mesh | status | compile_s | peak GiB/dev | "
            "AR / AG / RS / A2A / CP (count) | coll GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | skipped | "
                        f"— | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR | — |"
                        f" — | — | — |")
            continue
        c = r["collectives"]["counts"]
        cc = (f"{c['all-reduce']} / {c['all-gather']} / "
              f"{c['reduce-scatter']} / {c['all-to-all']} / "
              f"{c['collective-permute']}")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r['compile_s']:.0f} | "
            f"{fmt_bytes(r['memory']['peak_bytes_per_device'])} | {cc} | "
            f"{r['collectives']['total_bytes'] / 2**30:.2f} |")
    return "\n".join(rows)


def stats(recs) -> dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skipped"]
    er = [r for r in recs if r.get("status") == "error"]
    return {"ok": len(ok), "skipped": len(sk), "error": len(er),
            "total": len(recs)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--mode", default="both",
                    choices=["roofline", "dryrun", "both", "stats"])
    args = ap.parse_args()
    recs = load(args.out)
    print(f"<!-- {stats(recs)} -->")
    if args.mode in ("dryrun", "both"):
        print("\n### Dry-run compile matrix\n")
        print(dryrun_table(recs))
    if args.mode in ("roofline", "both"):
        print("\n### Roofline (single-pod, depth-extrapolated)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
