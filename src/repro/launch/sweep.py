import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Full dry-run sweep: every (arch × shape) × {single-pod, multi-pod}.

Single-pod cells also run the depth-extrapolated roofline (§Roofline source).
Writes one JSON per cell into --out; idempotent (--resume skips existing).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402

from repro import configs as C  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--archs", default="all")
    args = ap.parse_args()
    archs = C.ARCH_IDS if args.archs == "all" else args.archs.split(",")
    out = pathlib.Path(args.out)
    done = errors = 0
    for arch in archs:
        for sname in SHAPES:
            for mp in (False, True):
                tag = "pod2x16x16" if mp else "pod16x16"
                path = out / f"{arch}__{sname}__{tag}.json"
                if args.resume and path.exists():
                    rec = json.loads(path.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, sname, mp, args.out,
                               extrapolate=not mp)
                done += 1
                errors += rec.get("status") == "error"
    print(f"[sweep] finished: {done} cells run, {errors} errors")


if __name__ == "__main__":
    main()
