"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3 global layers
    sliding_window: Optional[int] = None
    local_global_period: Optional[int] = None  # gemma3: every Nth layer global
    full_attn_layers: Optional[tuple[int, ...]] = None  # hymba explicit fulls

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    expert_pad_to: Optional[int] = None  # EP divisibility padding (granite)
    moe_capacity: float = 1.25  # capacity factor (tokens over C are dropped)

    # SSM / xLSTM / hymba
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_qkv_block: int = 4  # xlstm mLSTM: block-diagonal q/k/v block size
    slstm_period: int = 0  # xlstm: every Nth layer is sLSTM (7:1 → 8)

    # enc-dec / multimodal frontends (stubs feed precomputed embeddings)
    encoder_layers: int = 0
    frontend: Optional[str] = None  # audio_stub | vision_stub
    frontend_len: int = 0

    # misc
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    dtype: str = "bfloat16"
    max_seq_len: int = 131_072
    # attention flavor applicable for long-context shapes
    subquadratic: bool = False  # True → long_500k cell runs

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads {self.num_heads} not a "
                             f"multiple of kv heads {self.num_kv_heads}")
        if self.num_experts and self.expert_pad_to is None:
            object.__setattr__(self, "expert_pad_to", self.num_experts)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def layer_types(self) -> list[str]:
        """Per-layer block type, in order — drives the scan grouping."""
        out = []
        for i in range(self.num_layers):
            if self.family == "ssm":  # xlstm
                if self.slstm_period and i % self.slstm_period == self.slstm_period - 1:
                    out.append("slstm")
                else:
                    out.append("mlstm")
            elif self.family == "hybrid":
                full = self.full_attn_layers or ()
                out.append("hybrid_full" if i in full else "hybrid_sw")
            elif self.local_global_period:
                if (i + 1) % self.local_global_period == 0:
                    out.append("attn_global")
                else:
                    out.append("attn_local")
            elif self.family == "moe":
                out.append("moe")
            else:
                out.append("attn")
        return out

    def groups(self) -> list[tuple[str, int]]:
        """Compress consecutive identical layer types into scan groups."""
        types = self.layer_types()
        groups: list[tuple[str, int]] = []
        for t in types:
            if groups and groups[-1][0] == t:
                groups[-1] = (t, groups[-1][1] + 1)
            else:
                groups.append((t, 1))
        return groups

    # -- analytic parameter counts (validated in tests) ---------------------

    def attn_params(self) -> int:
        hd = self.head_dim
        return (self.d_model * self.num_heads * hd            # q
                + 2 * self.d_model * self.num_kv_heads * hd   # k, v
                + self.num_heads * hd * self.d_model)         # o

    def mlp_params(self) -> int:
        return 3 * self.d_model * self.d_ff  # SwiGLU: gate, up, down

    def moe_params(self) -> int:
        e = self.expert_pad_to  # allocated (EP-padded) expert count
        return (self.d_model * e                                # router
                + e * 3 * self.d_model * self.d_ff)

    def approx_params(self) -> int:
        """Analytic total parameter count (embeddings + blocks + norms)."""
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        total = emb + head + self.d_model  # final norm
        for t in self.layer_types():
            if t in ("attn", "attn_local", "attn_global"):
                total += self.attn_params() + self.mlp_params() + 2 * self.d_model
                if self.qk_norm:
                    total += 2 * self.head_dim
            elif t == "moe":
                total += self.attn_params() + self.moe_params() + 2 * self.d_model
                if self.qk_norm:
                    total += 2 * self.head_dim
            elif t == "mlstm":
                d = self.d_model
                di = d * self.ssm_expand
                h = self.num_heads
                total += (2 * d * di                   # up, z-gate
                          + self.ssm_conv * di         # causal conv
                          + 3 * di * self.ssm_qkv_block  # block-diag q/k/v
                          + di * 2 * h + h             # i/f gates + f bias
                          + di                         # head norm
                          + di * d                     # down
                          + d)                         # pre-LN
            elif t == "slstm":
                d = self.d_model
                h = self.num_heads
                dh = d // h
                dff = int(d * 4 / 3)
                total += (4 * d * d                    # input gates (i,f,z,o)
                          + h * dh * 4 * dh            # per-head recurrence
                          + 2 * d                      # f bias + head norm
                          + 3 * d * dff                # GeGLU ffn
                          + d)                         # pre-LN
            elif t in ("hybrid_full", "hybrid_sw"):
                d_in = self.d_model * self.ssm_expand
                total += self.attn_params() + 2 * self.d_model
                total += (2 * self.d_model * d_in          # ssm in-proj (x, z)
                          + d_in * self.ssm_conv           # conv
                          + d_in * (2 * self.ssm_state + 1)  # B, C, dt proj
                          + d_in                           # A (per-channel)
                          + d_in * self.d_model)           # out proj
                total += self.mlp_params()
        if self.encoder_layers:
            # whisper: encoder self-attn + mlp, decoder adds cross-attn
            enc = self.encoder_layers * (
                self.attn_params() + self.mlp_params() + 2 * self.d_model)
            cross = self.num_layers * (self.attn_params() + self.d_model)
            total += enc + cross
        return total
