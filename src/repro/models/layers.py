"""Transformer building blocks: norms, RoPE, GQA attention, MLP, MoE.

Everything is a pure function over explicit param trees (leaves created via
:class:`repro.models.params.PLeaf` so dim specs travel with the arrays).

Attention supports the union of the assigned architectures' needs:
  * GQA with arbitrary q/kv ratios (the einsums keep the kv-head dim explicit
    so tensor-parallel sharding applies to it),
  * qk-norm (qwen3, gemma3), RoPE with per-layer theta (gemma3 global layers),
  * causal / sliding-window / bidirectional / cross masks,
  * prefill (returns a KV cache) and single-token decode (ring buffer for
    sliding-window layers → O(window) memory at 500k-token contexts).

MoE uses sort-based grouped-GEMM dispatch with a capacity factor (drop policy)
— the production TPU shape ``(E, C, D) · (E, D, F)`` with the expert dim
sharded over the model axis (EP).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.params import PLeaf, dense_init

NEG_INF = -2.0e38


def _c(rules, x, dims):
    return x if rules is None else rules.constraint(x, dims)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": PLeaf(jnp.ones((d,), dtype), ((None,),))}


def rms_norm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {
        "scale": PLeaf(jnp.ones((d,), dtype), ((None,),)),
        "bias": PLeaf(jnp.zeros((d,), dtype), ((None,),)),
    }


def layer_norm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype, cross: bool = False) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": PLeaf(dense_init(ks[0], (d, h, hd), dtype),
                    (("fsdp",), ("tp",), (None, "tp"))),
        "wk": PLeaf(dense_init(ks[1], (d, hk, hd), dtype),
                    (("fsdp",), ("tp",), (None, "tp"))),
        "wv": PLeaf(dense_init(ks[2], (d, hk, hd), dtype),
                    (("fsdp",), ("tp",), (None, "tp"))),
        "wo": PLeaf(dense_init(ks[3], (h, hd, d), dtype,
                               scale=1.0 / math.sqrt(h * hd)),
                    (("tp",), (None, "tp"), ("fsdp",))),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _mask_bias(mode: str, mask_kind: str, q_len: int, kv_len: int,
               q_pos: jax.Array, kv_pos: jax.Array,
               kv_valid: Optional[jax.Array], window: Optional[int]):
    """(q_len, kv_len) additive bias (or (B, q, kv) if kv_valid is batched)."""
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    if mask_kind == "causal":
        ok = kp <= qp
    elif mask_kind == "sliding":
        ok = (kp <= qp) & (kp > qp - window)
    elif mask_kind in ("bidir", "cross"):
        ok = jnp.ones((q_len, kv_len), bool)
    else:
        raise ValueError(mask_kind)
    bias = jnp.where(ok, 0.0, NEG_INF)
    if kv_valid is not None:
        bias = bias[None] + jnp.where(kv_valid, 0.0, NEG_INF)[:, None, :]
    return bias


def _sdpa(q, k, v, bias, rules, g: int):
    """q: (B,Q,H,D); k,v: (B,K,Hk,D), repeated to H heads (GQA).

    The kv-repeat (a fused broadcast) keeps the head dim at H everywhere so
    tensor-parallel head sharding survives the contraction — reshaping to
    (Hk, G) would split the sharded dim and trigger GSPMD re-replication.
    """
    hd = q.shape[-1]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    if bias.ndim == 2:
        scores = scores + bias[None, None]
    else:
        scores = scores + bias[:, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


_BLOCK_Q_THRESHOLD = 8192   # above this, score matrices stream in q-blocks
_BLOCK_Q = 1024


def _seqshard_applicable(rules, hk: int, buf: int) -> bool:
    """Flash-decoding path: KV cache seq-sharded over the model axis.

    Used when kv heads don't divide the model axis (gemma3 kv=1, qwen3 kv=8
    on a 16-way axis): head_dim-fallback sharding makes QK a sharded-dim
    contraction whose partial scores get all-reduced — (B,H,S) fp32 per layer,
    measured at ~7.5 GB/step on qwen3-0.6b decode_32k. Sharding the *sequence*
    instead turns the combine into a (B,H,D)-sized log-sum-exp psum.
    """
    if rules is None or not hasattr(rules, "mesh"):
        return False
    mesh = rules.mesh
    if "model" not in mesh.shape or mesh.shape["model"] <= 1:
        return False
    nm = mesh.shape["model"]
    return hk % nm != 0 and buf % nm == 0


def _decode_attn_seqshard(q, k_new, v_new, cache, pos, mask_kind, window,
                          rules, g: int):
    """One decode step with a sequence-sharded cache (flash-decoding).

    Inside shard_map over the model axis: write the new KV into the owning
    shard, compute local partial attention with a running max, and combine
    across shards with exp-rescaled psums. Per-layer wire: O(B·H·D) floats
    (vs O(B·H·S) for the head_dim-fallback all-reduce).

    q: (B,1,H,D); k_new/v_new: (B,1,Hk,D); cache k/v: (B,buf,Hk,D).
    Returns (out (B,1,H,D), new_cache).
    """
    import math as _math

    mesh = rules.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
    if dp is not None:
        dp_size = math.prod(mesh.shape[a] for a in dp)
        if q.shape[0] % dp_size != 0:  # e.g. long_500k batch=1: replicate
            dp = None
    nm = mesh.shape["model"]
    buf = cache["k"].shape[1]
    slot = jnp.asarray(pos % buf if mask_kind == "sliding" else pos,
                       jnp.int32)

    def local(qr, kn, vn, kl, vl, slot_g, pos_g):
        B, S_loc, Hk, D = kl.shape
        mi = jax.lax.axis_index("model")
        lo = mi * S_loc
        rel = slot_g - lo
        in_range = (rel >= 0) & (rel < S_loc)
        relc = jnp.clip(rel, 0, S_loc - 1)
        # NOTE §Perf B2: a row-granular .at[relc].set() variant was tried and
        # REFUTED — bytes-accessed rose 9% (the gather of the original row is
        # extra traffic; XLA already fuses this whole-buffer select).
        kl2 = jax.lax.dynamic_update_slice(kl, kn, (0, relc, 0, 0))
        vl2 = jax.lax.dynamic_update_slice(vl, vn, (0, relc, 0, 0))
        kl = jnp.where(in_range, kl2, kl)
        vl = jnp.where(in_range, vl2, vl)

        kr = jnp.repeat(kl, g, axis=2) if g > 1 else kl
        vr = jnp.repeat(vl, g, axis=2) if g > 1 else vl
        scores = jnp.einsum("bqhd,bkhd->bhqk", qr, kr,
                            preferred_element_type=jnp.float32)
        scores = scores / _math.sqrt(D)
        idx = lo + jnp.arange(S_loc, dtype=jnp.int32)      # global slot ids
        if mask_kind == "sliding":
            total = S_loc * nm
            age = (slot_g - idx) % total
            ok = age < jnp.minimum(pos_g + 1, total)
        else:
            ok = idx <= pos_g
        scores = jnp.where(ok[None, None, None, :], scores, NEG_INF)
        m = jnp.maximum(jnp.max(scores, axis=-1), -1e30)   # (B,H,1)
        p = jnp.exp(scores - m[..., None])
        denom = jnp.sum(p, axis=-1)                        # (B,H,1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr)
        M = jax.lax.pmax(m, "model")
        scale = jnp.exp(m - M)                             # (B,H,1)
        o_g = jax.lax.psum(o * scale.transpose(0, 2, 1)[..., None]
                           .astype(o.dtype), "model")
        d_g = jax.lax.psum(denom * scale, "model")
        out = o_g / jnp.maximum(d_g, 1e-30).transpose(0, 2, 1)[..., None] \
            .astype(o_g.dtype)
        return out.astype(qr.dtype), kl, vl

    smapped = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, None, None, None),
                  P(dp, None, None, None), P(dp, "model", None, None),
                  P(dp, "model", None, None), P(), P()),
        out_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                   P(dp, "model", None, None)),
        check_rep=False,
    )
    out, kc, vc = smapped(q, k_new, v_new, cache["k"], cache["v"], slot,
                          jnp.asarray(pos, jnp.int32))
    return out, {"k": kc, "v": vc}


def _sdpa_blocked(q, k, v, kv_pos, mask_kind, window, rules, g: int):
    """Query-blocked attention: scores never exceed (B, H, block, K).

    The memory analogue of flash attention — full softmax rows per q block
    (no online renormalization needed since the whole row fits), scanned over
    blocks with `lax.map`.
    """
    B, S, H, D = q.shape
    nb = S // _BLOCK_Q
    qb = q.reshape(B, nb, _BLOCK_Q, H, D).swapaxes(0, 1)  # (nb, B, blk, H, D)
    qpos = jnp.arange(S, dtype=jnp.int32).reshape(nb, _BLOCK_Q)

    def one(args):
        qblk, qp = args
        bias = _mask_bias("train", mask_kind, _BLOCK_Q, k.shape[1],
                          qp, kv_pos, None, window)
        return _sdpa(qblk, k, v, bias, rules, g)

    out = jax.lax.map(one, (qb, qpos))                    # (nb, B, blk, H, D)
    return out.swapaxes(0, 1).reshape(B, S, H, D)


def attention(
    p, cfg, x, *,
    rules=None,
    mask_kind: str = "causal",
    window: Optional[int] = None,
    theta: Optional[float] = None,
    mode: str = "train",          # train | prefill | decode
    pos_offset=0,                 # decode: current position (traced ok)
    cache: Optional[dict] = None,
    cross_x: Optional[jax.Array] = None,   # encoder output for cross-attn
    cache_len: Optional[int] = None,       # static cache buffer length
):
    """Returns (y, new_cache | None)."""
    B, S, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hk
    theta = cfg.rope_theta if theta is None else theta
    is_cross = cross_x is not None

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    kv_src = cross_x if is_cross else x
    if mode == "decode" and is_cross and cache is not None:
        k = cache["k"]
        v = cache["v"]
    else:
        k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])

    if "q_norm" in p:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps) if not is_cross else k

    if not is_cross:
        if mode == "decode":
            q_pos = jnp.full((S,), 0, jnp.int32) + pos_offset
        else:
            q_pos = jnp.arange(S, dtype=jnp.int32)
        q = rope(q, q_pos, theta)
        if mode == "decode" and cache is not None:
            k = rope(k, q_pos, theta)
        elif mode != "decode":
            k = rope(k, jnp.arange(k.shape[1], dtype=jnp.int32), theta)

    # Activations never shard head_dim: it is the QK contraction dim, and a
    # sharded contraction all-reduces partial *scores* — measured 48 GiB/step
    # f32 on granite train_4k (heads=24 ∤ 16 → the old (None,"tp") fallback).
    # When heads don't divide the model axis GSPMD now picks the layout
    # (params keep the head_dim fallback for storage sharding).
    q = _c(rules, q, (("batch",), (None,), ("tp",), (None,)))
    k = _c(rules, k, (("batch",), (None,), ("tp",), (None,)))
    v = _c(rules, v, (("batch",), (None,), ("tp",), (None,)))

    new_cache = None
    if mode in ("train", "prefill"):
        kv_len = k.shape[1]
        kv_pos = jnp.arange(kv_len, dtype=jnp.int32)
        if S >= _BLOCK_Q_THRESHOLD and S % _BLOCK_Q == 0:
            # blocked (flash-style) attention: O(S·block) score memory
            out = _sdpa_blocked(q, k, v, kv_pos, mask_kind, window, rules, g)
        else:
            bias = _mask_bias(mode, mask_kind, S, kv_len,
                              jnp.arange(S, dtype=jnp.int32), kv_pos, None,
                              window)
            out = _sdpa(q, k, v, bias, rules, g)
        if mode == "prefill":
            # cache layout invariant: position p lives at slot p % buf
            buf = kv_len if cache_len is None else cache_len
            if mask_kind == "sliding" and window is not None:
                buf = min(buf, window)
            take = min(kv_len, buf)
            klast, vlast = k[:, kv_len - take:], v[:, kv_len - take:]
            if take == buf and kv_len % buf != 0:
                shift = kv_len % buf
                klast = jnp.roll(klast, shift, axis=1)
                vlast = jnp.roll(vlast, shift, axis=1)
            if take == buf:
                kc, vc = klast, vlast
            else:
                kc = jnp.zeros((B, buf, hk, hd), k.dtype)
                vc = jnp.zeros((B, buf, hk, hd), v.dtype)
                kc = jax.lax.dynamic_update_slice(kc, klast, (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, vlast, (0, 0, 0, 0))
            new_cache = {"k": kc, "v": vc}
    elif mode == "decode":
        if is_cross:
            kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
            bias = jnp.zeros((S, k.shape[1]), jnp.float32)
            out = _sdpa(q, k, v, bias, rules, g)
            new_cache = cache
        elif _seqshard_applicable(rules, hk, cache["k"].shape[1]):
            out, new_cache = _decode_attn_seqshard(
                q, k, v, cache, pos_offset, mask_kind, window, rules, g)
        else:
            kc, vc = cache["k"], cache["v"]
            buf = kc.shape[1]
            slot = (pos_offset % buf) if (mask_kind == "sliding") else pos_offset
            slot = jnp.asarray(slot, jnp.int32)
            kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
            new_cache = {"k": kc, "v": vc}
            # validity/causality of cache slots
            idx = jnp.arange(buf, dtype=jnp.int32)
            if mask_kind == "sliding":
                # slot ages: written within the last `window` positions
                age = (slot - idx) % buf
                ok = (age < jnp.minimum(pos_offset + 1, buf))
            else:
                ok = idx <= pos_offset
            bias = jnp.where(ok, 0.0, NEG_INF)[None, None, :]  # (1, S=1, buf)
            bias = jnp.broadcast_to(bias, (B, S, buf))
            out = _sdpa(q, kc, vc, bias, rules, g)
    else:
        raise ValueError(mode)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = _c(rules, y, (("batch",), ("sp",), (None,)))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(key, d: int, d_ff: int, dtype, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": PLeaf(dense_init(ks[0], (d, d_ff), dtype),
                      (("fsdp",), ("tp",))),
        "w_down": PLeaf(dense_init(ks[1], (d_ff, d), dtype),
                        (("tp",), ("fsdp",))),
    }
    if gated:
        p["w_gate"] = PLeaf(dense_init(ks[2], (d, d_ff), dtype),
                            (("fsdp",), ("tp",)))
    return p


def mlp(p, x, act: str, rules=None):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = _act(act)(gate) * up
    else:
        h = _act(act)(up)
    h = _c(rules, h, (("batch",), ("sp",), ("tp",)))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return _c(rules, y, (("batch",), ("sp",), (None,)))


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based grouped GEMM, capacity drop policy)
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    e = cfg.expert_pad_to  # padded expert count for EP divisibility
    ks = jax.random.split(key, 4)
    return {
        "router": PLeaf(dense_init(ks[0], (d, e), dtype), (("fsdp",), (None,))),
        "w_gate": PLeaf(dense_init(ks[1], (e, d, dff), dtype),
                        (("expert",), ("fsdp",), (None,))),
        "w_up": PLeaf(dense_init(ks[2], (e, d, dff), dtype),
                      (("expert",), ("fsdp",), (None,))),
        "w_down": PLeaf(dense_init(ks[3], (e, dff, d), dtype),
                        (("expert",), (None,), ("fsdp",))),
    }


def moe(p, cfg, x, act: str, rules=None, capacity_factor: float | None = None):
    """x: (B, S, D) → (B, S, D). Sort-based dispatch, EP over 'expert'."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity
    B, S, D = x.shape
    E = cfg.expert_pad_to
    E_real = cfg.num_experts
    K = cfg.experts_per_tok
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, p["router"],
                        preferred_element_type=jnp.float32)
    if E_real < E:  # padded experts never routed
        pad_bias = jnp.where(jnp.arange(E) < E_real, 0.0, NEG_INF)
        logits = logits + pad_bias[None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)          # (T, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # flatten (token, k) assignments and sort by expert id
    flat_e = top_i.reshape(-1)                      # (T·K,)
    flat_t = jnp.repeat(jnp.arange(T), K)           # (T·K,)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)                     # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    C = max(int(math.ceil(T * K / E * capacity_factor)), 1)
    # rank of each assignment within its expert group
    counts = jnp.bincount(se, length=E)             # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + jnp.clip(rank, 0, C - 1), E * C)  # drop→OOB

    # gather tokens into the (E, C, D) expert buffer
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(xt[st])
    buf = buf[:-1].reshape(E, C, D)
    buf = _c(rules, buf, (("expert",), (None,), (None,)))

    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = _act(act)(gate) * up
    h = _c(rules, h, (("expert",), (None,), (None,)))
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = _c(rules, out, (("expert",), (None,), (None,)))

    # scatter back with routing weights
    out_flat = out.reshape(E * C, D)
    contrib = jnp.where(keep[:, None], out_flat[jnp.clip(slot, 0, E * C - 1)], 0.0)
    y = jnp.zeros((T, D), out.dtype).at[st].add(contrib * sw[:, None].astype(out.dtype))
    return y.reshape(B, S, D)
