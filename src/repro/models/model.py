"""Model assembly: embedding → scanned layer groups → head, for all families.

Layer heterogeneity (gemma3's 5:1 local:global, xlstm's 7:1 mLSTM:sLSTM,
hymba's 3 full-attention layers) is handled by *grouping*: consecutive layers
of the same type are stacked and driven by one `lax.scan`, so HLO size stays
O(#groups), not O(#layers) — this is what keeps 64-layer × 512-device SPMD
compiles tractable.

Modes:
  * ``train``   — full-sequence forward, no caches (remat-wrapped layers).
  * ``prefill`` — full-sequence forward, returns per-layer caches.
  * ``decode``  — one token against the caches (ring buffers for sliding
    windows, O(1) recurrent states for SSM blocks).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.params import (PLeaf, dense_init, split_tree, stack_trees)

ATTN_TYPES = ("attn", "attn_local", "attn_global", "moe")
HYBRID_TYPES = ("hybrid_full", "hybrid_sw")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 8)
    if kind in ATTN_TYPES:
        p = {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        }
        if kind == "moe":
            p["moe"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                                  gated=cfg.act != "gelu_plain")
        return p
    if kind == "mlstm":
        return {"ln": L.init_rmsnorm(cfg.d_model, dtype),
                "mlstm": S.init_mlstm(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln": L.init_rmsnorm(cfg.d_model, dtype),
                "slstm": S.init_slstm(ks[0], cfg, dtype)}
    if kind in HYBRID_TYPES:
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "mamba": S.init_mamba(ks[1], cfg, dtype),
            "attn_norm": L.init_rmsnorm(cfg.d_model, dtype),
            "mamba_norm": L.init_rmsnorm(cfg.d_model, dtype),
            "mix": {"w": PLeaf(jnp.full((2,), 0.5, dtype), ((None,),))},
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
        }
    if kind == "enc_attn":
        return {
            "ln1": L.init_layernorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ln2": L.init_layernorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, gated=False),
        }
    if kind == "dec_attn":
        return {
            "ln1": L.init_layernorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "ln_cross": L.init_layernorm(cfg.d_model, dtype),
            "cross": L.init_attention(ks[1], cfg, dtype, cross=True),
            "ln2": L.init_layernorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, gated=False),
        }
    raise ValueError(kind)


def init_model(cfg: ModelConfig, key) -> tuple[Any, Any]:
    """Returns (params, dims) trees."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 4)
    tree: dict = {
        "embed": {"w": PLeaf(
            dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
            (("tp",), ("fsdp",)))},
        "final_norm": (L.init_layernorm if cfg.family == "encdec"
                       else L.init_rmsnorm)(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        tree["head"] = {"w": PLeaf(
            dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype),
            (("fsdp",), ("tp",)))}
    if cfg.family == "encdec":
        tree["dec_pos"] = {"w": PLeaf(
            dense_init(keys[-3], (cfg.max_seq_len, cfg.d_model), dtype,
                       scale=0.02), ((None,), ("fsdp",)))}

    groups = []
    ki = 0
    if cfg.encoder_layers:
        enc = [_init_layer(keys[ki + i], cfg, "enc_attn", dtype)
               for i in range(cfg.encoder_layers)]
        ki += cfg.encoder_layers
        groups.append(("enc_attn", stack_trees(enc)))
        dec_kinds = [("dec_attn", cfg.num_layers)]
    else:
        dec_kinds = cfg.groups()
    for kind, count in dec_kinds:
        sub = [_init_layer(keys[ki + i], cfg, kind, dtype) for i in range(count)]
        ki += count
        groups.append((kind, stack_trees(sub)))
    tree["groups"] = {f"g{i}_{kind}": sub for i, (kind, sub) in enumerate(groups)}
    return split_tree(tree)


# ---------------------------------------------------------------------------
# per-layer application
# ---------------------------------------------------------------------------


def _attn_kind_args(cfg: ModelConfig, kind: str):
    if kind == "attn_local" or kind == "hybrid_sw":
        return dict(mask_kind="sliding", window=cfg.sliding_window,
                    theta=cfg.rope_theta)
    if kind == "attn_global":
        return dict(mask_kind="causal",
                    theta=cfg.rope_theta_global or cfg.rope_theta)
    if kind == "enc_attn":
        return dict(mask_kind="bidir", theta=cfg.rope_theta)
    return dict(mask_kind="causal", theta=cfg.rope_theta)


def apply_layer(p, cfg: ModelConfig, kind: str, x, *, rules, mode,
                pos_offset, cache, cross_x, cache_len):
    """One layer of the given kind. Returns (x, new_cache)."""
    norm = L.layer_norm if cfg.family == "encdec" else L.rms_norm
    new_cache: dict = {}
    if kind in ATTN_TYPES or kind in ("enc_attn", "dec_attn"):
        a_args = _attn_kind_args(cfg, kind)
        h, c_attn = L.attention(
            p["attn"], cfg, norm(p["ln1"], x, cfg.norm_eps), rules=rules,
            mode=mode, pos_offset=pos_offset,
            cache=cache.get("attn") if cache else None,
            cache_len=cache_len, **a_args)
        x = x + h
        if c_attn is not None:
            new_cache["attn"] = c_attn
        if kind == "dec_attn":
            h, c_cross = L.attention(
                p["cross"], cfg, norm(p["ln_cross"], x, cfg.norm_eps),
                rules=rules, mode=mode, pos_offset=pos_offset,
                cache=cache.get("cross") if cache else None,
                cross_x=cross_x, mask_kind="cross",
                cache_len=None)
            x = x + h
            if c_cross is not None:
                new_cache["cross"] = c_cross
        h2in = norm(p["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            from repro.models.moe_ep import ep_applicable, moe_ep
            if ep_applicable(cfg, rules, h2in.shape[0], h2in.shape[1]):
                h2 = moe_ep(p["moe"], cfg, h2in, cfg.act, rules)
            else:
                h2 = L.moe(p["moe"], cfg, h2in, cfg.act, rules=rules)
        else:
            h2 = L.mlp(p["mlp"], h2in,
                       "gelu" if cfg.family == "encdec" else cfg.act,
                       rules=rules)
        x = x + h2
        return x, new_cache
    if kind == "mlstm":
        h, c = S.mlstm_block(p["mlstm"], cfg, L.rms_norm(p["ln"], x, cfg.norm_eps),
                             rules=rules, mode=mode, cache=cache)
        return x + h, c
    if kind == "slstm":
        h, c = S.slstm_block(p["slstm"], cfg, L.rms_norm(p["ln"], x, cfg.norm_eps),
                             rules=rules, mode=mode, cache=cache)
        return x + h, c
    if kind in HYBRID_TYPES:
        xin = L.rms_norm(p["ln1"], x, cfg.norm_eps)
        a_args = _attn_kind_args(cfg, "attn" if kind == "hybrid_full"
                                 else "hybrid_sw")
        ha, c_attn = L.attention(
            p["attn"], cfg, xin, rules=rules, mode=mode,
            pos_offset=pos_offset,
            cache=cache.get("attn") if cache else None,
            cache_len=cache_len, **a_args)
        hm, c_ssm = S.mamba_block(
            p["mamba"], cfg, xin, rules=rules, mode=mode,
            cache={"ssm": cache["ssm"], "conv": cache["conv"]} if cache else None)
        ha = L.rms_norm(p["attn_norm"], ha, cfg.norm_eps)
        hm = L.rms_norm(p["mamba_norm"], hm, cfg.norm_eps)
        w = p["mix"]["w"].astype(ha.dtype)
        x = x + w[0] * ha + w[1] * hm
        h2 = L.mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                   rules=rules)
        x = x + h2
        nc = dict(c_ssm or {})
        if c_attn is not None:
            nc["attn"] = c_attn
        return x, nc
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------


def _layer_cache_spec(cfg: ModelConfig, kind: str, B: int, cache_len: int,
                      dtype) -> dict:
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    di = cfg.d_model * cfg.ssm_expand
    H = cfg.num_heads

    def kv(slen):
        return {"k": jax.ShapeDtypeStruct((B, slen, hk, hd), dtype),
                "v": jax.ShapeDtypeStruct((B, slen, hk, hd), dtype)}

    if kind in ("attn", "attn_global", "moe"):
        return {"attn": kv(cache_len)}
    if kind in ("attn_local",):
        return {"attn": kv(min(cache_len, cfg.sliding_window))}
    if kind == "dec_attn":
        return {"attn": kv(cache_len), "cross": kv(cfg.frontend_len)}
    if kind == "mlstm":
        dh = di // H
        return {"ssm": (jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
                        jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
                        jax.ShapeDtypeStruct((B, H), jnp.float32)),
                "conv": jax.ShapeDtypeStruct((B, cfg.ssm_conv - 1, di), dtype)}
    if kind == "slstm":
        dh = cfg.d_model // H
        st = jax.ShapeDtypeStruct((B, H, dh), jnp.float32)
        return {"ssm": (st, st, st, st)}
    if kind in HYBRID_TYPES:
        sw = (min(cache_len, cfg.sliding_window)
              if kind == "hybrid_sw" else cache_len)
        return {"attn": kv(sw),
                "ssm": jax.ShapeDtypeStruct((B, di, cfg.ssm_state), jnp.float32),
                "conv": jax.ShapeDtypeStruct((B, cfg.ssm_conv - 1, di), dtype)}
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, B: int, cache_len: int):
    """ShapeDtypeStruct tree for the full decode cache (stacked per group)."""
    dtype = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.encoder_layers:
        groups = [("dec_attn", cfg.num_layers)]
        out["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_len, cfg.d_model), dtype)
    else:
        groups = cfg.groups()
    gi = 1 if cfg.encoder_layers else 0  # encoder group holds no decode cache
    for i, (kind, count) in enumerate(groups):
        spec = _layer_cache_spec(cfg, kind, B, cache_len, dtype)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((count,) + s.shape, s.dtype), spec)
        out[f"g{i + gi}_{kind}"] = stacked
    return out


def zero_caches(cfg: ModelConfig, B: int, cache_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, B, cache_len))


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, tokens, rules):
    e = params["embed"]["w"][tokens]
    if cfg.family in ("dense",) and cfg.name.startswith("gemma"):
        e = e * math.sqrt(cfg.d_model)
    if rules is not None:
        e = rules.constraint(e, (("batch",), ("sp",), (None,)))
    return e


def _head(params, cfg: ModelConfig, x, rules):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"])
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if rules is not None:
        logits = rules.constraint(logits, (("batch",), ("sp",), ("tp",)))
    return logits


def _sinusoidal(S_len: int, d: int):
    pos = jnp.arange(S_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _run_group(p_group, cfg, kind, count, x, *, rules, mode, pos_offset,
               caches, cross_x, cache_len, remat, unroll=False):
    """Apply `count` stacked layers of one kind via lax.scan (or unrolled).

    ``unroll=True`` emits every layer into the HLO — used by the roofline
    dry-run because XLA's cost analysis counts a while-loop body once
    (FLOPs/bytes would otherwise be undercounted by the trip count).
    """
    def one(xc, layer_p, layer_cache):
        return apply_layer(layer_p, cfg, kind, xc, rules=rules, mode=mode,
                           pos_offset=pos_offset, cache=layer_cache,
                           cross_x=cross_x, cache_len=cache_len)

    if unroll and count > 1:
        ncs = []
        fn = jax.checkpoint(one) if (remat and mode == "train") else one
        for i in range(count):
            lp = jax.tree.map(lambda a, i=i: a[i], p_group)
            lc = (jax.tree.map(lambda a, i=i: a[i], caches)
                  if caches is not None else None)
            x, nc = fn(x, lp, lc)
            ncs.append(nc)
        if mode == "train" or not ncs or not ncs[0]:
            return x, None
        return x, jax.tree.map(lambda *a: jnp.stack(a, 0), *ncs)

    if count == 1:
        lp = jax.tree.map(lambda a: a[0], p_group)
        lc = (jax.tree.map(lambda a: a[0], caches) if caches else None)
        fn = jax.checkpoint(one) if (remat and mode == "train") else one
        x, nc = fn(x, lp, lc)
        nc_stacked = (jax.tree.map(lambda a: a[None], nc) if nc else None)
        return x, nc_stacked

    def body(xc, xs):
        layer_p, layer_cache = xs
        fn = jax.checkpoint(one) if (remat and mode == "train") else one
        xc, nc = fn(xc, layer_p, layer_cache)
        return xc, nc

    xs = (p_group, caches) if caches is not None else (p_group, None)
    if caches is None:
        # scan only over params
        def body_np(xc, layer_p):
            fn = jax.checkpoint(one) if (remat and mode == "train") else one
            xc, nc = fn(xc, layer_p, None)
            return xc, nc
        x, ncs = jax.lax.scan(body_np, x, p_group)
    else:
        x, ncs = jax.lax.scan(body, x, xs)
    if mode == "train":
        ncs = None
    return x, ncs


def forward(
    params,
    cfg: ModelConfig,
    batch: dict,
    *,
    rules=None,
    mode: str = "train",
    caches: Optional[dict] = None,
    pos_offset=0,
    cache_len: Optional[int] = None,
    remat: bool = True,
    unroll: bool = False,
):
    """Returns (logits, new_caches)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    frontend = batch.get("frontend")

    cross_x = None
    new_caches: dict = {}

    # ---- encoder (whisper) / multimodal prefix (pixtral) ----
    if cfg.family == "encdec":
        enc_key = next(k for k in params["groups"] if k.startswith("g0_"))
        if mode == "decode" and caches is not None and "enc_out" in caches:
            cross_x = caches["enc_out"]
        else:
            fe = frontend + _sinusoidal(frontend.shape[1], cfg.d_model
                                        ).astype(frontend.dtype)[None]
            cross_x, _ = _run_group(
                params["groups"][enc_key], cfg, "enc_attn", cfg.encoder_layers,
                fe, rules=rules, mode="train", pos_offset=0, caches=None,
                cross_x=None, cache_len=None, remat=remat, unroll=unroll)
        if mode in ("prefill", "decode"):
            new_caches["enc_out"] = cross_x

    x = _embed(params, cfg, tokens, rules)
    if cfg.family == "encdec":
        if mode == "decode":
            pos = jnp.asarray(pos_offset, jnp.int32)
            x = x + jax.lax.dynamic_slice_in_dim(
                params["dec_pos"]["w"], pos, 1, axis=0)[None]
        else:
            x = x + params["dec_pos"]["w"][None, :x.shape[1]]
    if cfg.family == "vlm" and frontend is not None and mode != "decode":
        # patch-embedding prefix (stub frontend) + text embeddings
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)

    # ---- decoder stack ----
    for key in params["groups"]:
        if key.startswith("g0_enc"):
            continue
        kind = key.split("_", 1)[1]
        count = _group_count(params["groups"][key])
        x, nc = _run_group(
            params["groups"][key], cfg, kind, count, x, rules=rules,
            mode=mode, pos_offset=pos_offset,
            caches=(caches.get(key) if caches else None),
            cross_x=cross_x, cache_len=cache_len, remat=remat, unroll=unroll)
        if nc is not None and mode in ("prefill", "decode"):
            new_caches[key] = nc

    norm = L.layer_norm if cfg.family == "encdec" else L.rms_norm
    x = norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm" and frontend is not None and mode != "decode":
        x = x[:, frontend.shape[1]:]  # logits over text positions only
    logits = _head(params, cfg, x, rules)
    return logits, (new_caches if mode in ("prefill", "decode") else None)


def _group_count(p_group) -> int:
    return jax.tree.leaves(p_group)[0].shape[0]


def lm_loss(logits, labels, mask=None):
    """Mean token cross-entropy in fp32."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
