"""Expert-parallel MoE via shard_map + all-to-all (the §Perf hillclimb fix).

The baseline `layers.moe` expresses dispatch as a *global* argsort + scatter.
Under GSPMD that forces token-buffer replication across the expert (model)
axis — the dry-run measured ~20 TB/device/step of collective traffic on
qwen3-moe × train_4k (EXPERIMENTS.md §Perf, hypothesis A1).

This module is the production formulation:

  * activations enter shard_map sharded over the data axes; each *model* rank
    routes an exclusive 1/|model| slice of the local tokens (token-parallel
    routing — routing FLOPs drop |model|-fold too);
  * assignments are binned per destination rank (experts are contiguous per
    rank) with a per-expert, per-source capacity ``cap = ⌈Ts·K/E·cf⌉``;
  * one ragged-free `all_to_all` moves (n_model, e_loc·cap, D) send buffers;
  * local grouped GEMM over the rank's ``e_loc`` experts;
  * the reverse `all_to_all` + local unscatter/combine restores token order;
  * one `all_gather` over the model axis rebuilds the replicated activation.

Wire bytes per layer per device ≈ 2·(n_model·e_loc·cap·D) + T_loc·D
(a2a out/in + gather) — about 0.4 GB for qwen3-moe train_4k vs ~423 GB
measured for the baseline. Exactness: with a non-dropping capacity factor the
outputs match `layers.moe` bit-for-bit up to routing ties (tested).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import NEG_INF, _act


def ep_applicable(cfg, rules, B: int, S: int) -> bool:
    if rules is None or not hasattr(rules, "mesh"):
        return False
    mesh = rules.mesh
    if "model" not in mesh.shape:
        return False
    n_model = mesh.shape["model"]
    dp = math.prod(mesh.shape[a] for a in ("pod", "data") if a in mesh.shape)
    return (cfg.expert_pad_to % n_model == 0
            and S % n_model == 0           # seq is sharded over the model axis
            and B % dp == 0)


def moe_ep(p, cfg, x, act: str, rules, capacity_factor: float | None = None):
    """x: (B, S, D) global → (B, S, D). EP over 'model', DP over data axes."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity
    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_model = mesh.shape["model"]
    E = cfg.expert_pad_to
    E_real = cfg.num_experts
    K = cfg.experts_per_tok
    e_loc = E // n_model

    def local_fn(x_loc, router, wg, wu, wd):
        # §Perf A3: x enters SEQ-SHARDED over the model axis — each rank owns
        # its token slice outright. The previous replicated-x design made the
        # backward of the block a full (T,D) fp32 all-reduce (the transpose of
        # replication); seq-sharding turns that into the transpose of a
        # slice/gather pair, measured 2–3× cheaper on granite train_4k.
        B_loc, S_loc, D = x_loc.shape
        Ts = B_loc * S_loc
        cap = max(int(math.ceil(Ts * K / E * capacity_factor)), 1)
        xs = x_loc.reshape(Ts, D)

        # -- route my token slice --
        logits = jnp.einsum("td,de->te", xs, router,
                            preferred_element_type=jnp.float32)
        if E_real < E:
            logits += jnp.where(jnp.arange(E) < E_real, 0.0, NEG_INF)[None]
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

        # -- bin (token, k) assignments into the per-expert send queues --
        flat_e = top_i.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Ts), K)
        flat_w = top_w.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(se, length=E)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(Ts * K) - starts[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + jnp.clip(rank, 0, cap - 1), E * cap)

        send = jnp.zeros((E * cap + 1, D), xs.dtype).at[slot].set(
            xs[st] * keep[:, None].astype(xs.dtype))[:-1]
        # experts are contiguous per destination rank → rank-major layout
        send = send.reshape(n_model, e_loc * cap, D)

        # -- dispatch / compute / return --
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=True)            # (n_model·eloc·cap, D)?
        recv = recv.reshape(n_model, e_loc, cap, D)
        buf = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_model * cap, D)
        gate = jnp.einsum("egd,edf->egf", buf, wg)
        up = jnp.einsum("egd,edf->egf", buf, wu)
        out = jnp.einsum("egf,efd->egd", _act(act)(gate) * up, wd)
        out = out.reshape(e_loc, n_model, cap, D).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(
            out.reshape(n_model, e_loc * cap, D), "model",
            split_axis=0, concat_axis=0, tiled=True)
        out_flat = back.reshape(E * cap, D)

        # -- undo the local binning, apply combine weights --
        contrib = jnp.where(
            keep[:, None], out_flat[jnp.clip(slot, 0, E * cap - 1)], 0.0)
        y_slice = jnp.zeros((Ts, D), out_flat.dtype).at[st].add(
            contrib * sw[:, None].astype(out_flat.dtype))

        # output stays seq-sharded; GSPMD re-gathers at the block boundary
        return y_slice.reshape(B_loc, S_loc, D).astype(x_loc.dtype)

    smapped = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(dp_axes or None, "model", None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(dp_axes or None, "model", None),
        check_rep=False,
    )
    return smapped(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
