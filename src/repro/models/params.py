"""Parameter-tree helpers: every leaf is created together with its dim spec.

``init_*`` functions build nested dicts whose leaves are :class:`PLeaf`
(array + logical dim spec). :func:`split_tree` separates them into the pure
param tree (what the optimizer sees) and the dim-spec tree (what the sharding
layer sees) — one source of truth, no duplicate bookkeeping.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PLeaf:
    value: Any  # jax.Array or ShapeDtypeStruct
    dims: tuple  # per-dim logical alternatives (see distributed.sharding)


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    if len(shape) >= 2:
        fan_in = math.prod(shape[:-1]) if len(shape) == 2 else shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * s
            ).astype(dtype)


def is_leaf(x) -> bool:
    return isinstance(x, PLeaf)


def split_tree(tree):
    """nested dict of PLeaf → (param tree, dims tree)."""
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=is_leaf)
    dims = jax.tree.map(lambda l: l.dims, tree, is_leaf=is_leaf)
    return params, dims


def map_with_dims(fn: Callable, params, dims):
    """tree_map over (param, dimspec) pairs."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_d = treedef.flatten_up_to(dims)
    return treedef.unflatten([fn(p, d) for p, d in zip(flat_p, flat_d)])


def stack_trees(trees: Sequence):
    """Stack identical trees along a new leading (scan) axis.

    PLeaf leaves keep their dim specs, prefixed with an unsharded layer dim.
    """
    def _stack(*leaves):
        if isinstance(leaves[0], PLeaf):
            vals = jnp.stack([l.value for l in leaves], axis=0)
            return PLeaf(vals, ((None,),) + tuple(leaves[0].dims))
        return jnp.stack(leaves, axis=0)

    return jax.tree.map(_stack, *trees, is_leaf=is_leaf)


def stack_dims(dims_tree):
    """Prefix every dim spec with an unsharded 'layers' dim."""
    return jax.tree.map(
        lambda d: ((None,),) + tuple(d), dims_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def tree_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
