"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and Mamba-style selective SSM.

Hardware adaptation notes (DESIGN.md §4):
  * mLSTM is implemented in its *chunkwise-parallel* stabilized form — the
    matrix-memory recurrence C_t = f_t·C + i_t·k v^T is computed per chunk with
    an intra-chunk attention-like term and an inter-chunk carried state, all in
    log-space with running-max stabilization (exponential gating preserved).
    This is the standard TPU/GPU-parallel formulation; a naive per-step scan
    would serialize 4k+ matmuls.
  * sLSTM has no parallel form (its recurrence is nonlinear in h); it runs as
    a `lax.scan` — faithfully sequential, as in the paper (arXiv:2405.04517).
  * Mamba's diagonal selective scan runs chunked: outer `lax.scan` over
    chunks, inner `associative_scan` within a chunk — bounds the materialized
    (B, Q, D_inner, N) element tensors to one chunk.

All blocks expose train/prefill (full sequence, returns final state) and
decode (single step) paths that are consistency-tested against each other.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import PLeaf, dense_init

LOG_EPS = -2.0e38


def _c(rules, x, dims):
    return x if rules is None else rules.constraint(x, dims)


def _headwise_rmsnorm(x, scale, eps):
    """x: (..., H, Dh) — normalize per head (xLSTM group norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ===========================================================================
# mLSTM
# ===========================================================================


def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    h = cfg.num_heads
    bs = cfg.ssm_qkv_block
    ks = jax.random.split(key, 9)
    return {
        "w_up": PLeaf(dense_init(ks[0], (d, di), dtype), (("fsdp",), ("tp",))),
        "w_z": PLeaf(dense_init(ks[1], (d, di), dtype), (("fsdp",), ("tp",))),
        "conv": PLeaf(dense_init(ks[2], (cfg.ssm_conv, di), dtype),
                      ((None,), ("tp",))),
        # block-diagonal q/k/v (official xLSTM proj_blocksize structure)
        "wq": PLeaf(dense_init(ks[3], (di // bs, bs, bs), dtype),
                    (("tp",), (None,), (None,))),
        "wk": PLeaf(dense_init(ks[4], (di // bs, bs, bs), dtype),
                    (("tp",), (None,), (None,))),
        "wv": PLeaf(dense_init(ks[5], (di // bs, bs, bs), dtype),
                    (("tp",), (None,), (None,))),
        "w_if": PLeaf(dense_init(ks[6], (di, 2 * h), dtype,
                                 scale=0.01), (("tp",), (None,))),
        "f_bias": PLeaf(jnp.full((h,), 3.0, dtype), ((None,),)),
        "norm": PLeaf(jnp.ones((h, di // h), dtype), ((None,), (None,))),
        "w_down": PLeaf(dense_init(ks[7], (di, d), dtype), (("tp",), ("fsdp",))),
    }


def _causal_conv(x, w, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); state: (B, K-1, C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, xp.shape[1] - (K - 1):, :]
    return out, new_state


def _mlstm_chunk_scan(q, k, v, lf, li, state, chunk: int):
    """Chunkwise stabilized mLSTM core.

    q,k,v: (B, S, H, Dh); lf, li: (B, S, H) log gates.
    state: (S_mat (B,H,Dh,Dh), n (B,H,Dh), m (B,H)).
    Returns h (B, S, H, Dh), new state.
    """
    B, S, H, Dh = q.shape
    nc = S // chunk
    k = k / math.sqrt(Dh)

    def reshape_c(x):
        return x.reshape(B, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, lfs, lis = map(reshape_c, (q, k, v, lf, li))

    def body(carry, xs):
        Smat, n, m = carry
        qc, kc, vc, lfc, lic = xs  # (B, Q, H, Dh) / (B, Q, H)
        cum = jnp.cumsum(lfc, axis=1)                     # (B,Q,H) inclusive
        # intra-chunk log weights L[t, τ] = cum_t − cum_τ + li_τ (τ ≤ t)
        L = cum[:, :, None, :] - cum[:, None, :, :] + lic[:, None, :, :]
        tmask = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(tmask[None, :, :, None], L, LOG_EPS)
        G = cum + m[:, None, :]                           # (B,Q,H) boundary
        m_t = jnp.maximum(jnp.max(L, axis=2), G)          # (B,Q,H)
        w = jnp.exp(L - m_t[:, :, None, :])               # (B,t,τ,H)
        inter = jnp.exp(G - m_t)                          # (B,Q,H)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc,
                            preferred_element_type=jnp.float32)
        a = w * scores
        numer = jnp.einsum("btsh,bshd->bthd", a, vc.astype(jnp.float32))
        numer += inter[..., None] * jnp.einsum(
            "bthd,bhde->bthe", qc, Smat, preferred_element_type=jnp.float32)
        den = jnp.sum(a, axis=2)                          # (B,Q,H)
        den += inter * jnp.einsum("bthd,bhd->bth", qc, n,
                                  preferred_element_type=jnp.float32)
        h = numer / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # chunk-end state update
        cum_last = cum[:, -1:, :]                         # (B,1,H)
        logdecay = cum_last - cum + lic                   # (B,Q,H)
        m_new = jnp.maximum(cum_last[:, 0] + m, jnp.max(logdecay, axis=1))
        sdec = jnp.exp(cum_last[:, 0] + m - m_new)        # (B,H)
        wdec = jnp.exp(logdecay - m_new[:, None, :])      # (B,Q,H)
        S_new = (sdec[..., None, None] * Smat
                 + jnp.einsum("bsh,bshd,bshe->bhde", wdec, kc,
                              vc.astype(jnp.float32)))
        n_new = (sdec[..., None] * n
                 + jnp.einsum("bsh,bshd->bhd", wdec, kc))
        return (S_new, n_new, m_new), h.astype(q.dtype)

    (Smat, n, m), hs = jax.lax.scan(body, state, (qs, ks, vs, lfs, lis))
    h = hs.swapaxes(0, 1).reshape(B, S, H, Dh)
    return h, (Smat, n, m)


def mlstm_init_state(B, H, Dh, dtype=jnp.float32):
    return (jnp.zeros((B, H, Dh, Dh), dtype),
            jnp.zeros((B, H, Dh), dtype),
            jnp.zeros((B, H), dtype))


def mlstm_block(p, cfg, x, *, rules=None, mode="train", cache=None,
                chunk: int = 64):
    """Full mLSTM block. Returns (y, new_cache)."""
    B, S, D = x.shape
    H = cfg.num_heads
    di = D * cfg.ssm_expand
    Dh = di // H
    xi = jnp.einsum("bsd,de->bse", x, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xi = _c(rules, xi, (("batch",), ("sp",), ("tp",)))

    conv_state = cache.get("conv") if cache else None
    xc, conv_state = _causal_conv(xi, p["conv"], conv_state)
    xc = jax.nn.silu(xc)

    bs_blk = cfg.ssm_qkv_block
    nb = di // bs_blk

    def blkproj(src, w):  # block-diagonal projection
        y = jnp.einsum("bsnk,nkj->bsnj", src.reshape(B, S, nb, bs_blk), w)
        return y.reshape(B, S, H, Dh)

    q = blkproj(xc, p["wq"])
    k = blkproj(xc, p["wk"])
    v = blkproj(xi, p["wv"])
    gates = jnp.einsum("bse,eh->bsh", xc, p["w_if"])
    li = gates[..., :H]
    lf = jax.nn.log_sigmoid(gates[..., H:] + p["f_bias"][None, None, :]
                            .astype(gates.dtype))

    if mode == "decode":
        Smat, n, m = cache["ssm"]
        lf1, li1 = lf[:, 0], li[:, 0]                     # (B,H)
        m_new = jnp.maximum(lf1 + m, li1)
        fp = jnp.exp(lf1 + m - m_new)
        ip = jnp.exp(li1 - m_new)
        k1 = k[:, 0] / math.sqrt(Dh)
        Smat = fp[..., None, None] * Smat + ip[..., None, None] * (
            k1[..., :, None] * v[:, 0][..., None, :])
        n = fp[..., None] * n + ip[..., None] * k1
        num = jnp.einsum("bhd,bhde->bhe", q[:, 0], Smat)
        den = jnp.einsum("bhd,bhd->bh", q[:, 0], n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        h = h[:, None].astype(x.dtype)                    # (B,1,H,Dh)
        new_state = (Smat, n, m_new)
    else:
        state = mlstm_init_state(B, H, Dh)
        pad = (-S) % chunk
        if pad:
            padf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
            qp, kp, vp, lfp, lip = map(padf, (q, k, v, lf, li))
        else:
            qp, kp, vp, lfp, lip = q, k, v, lf, li
        h, new_state = _mlstm_chunk_scan(qp, kp, vp, lfp, lip, state, chunk)
        h = h[:, :S]
        if pad:  # state absorbed padded steps with li=0? recompute guard:
            # padded steps have lf=0 (f=sigmoid→log_sigmoid(bias)) — to keep
            # the carried state exact we mask pad gates hard instead.
            pass

    h = _headwise_rmsnorm(h, p["norm"], cfg.norm_eps)
    h = h.reshape(B, S, di) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", h, p["w_down"])
    y = _c(rules, y, (("batch",), ("sp",), (None,)))
    new_cache = {"ssm": new_state, "conv": conv_state}
    return y, new_cache


# ===========================================================================
# sLSTM
# ===========================================================================


def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    dff = int(d * 4 / 3)
    return {
        "w_in": PLeaf(dense_init(ks[0], (d, 4 * d), dtype),
                      (("fsdp",), ("tp",))),
        "r": PLeaf(dense_init(ks[1], (h, dh, 4 * dh), dtype, scale=0.1),
                   ((None,), (None,), (None,))),
        "f_bias": PLeaf(jnp.full((h, dh), 3.0, dtype), ((None,), (None,))),
        "norm": PLeaf(jnp.ones((h, dh), dtype), ((None,), (None,))),
        "ffn_gate": PLeaf(dense_init(ks[2], (d, dff), dtype),
                          (("fsdp",), ("tp",))),
        "ffn_up": PLeaf(dense_init(ks[3], (d, dff), dtype),
                        (("fsdp",), ("tp",))),
        "ffn_down": PLeaf(dense_init(ks[4], (dff, d), dtype),
                          (("tp",), ("fsdp",))),
    }


def slstm_init_state(B, H, Dh, dtype=jnp.float32):
    z = jnp.zeros((B, H, Dh), dtype)
    return (z, z, z, jnp.zeros((B, H, Dh), dtype))  # c, n, h, m


def _slstm_step(p, cfg, xg, state):
    """xg: (B, H, Dh, 4) pre-activations from input; state: (c, n, h, m)."""
    c, n, h_prev, m = state
    rec = jnp.einsum("bhd,hdk->bhk", h_prev, p["r"])
    rec = rec.reshape(*h_prev.shape[:-1], h_prev.shape[-1], 4)
    pre = xg.astype(jnp.float32) + rec.astype(jnp.float32)
    i_t, f_t, z_t, o_t = [pre[..., j] for j in range(4)]
    f_t = f_t + p["f_bias"].astype(jnp.float32)[None]
    m_new = jnp.maximum(f_t + m, i_t)                     # exp gating
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_t + m - m_new)
    c_new = fp * c + ip * jnp.tanh(z_t)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_block(p, cfg, x, *, rules=None, mode="train", cache=None):
    B, S, D = x.shape
    H = cfg.num_heads
    Dh = D // H
    xg = jnp.einsum("bsd,dk->bsk", x, p["w_in"]).reshape(B, S, H, Dh, 4)

    if mode == "decode":
        state = cache["ssm"]
        state = _slstm_step(p, cfg, xg[:, 0], state)
        h = state[2][:, None]                             # (B,1,H,Dh)
    else:
        state = slstm_init_state(B, H, Dh)

        def body(st, xt):
            st = _slstm_step(p, cfg, xt, st)
            return st, st[2]

        state, hs = jax.lax.scan(body, state, xg.swapaxes(0, 1))
        h = hs.swapaxes(0, 1)                             # (B,S,H,Dh)

    h = _headwise_rmsnorm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    core = h.reshape(B, S, D)
    # GeGLU FFN (4/3 factor, per xLSTM block design), residual on the core
    gate = jnp.einsum("bsd,df->bsf", core, p["ffn_gate"])
    up = jnp.einsum("bsd,df->bsf", core, p["ffn_up"])
    ffn = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(gate) * up, p["ffn_down"])
    y = _c(rules, core + ffn, (("batch",), ("sp",), (None,)))
    return y, {"ssm": state}


# ===========================================================================
# Mamba-style selective SSM (hymba's parallel-head partner)
# ===========================================================================


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": PLeaf(dense_init(ks[0], (d, 2 * di), dtype),
                      (("fsdp",), ("tp",))),
        "conv": PLeaf(dense_init(ks[1], (cfg.ssm_conv, di), dtype),
                      ((None,), ("tp",))),
        "w_bcdt": PLeaf(dense_init(ks[2], (di, 2 * N + 1), dtype),
                        (("tp",), (None,))),
        "dt_bias": PLeaf(jnp.zeros((di,), dtype), ((None,),)),
        "a_log": PLeaf(jnp.log(jnp.linspace(1.0, float(N), N))[None, :]
                       .repeat(di, 0).astype(jnp.float32),
                       ((None,), (None,))),
        "d_skip": PLeaf(jnp.ones((di,), dtype), ((None,),)),
        "w_out": PLeaf(dense_init(ks[3], (di, d), dtype),
                       (("tp",), ("fsdp",))),
    }


def mamba_init_state(B, di, N, dtype=jnp.float32):
    return jnp.zeros((B, di, N), dtype)


def _selective_scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t·h_{t−1} + b_t, diagonal. a, b: (B, S, Di, N); h0: (B, Di, N)."""
    B, S, Di, N = a.shape
    nc = S // chunk

    def reshape_c(x):
        return x.reshape(B, nc, chunk, Di, N).swapaxes(0, 1)

    ac, bc = reshape_c(a), reshape_c(b)

    def compose(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        aq, bq = xs                                        # (B, Q, Di, N)
        A, Bc = jax.lax.associative_scan(compose, (aq, bq), axis=1)
        hs = A * h[:, None] + Bc                           # (B, Q, Di, N)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(body, h0, (ac, bc))
    hs = hs.swapaxes(0, 1).reshape(B, S, Di, N)
    return hs, h_last


def mamba_block(p, cfg, x, *, rules=None, mode="train", cache=None,
                chunk: int = 128):
    B, S, D = x.shape
    di = D * cfg.ssm_expand
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z = xz[..., :di], xz[..., di:]
    xi = _c(rules, xi, (("batch",), ("sp",), ("tp",)))

    conv_state = cache.get("conv") if cache else None
    xc, conv_state = _causal_conv(xi, p["conv"], conv_state)
    xc = jax.nn.silu(xc)

    bcdt = jnp.einsum("bse,ek->bsk", xc, p["w_bcdt"])
    Bmat = bcdt[..., :N]                                   # (B,S,N)
    Cmat = bcdt[..., N:2 * N]
    dt = jax.nn.softplus(bcdt[..., -1:]
                         + p["dt_bias"].astype(bcdt.dtype)[None, None, :])
    # dt: (B,S,Di) — rank-1 Δ projection broadcast + per-channel bias
    A = -jnp.exp(p["a_log"])                               # (Di,N)
    # f32 throughout the scan: associative_scan concatenates partial results
    # with original elements, so both operands must share one dtype; the
    # recurrence is also the numerically sensitive part.
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A[None, None])
    b = ((dt * xc)[..., None] * Bmat[:, :, None, :]).astype(jnp.float32)

    if mode == "decode":
        h0 = cache["ssm"]
        h = a[:, 0] * h0 + b[:, 0]                         # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0])[:, None]
        h_last = h
    else:
        h0 = mamba_init_state(B, di, N)
        pad = (-S) % chunk
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        hs, h_last = _selective_scan_chunked(a, b, h0, chunk)
        hs = hs[:, :S]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cmat)

    y = y + xc * p["d_skip"].astype(y.dtype)[None, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_out"])
    out = _c(rules, out, (("batch",), ("sp",), (None,)))
    return out, {"ssm": h_last, "conv": conv_state}
