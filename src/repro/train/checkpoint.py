"""Fault-tolerant checkpointing: atomic, async, mesh-elastic.

Protocol (crash-safe by construction):
  1. all state leaves are gathered to host and written to
     ``<dir>/step_<N>.tmp/`` as one ``.npz`` per top-level key;
  2. a ``manifest.json`` (step, leaf paths, config hash, wall time) is written
     *inside* the tmp dir and fsync'd;
  3. the tmp dir is atomically renamed to ``step_<N>/``.

A restart only ever sees fully-renamed directories — a crash mid-write leaves
a ``.tmp`` dir that ``latest_step`` ignores and ``clean`` removes.

Elastic resharding: leaves are saved as *full* (unsharded) arrays, so restore
can place them onto any mesh/sharding — scale up, down, or reshape between
runs. (At >10B params production systems shard the save too; the manifest
format reserves a ``shards`` field for that extension.)

Async mode runs step 1–3 on a worker thread so the train loop never blocks
on I/O (overlap of checkpoint writes with compute).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import queue
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(p) for p in path)
        out.append((name, leaf))
    return out


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def config_hash(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, async_write: bool = True,
                 keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_write = async_write
        self._q: queue.Queue = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._errors: list[str] = []
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- write ---------------------------------------------------------------

    def save(self, step: int, state, extra: Optional[dict] = None,
             cfg_hash: str = ""):
        """Snapshot state (device→host copy happens here, synchronously, so
        the caller may donate/overwrite buffers immediately after)."""
        host = [(n, np.asarray(jax.device_get(x)))
                for n, x in _flatten_with_names(state)]
        job = (step, host, extra or {}, cfg_hash)
        if self.async_write:
            self._q.put(job)
        else:
            self._write(job)

    def wait(self):
        if self.async_write:
            self._q.join()
        if self._errors:
            raise IOError("; ".join(self._errors))

    def _drain(self):
        while True:
            job = self._q.get()
            try:
                self._write(job)
            except Exception as e:  # surfaced on wait()
                self._errors.append(f"step {job[0]}: {e}")
            finally:
                self._q.task_done()

    def _write(self, job):
        step, host, extra, cfg_hash = job
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # npz can't round-trip ml_dtypes (bf16 etc.) — store raw views plus a
        # dtype table in the manifest
        arrays, dtypes = {}, {}
        for n, a in host:
            dtypes[n] = str(a.dtype)
            if a.dtype.name == "bfloat16":
                a = a.view(np.uint16)
            arrays[n] = a
        np.savez(tmp / "state.npz", **arrays)
        manifest = {
            "step": step,
            "leaves": [n for n, _ in host],
            "dtypes": dtypes,
            "config_hash": cfg_hash,
            "time": time.time(),
            "extra": extra,
            "shards": None,  # reserved: per-host sharded saves
            "complete": True,
        }
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def clean_incomplete(self):
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and (p / "manifest.json").exists():
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_state, step: Optional[int] = None,
                shardings=None, cfg_hash: Optional[str] = None):
        """Restore into the structure of ``target_state``.

        ``shardings``: optional matching tree of NamedSharding — leaves are
        device_put directly to their (possibly different-mesh) placement:
        this is the elastic-rescale path.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        if cfg_hash and manifest["config_hash"] and \
                manifest["config_hash"] != cfg_hash:
            raise ValueError(
                f"checkpoint config hash {manifest['config_hash']} != "
                f"current {cfg_hash}")
        data = np.load(d / "state.npz")
        names = [n for n, _ in _flatten_with_names(target_state)]
        missing = [n for n in names if n not in data.files]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
        flat, treedef = jax.tree_util.tree_flatten(target_state)
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
        else:
            flat_sh = [None] * len(flat)
        import ml_dtypes

        saved_dtypes = manifest.get("dtypes", {})
        out = []
        for (name, ref), sh in zip(_flatten_with_names(target_state), flat_sh):
            arr = data[name]
            if saved_dtypes.get(name) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
            arr = arr.astype(dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return treedef.unflatten(out), manifest
