"""Straggler detection and step-time accounting.

At pod scale a single slow host gates every synchronous collective. The
monitor keeps an EWMA + variance of step wall times, flags steps beyond
``mean + k·σ``, and exposes the hook the launcher uses to decide hot-spare
substitution (at real scale: re-slicing the job onto a spare pod; here the
policy and bookkeeping are implemented and tested with an injected slowdown).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    mean_s: float
    threshold_s: float


class StepMonitor:
    def __init__(self, k_sigma: float = 3.0, min_samples: int = 8,
                 alpha: float = 0.1,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.k = k_sigma
        self.min_samples = min_samples
        self.alpha = alpha
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.events: list[StragglerEvent] = []
        self.on_straggler = on_straggler
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> Optional[StragglerEvent]:
        dt = time.perf_counter() - self._t0
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> Optional[StragglerEvent]:
        ev = None
        if self.n >= self.min_samples:
            thresh = self.mean + self.k * math.sqrt(max(self.var, 1e-12))
            if dt > thresh:
                ev = StragglerEvent(step, dt, self.mean, thresh)
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
        # EWMA update (straggler steps update slowly so one hiccup doesn't
        # poison the baseline)
        a = self.alpha if ev is None else self.alpha * 0.1
        delta = dt - self.mean
        self.mean += a * delta
        self.var = (1 - a) * (self.var + a * delta * delta)
        self.n += 1
        return ev

    @property
    def straggler_fraction(self) -> float:
        return len(self.events) / max(self.n, 1)
