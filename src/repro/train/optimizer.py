"""AdamW with fp32 master weights + cosine schedule (no external deps).

True mixed precision: model params live in cfg.dtype (bf16), the optimizer
keeps fp32 master params and moments — all sharded with the same dim specs as
the parameters (ZeRO-3: optimizer state is FSDP-sharded over the data axes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: OptimizerConfig, step):
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> dict:
    # copy=True: for fp32 params, astype would alias the param buffer and
    # break donation (same buffer donated twice in train_step)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: OptimizerConfig, grads, opt_state, param_dtype):
    """One AdamW step. Returns (new bf16 params, new opt state, metrics)."""
    step = opt_state["step"]
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        return master - lr * delta, m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, ma, m, v) for g, ma, m, v in
           zip(flat_g, flat_ma, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v,
                 "step": step + 1}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
