"""Jittable train / prefill / serve step builders with full sharding specs.

These are the functions the dry-run lowers and the trainer executes. Sharding
comes from the dim specs attached at parameter creation plus the cache/batch
dim tables below — one rule system end to end.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import MeshRules
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.params import map_with_dims
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state

KV_DIMS = (("batch",), (None,), ("tp",), (None, "tp"))
KV_DIMS_SEQSHARD = (("batch",), ("tp",), (None,), (None,))
_CACHE_DIMS_BY_RANK_HINT = {}


def batch_dims(cfg: ModelConfig, batch_tree):
    """Dim specs for an input batch tree (tokens/labels/frontend/pos)."""
    def dims_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("tokens", "labels"):
            return (("batch",), ("sp",))
        if name == "frontend":
            return (("batch",), (None,), (None,))
        if name == "pos":
            return ()
        raise KeyError(name)

    return jax.tree_util.tree_map_with_path(dims_for, batch_tree)


def cache_dims_tree(cfg: ModelConfig, cache_tree, rules=None):
    """Dim specs for a decode-cache tree, keyed on group kind + leaf rank.

    When kv heads don't divide the model axis, KV caches are *sequence*
    sharded over it and decode uses the flash-decoding shard_map path
    (layers._decode_attn_seqshard) — see EXPERIMENTS.md §Perf B.
    """
    seqshard = False
    if rules is not None and hasattr(rules, "mesh") and \
            "model" in rules.mesh.shape and rules.mesh.shape["model"] > 1:
        seqshard = cfg.num_kv_heads % rules.mesh.shape["model"] != 0
    kv_dims = KV_DIMS_SEQSHARD if seqshard else KV_DIMS

    def dims_for(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        if keys and keys[0] == "enc_out":
            return (("batch",), (None,), (None,))
        kind = keys[0].split("_", 1)[1] if keys else ""
        leafname = keys[-1] if keys else ""
        r = leaf.ndim
        if leafname in ("k", "v"):
            return ((None,),) + kv_dims        # stacked layer dim first
        if leafname == "conv":
            return ((None,), ("batch",), (None,), ("tp",))
        # ssm states (tuple leaves have no key for the tuple index)
        if kind == "mlstm" or kind == "slstm":
            # ranks: 5=(L,B,H,Dh,Dh), 4=(L,B,H,Dh), 3=(L,B,H)
            if r == 5:
                return ((None,), ("batch",), (None,), (None,), ("tp",))
            if r == 4:
                return ((None,), ("batch",), (None,), (None, "tp"))
            return ((None,), ("batch",), (None,))
        if kind in ("hybrid_full", "hybrid_sw"):
            if leafname == "ssm" or r == 4:
                return ((None,), ("batch",), ("tp",), (None,))
        return ((None,),) * r

    return jax.tree_util.tree_map_with_path(dims_for, cache_tree)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig, rules,
                    unroll: bool = False, microbatches: int = 1,
                    accum_dtype: str = "float32"):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatches > 1`` scans gradient accumulation over batch slices —
    activation memory drops by the microbatch factor (the knob for cells that
    exceed per-device HBM). ``accum_dtype="bfloat16"`` halves the accumulator
    memory (gradient compression at the accumulation level; the wire-level
    int8 path lives in distributed.compression).
    """
    param_dtype = jnp.dtype(cfg.dtype)
    adt = jnp.dtype(accum_dtype)

    def loss_fn(params, mb):
        logits, _ = M.forward(params, cfg, mb, rules=rules,
                              mode="train", remat=True, unroll=unroll)
        return M.lm_loss(logits, mb["labels"])

    def train_step(state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, adt),
                                state["params"])

            def body(carry, mb):
                acc, loss_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(state["params"], mb)
                acc = jax.tree.map(lambda a, gi: a + gi.astype(adt), acc, g)
                return (acc, loss_acc + loss), None

            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, state["opt"], param_dtype)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key):
    params_l, dims = M.init_model(cfg, key)
    state = {"params": params_l, "opt": init_opt_state(params_l)}
    return state, dims


def state_dims(dims):
    """Dim-spec tree matching the train state structure."""
    return {
        "params": dims,
        "opt": {"master": dims, "m": dims, "v": dims, "step": ()},
    }


def state_shardings(rules: MeshRules, state_tree, sdims):
    def leaf(x, d):
        shape = x.shape if hasattr(x, "shape") else ()
        return NamedSharding(rules.mesh, rules.spec(shape, d)) if shape or d == () \
            else NamedSharding(rules.mesh, P())

    flat_x, treedef = jax.tree.flatten(state_tree)
    flat_d = treedef.flatten_up_to(sdims)
    return treedef.unflatten([leaf(x, d) for x, d in zip(flat_x, flat_d)])


def tree_shardings(rules: MeshRules, tree, dims_tree):
    flat_x, treedef = jax.tree.flatten(tree)
    flat_d = treedef.flatten_up_to(dims_tree)
    return treedef.unflatten([
        NamedSharding(rules.mesh, rules.spec(x.shape, d))
        for x, d in zip(flat_x, flat_d)
    ])


# ---------------------------------------------------------------------------
# serve (prefill + decode)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, rules, cache_len: int, unroll: bool = False):
    def prefill_step(params, batch):
        logits, caches = M.forward(params, cfg, batch, rules=rules,
                                   mode="prefill", cache_len=cache_len,
                                   remat=False, unroll=unroll)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules, unroll: bool = False):
    """One greedy decode step against the KV/state caches."""
    def serve_step(params, batch):
        logits, caches = M.forward(
            params, cfg, {"tokens": batch["tokens"]}, rules=rules,
            mode="decode", caches=batch["caches"], pos_offset=batch["pos"],
            remat=False, unroll=unroll)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, caches  # next_tok: (B, 1), feedable to the next step

    return serve_step
