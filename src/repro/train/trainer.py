"""Training loop: checkpoint/resume, straggler monitor, SIGTERM safety.

Deliberately host-driven and restart-oriented: every ``ckpt_every`` steps the
full state is snapshotted (async); on start, ``resume="auto"`` picks up the
latest complete checkpoint — possibly onto a *different mesh* (elastic
scaling), since checkpoints store unsharded leaves and restore re-places them
with the current sharding rules.
"""
from __future__ import annotations

import dataclasses
import signal
from typing import Callable, Iterable, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.train import step as St
from repro.train.checkpoint import CheckpointManager, config_hash
from repro.train.monitor import StepMonitor
from repro.train.optimizer import OptimizerConfig


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    resume: str = "never"  # never | auto
    log_every: int = 10
    microbatches: int = 1
    seed: int = 0
    unroll: bool = False


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    opt_cfg: OptimizerConfig,
    batches: Iterable[dict],
    mesh=None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
):
    """Run the loop; returns (final state, history list)."""
    rules = None
    if mesh is not None:
        from repro.distributed.sharding import MeshRules
        rules = MeshRules.for_mesh(mesh)

    key = jax.random.PRNGKey(tcfg.seed)
    state, dims = St.init_train_state(cfg, key)
    chash = config_hash((cfg, opt_cfg))

    ckpt = None
    start_step = 0
    if tcfg.ckpt_dir:
        ckpt = CheckpointManager(tcfg.ckpt_dir)
        ckpt.clean_incomplete()
        if tcfg.resume == "auto" and ckpt.latest_step() is not None:
            shardings = None
            if rules is not None:
                shardings = St.tree_shardings(
                    rules, state, St.state_dims(dims))
            state, manifest = ckpt.restore(state, shardings=shardings,
                                           cfg_hash=chash)
            start_step = manifest["step"]

    step_fn = St.make_train_step(cfg, opt_cfg, rules, unroll=tcfg.unroll,
                                 microbatches=tcfg.microbatches)
    if mesh is not None:
        sh = St.tree_shardings(rules, state, St.state_dims(dims))
        step_fn = jax.jit(step_fn, in_shardings=(sh, None),
                          out_shardings=(sh, None), donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    stop = {"now": False}

    def _sigterm(sig, frame):  # checkpoint-then-exit on preemption
        stop["now"] = True

    old = signal.signal(signal.SIGTERM, _sigterm)

    monitor = StepMonitor()
    history = []
    it = iter(batches)
    step = start_step
    try:
        while step < tcfg.steps:
            try:
                batch = next(it)
            except StopIteration:
                break
            monitor.start()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            monitor.stop(step)
            step += 1
            if step % tcfg.log_every == 0 or step == tcfg.steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time_s"] = monitor.mean
                history.append({"step": step, **m})
                if on_metrics:
                    on_metrics(step, m)
            if ckpt and (step % tcfg.ckpt_every == 0 or stop["now"]
                         or step == tcfg.steps):
                ckpt.save(step, state, cfg_hash=chash,
                          extra={"stragglers": len(monitor.events)})
            if stop["now"]:
                break
    finally:
        signal.signal(signal.SIGTERM, old)
        if ckpt:
            ckpt.wait()
    return state, history
