"""Test fixtures. NOTE: no XLA_FLAGS here — tests run with 1 CPU device;
multi-device tests spawn subprocesses that set the flag themselves."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}")
    return proc.stdout
