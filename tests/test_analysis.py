"""Negative fixtures for the static-analysis pass itself.

Each checker must actually *detect* the defect class it exists for: a
deliberately-bad toy artifact per claim (extra dispatch structure, second
psum, dropped donation, fp32 payload leak, tracer-branch lint) asserted to
be flagged — plus the green half: a quick run over every registered
contract, and the named regression fixtures for the violations the auditor
surfaced in the real tree when it first ran (``precision.sq-norms-upcast``).
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_audit as ja
from repro.analysis.lint import lint_source


def _artifact(fn, *args, **kwargs):
    return ja.trace_artifact(fn, args, kwargs)


# ---------------------------------------------------------------------------
# scan structure: the one-dispatch claim
# ---------------------------------------------------------------------------


def test_extra_driving_scan_detected():
    """A second sequential k-scan (a re-dispatched greedy loop) is caught."""

    @jax.jit
    def good(x):
        return jax.lax.scan(lambda c, _: (c + 1.0, c), x, None, length=5)

    @jax.jit
    def bad(x):
        c, ys = jax.lax.scan(lambda c, _: (c + 1.0, c), x, None, length=5)
        c, _ = jax.lax.scan(lambda c, _: (c * 2.0, c), c, None, length=5)
        return c, ys

    x = jax.ShapeDtypeStruct((), np.float32)
    ok = ja.scan_structure(_artifact(good, x).jaxpr, rounds=5)
    assert ok.top_scans == 1 and ok.driving == 1
    leak = ja.scan_structure(_artifact(bad, x).jaxpr, rounds=5)
    assert leak.top_scans == 2 and leak.driving == 2


def test_unrolled_loop_has_no_driving_scan():
    """A Python-unrolled loop (k dispatgarbage baked into the artifact)
    shows zero driving scans — the structure check fails it."""

    @jax.jit
    def unrolled(x):
        for _ in range(5):
            x = x + 1.0
        return x

    ss = ja.scan_structure(
        _artifact(unrolled, jax.ShapeDtypeStruct((), np.float32)).jaxpr,
        rounds=5)
    assert ss.top_scans == 0 and ss.driving == 0


def test_scan_inside_loop_is_not_top_level():
    """A scan nested in another loop body runs per iteration — it must not
    count as a top-level (once-per-dispatch) scan."""

    @jax.jit
    def nested(x):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda a, _: (a + 1.0, a), c, None, length=3)
            return c2, c2
        return jax.lax.scan(outer, x, None, length=7)

    jaxpr = _artifact(nested, jax.ShapeDtypeStruct((), np.float32)).jaxpr
    tops = ja.top_level_scans(jaxpr)
    assert [ja.scan_length(e) for e in tops] == [7]


# ---------------------------------------------------------------------------
# collectives: the one-psum claim
# ---------------------------------------------------------------------------


def _shmap(fn):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P(None), check_rep=False))


def test_second_psum_detected():
    def one(x):
        return jax.lax.psum(jnp.sum(x), "data")

    def two(x):
        s = jax.lax.psum(jnp.sum(x), "data")
        return s + jax.lax.psum(jnp.max(x), "data")

    x = jax.ShapeDtypeStruct((8,), np.float32)
    assert ja.collective_census(_artifact(_shmap(one), x).jaxpr).total == 1
    census = ja.collective_census(_artifact(_shmap(two), x).jaxpr)
    assert census.counts["psum"] == 2


def test_oversized_collective_operand_detected():
    """An O(n·d)-sized psum payload busts the byte bound the contracts pin."""

    def big(x):
        return jax.lax.psum(x[None, :] * jnp.ones((64, 1)), "data")

    x = jax.ShapeDtypeStruct((8,), np.float32)
    census = ja.collective_census(_artifact(_shmap(big), x).jaxpr)
    # the psum operand is the LOCAL (64, n/p) block — 8/p rows per shard
    assert census.max_operand_bytes >= 64 * (8 // jax.device_count()) * 4


def test_per_tenant_psum_migration_detected():
    """The batched-sharded O(B·m) budget: stacking B tenants' partials into
    ONE (B, m+1) psum keeps the collective count at 1. The regression this
    fixture pins is the per-tenant migration — a Python loop (or unrolled
    vmap) issuing B separate psums — which the census must report as B
    collectives, busting the batched contracts' count budget."""
    B, m = 4, 6

    def stacked(parts):                     # parts: (B, n_loc) per shard
        g = parts[:, :m]
        stat = jnp.sum(parts, axis=1)
        return jax.lax.psum(
            jnp.concatenate([g, stat[:, None]], axis=1), "data")

    def per_tenant(parts):
        out = []
        for b in range(B):                  # the migration under test
            g = parts[b, :m]
            stat = jnp.sum(parts[b])
            out.append(jax.lax.psum(
                jnp.concatenate([g, stat[None]]), "data"))
        return jnp.stack(out)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    def _sh(fn):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=(P(None, "data"),),
                                 out_specs=P(None, None), check_rep=False))

    x = jax.ShapeDtypeStruct((B, 8 * jax.device_count()), np.float32)
    good = ja.collective_census(_artifact(_sh(stacked), x).jaxpr)
    assert good.counts["psum"] == 1
    assert good.max_operand_bytes == B * (m + 1) * 4   # O(B·m), one payload
    bad = ja.collective_census(_artifact(_sh(per_tenant), x).jaxpr)
    assert bad.counts["psum"] == B


def test_psum_inside_scan_body_censused_per_region():
    """The per-round budget censuses the driving scan's BODY, catching a
    collective that moved from per-dispatch to per-round."""

    def per_round(x):
        def step(c, _):
            return c + jax.lax.psum(jnp.sum(x), "data"), c
        return jax.lax.scan(step, 0.0, None, length=5)

    jaxpr = _artifact(_shmap(per_round),
                      jax.ShapeDtypeStruct((8,), np.float32)).jaxpr
    ss = ja.scan_structure(jaxpr, rounds=5)
    assert ss.driving == 1
    assert ja.collective_census(ss.driving_body).total == 1


# ---------------------------------------------------------------------------
# donation: aliased vs silently dropped
# ---------------------------------------------------------------------------


def test_donation_aliased_and_dropped_detected():
    @partial(jax.jit, donate_argnums=(0,))
    def aliased(seed, x):
        return seed * 2.0 + x          # same shape/dtype: aliases onto out

    @partial(jax.jit, donate_argnums=(0,))
    def dropped(seed, x):
        # output dtype differs from the donated buffer: XLA cannot alias it
        # and silently drops the donation (warns only at run time)
        return (seed + x).astype(jnp.bfloat16)

    s = jax.ShapeDtypeStruct((16,), np.float32)
    good_art = _artifact(aliased, s, s)
    good = ja.donation_audit(good_art.hlo)
    assert good.aliased == 1 and good.dropped == 0
    assert good_art.dropped_donations == 0
    bad_art = _artifact(dropped, s, s)
    bad = ja.donation_audit(bad_art.hlo)
    assert bad.aliased == 0
    # CPU strips the unusable donation at lowering with only a warning (no
    # jax.buffer_donor marker); the artifact capture turns it into a count
    assert bad.dropped + bad_art.dropped_donations == 1
    assert not bad.ok(expected_aliased=1)


def test_deferred_donation_resolved_against_compiled_alias_table():
    """Multi-device lowering leaves only ``jax.buffer_donor`` markers and
    lets XLA pick the aliasing after SPMD partitioning; the resolver must
    credit exactly the donors the compiled ``input_output_alias`` table
    covers and keep the rest dropped."""
    header = ("HloModule jit_run, is_scheduled=true, "
              "input_output_alias={ {0}: (0, {}, may-alias), "
              "{1}: (1, {}, may-alias) }, entry_computation_layout="
              "{(f32[14,24]{1,0}, s32[14]{0})->(f32[14,24]{1,0})}, "
              "num_partitions=2\n\n%body {\n}\n")

    class _Compiled:
        def as_text(self):
            return header

    class _Lowered:
        def compile(self):
            return _Compiled()

    both = ja.resolve_deferred_donations(
        ja.DonationTable(aliased=0, dropped=2), _Lowered())
    assert both.aliased == 2 and both.dropped == 0
    # a donor the compiled table does not cover stays dropped
    partial_ = ja.resolve_deferred_donations(
        ja.DonationTable(aliased=0, dropped=3), _Lowered())
    assert partial_.aliased == 2 and partial_.dropped == 1
    # statically-aliased params already own their table entries: no
    # double-credit for deferred donors
    mixed = ja.resolve_deferred_donations(
        ja.DonationTable(aliased=2, dropped=1), _Lowered())
    assert mixed.aliased == 2 and mixed.dropped == 1
    # nothing deferred → no compile, table unchanged
    clean = ja.resolve_deferred_donations(
        ja.DonationTable(aliased=1, dropped=0), lowered=None)
    assert clean.aliased == 1 and clean.dropped == 0


def test_engine_seed_donation_live():
    """satellite fixture: ``seed.is_deleted()`` matches the aliasing table
    (the donated buffer is consumed; the function's resident seed is not)."""
    from repro.analysis.registry import _rt_donation_live

    ok, detail = _rt_donation_live()
    assert ok, detail


# ---------------------------------------------------------------------------
# precision flow
# ---------------------------------------------------------------------------


def test_fp32_leak_detected():
    @jax.jit
    def leak(v):
        vf = v.astype(jnp.float32)         # payload-sized widen: the bug
        return jnp.sum(vf * vf, axis=-1)

    rep = ja.precision_flow(
        _artifact(leak, jax.ShapeDtypeStruct((48, 8), jnp.bfloat16)).jaxpr,
        min_widen_elems=112)
    assert rep.widens and rep.widens[0][1] == 384
    assert not rep.ok(require_half_dot=True)


def test_small_accumulator_widen_allowed():
    @jax.jit
    def accum(v, g):
        d = jax.lax.dot_general(v, v, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return jnp.sum(d) + g.astype(jnp.float32).sum()   # (8,) scalar-ish

    rep = ja.precision_flow(
        _artifact(accum, jax.ShapeDtypeStruct((48, 8), jnp.bfloat16),
                  jax.ShapeDtypeStruct((8,), jnp.bfloat16)).jaxpr,
        min_widen_elems=112)
    assert rep.ok(require_half_dot=True)
    assert rep.half_dots == 1


def test_sq_norms_upcast_fixture():
    """precision.sq-norms-upcast: the violation the auditor surfaced in the
    real tree — ``sq_norms`` materialized an fp32 copy of the bf16 payload.
    The old pattern stays detectable; the fixed pairwise stays clean."""
    from repro.core.distances import sqeuclidean_pairwise
    from repro.core.precision import resolve

    @jax.jit
    def old_pattern(X):                    # pre-fix sq_norms body
        Xa = X.astype(jnp.float32)
        return jnp.sum(Xa * Xa, axis=-1)

    bf16 = jax.ShapeDtypeStruct((48, 8), jnp.bfloat16)
    assert ja.precision_flow(_artifact(old_pattern, bf16).jaxpr,
                             min_widen_elems=112).widens

    @jax.jit
    def pairwise(X, Y):
        return sqeuclidean_pairwise(X, Y, resolve("bf16"))

    f32 = jax.ShapeDtypeStruct((48, 8), np.float32)
    rep = ja.precision_flow(_artifact(pairwise, f32, f32).jaxpr,
                            min_widen_elems=112)
    assert not rep.widens and rep.half_dots >= 1


# ---------------------------------------------------------------------------
# lint negative fixtures
# ---------------------------------------------------------------------------


def test_tracer_branch_detected():
    src = """
import jax

def step(carry, x):
    if x > 0:
        carry = carry + x
    return carry, x

def run(xs):
    return jax.lax.scan(step, 0.0, xs)
"""
    rules = {f.rule for f in lint_source(src)}
    assert "tracer-branch" in rules


def test_tracer_cast_detected():
    src = """
import jax

def step(carry, x):
    return carry + float(x), x

def run(xs):
    return jax.lax.scan(step, 0.0, xs)
"""
    assert any(f.rule == "tracer-cast" for f in lint_source(src))


def test_float_equality_detected_and_suppressable():
    src = "def f(x):\n    return x == 1.5\n"
    assert any(f.rule == "float-eq" for f in lint_source(src))
    ok = "def f(x):\n    return x == 1.5  # lint: allow(float-eq)\n"
    assert not lint_source(ok)


def test_np_on_traced_arg_detected():
    src = """
import jax
import numpy as np

@jax.jit
def f(x):
    return np.sum(x)
"""
    assert any(f.rule == "np-in-jit" for f in lint_source(src))


def test_missing_static_default_detected():
    src = """
import jax
from functools import partial

@partial(jax.jit, static_argnames=("mode",))
def f(x, mode="fast", normalize=True):
    return x
"""
    findings = [f for f in lint_source(src) if f.rule == "missing-static"]
    assert len(findings) == 1 and "normalize" in findings[0].message


def test_clean_scan_body_not_flagged():
    src = """
import jax
import jax.numpy as jnp

def step(carry, x):
    branch = jnp.where(x > 0, carry + x, carry)
    return branch, x

def run(xs):
    return jax.lax.scan(step, 0.0, xs)
"""
    assert not lint_source(src)


def test_repro_tree_is_lint_clean():
    from pathlib import Path

    import repro.analysis
    from repro.analysis.lint import lint_tree

    findings = lint_tree(Path(repro.analysis.__file__).parents[1])
    assert not findings, "\n".join(map(str, findings))


# ---------------------------------------------------------------------------
# the green half: every registered contract audits clean (quick grid)
# ---------------------------------------------------------------------------


def test_registered_contracts_audit_green():
    from repro.analysis import report as rep
    from repro.analysis.contracts import CONTRACTS
    from repro.analysis.registry import build_cases
    from repro.core import distributed, engine, service, streaming  # noqa: F401

    assert len(CONTRACTS) >= 7
    cases = build_cases(quick=True)
    covered = {c.contract for c in cases}
    for name, c in CONTRACTS.items():
        if not c.extra.get("runtime_only"):
            assert name in covered, f"contract {name} has no audit case"
    for case in cases:
        result = rep.evaluate_case(case)
        assert result.ok, (
            f"{result.label}: " + "; ".join(map(str, result.violations)))
