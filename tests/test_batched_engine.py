"""Batched multi-tenant selection: the batch axis must be invisible.

run_selection_batch solves B independent (V, k) requests in one jitted
dispatch; these tests certify that batching changes throughput and nothing
else — per-request selections, trajectories, AND evaluation counts are
identical to B unbatched run_selection calls across

    strategies {dense, stochastic, lazy}
  × backends {jnp, pallas_interpret}
  × B ∈ {1, 7, 64}

plus ragged per-request k (inert padding slots included), the B-aware gain
tile autotuner, and the donated-carry buffer discipline.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import EvalConfig, run_selection, run_selection_batch
from repro.core import engine as eng
from repro.core.functions import FUNCTIONS
from repro.core.optimizers import stochastic_greedy
from repro.core.service import _stochastic_samples
from repro.data.synthetic import blobs

N, D, K = 48, 8, 3
EPS = 0.1
BACKENDS = ("jnp", "pallas_interpret")
TRAJ_ATOL = {"jnp": 1e-5, "pallas_interpret": 1e-4}
N_DISTINCT = 6  # B > 6 cycles these tenants; duplicates must agree too

_FUNCS: dict = {}


def _funcs(backend: str, fname: str = "exemplar"):
    key = (backend, fname)
    if key not in _FUNCS:
        cfg = EvalConfig(backend=backend)
        _FUNCS[key] = [
            FUNCTIONS[fname](
                jnp.asarray(blobs(N, D, centers=4, seed=70 + t)[0]), cfg)
            for t in range(N_DISTINCT)]
    return _FUNCS[key]


def _ref(f, kind: str, k: int, seed: int):
    """Unbatched engine reference for one request."""
    if kind == "stochastic":
        return stochastic_greedy(f, k, eps=EPS, seed=seed, mode="device")
    cand = np.arange(f.n, dtype=np.int32)[None, :] if kind == "dense" \
        else None
    return run_selection(f, kind=kind, k=k, cand_rounds=cand,
                         counter_key=f"test_ref_{kind}")


@pytest.mark.parametrize("B", [1, 7, 64])
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["dense", "stochastic", "lazy"])
def test_batched_matches_unbatched(kind, backend, B):
    distinct = _funcs(backend)
    tenants = [t % N_DISTINCT for t in range(B)]
    fs = [distinct[t] for t in tenants]
    cand = None
    if kind == "stochastic":
        # the serving layer's draw is bit-identical to stochastic_greedy's
        cand = np.stack(
            [_stochastic_samples(N, K, EPS, seed=t) for t in tenants])
    res = run_selection_batch(fs, kind=kind, k=K, cand_rounds=cand,
                              counter_key=f"test_batched_{kind}")
    refs = {t: _ref(distinct[t], kind, K, t) for t in set(tenants)}
    assert len(res) == B
    for b, t in enumerate(tenants):
        ref = refs[t]
        assert res[b].indices == ref.indices, (kind, backend, B, b)
        assert res[b].evaluations == ref.evaluations, (kind, backend, B, b)
        np.testing.assert_allclose(
            res[b].trajectory, ref.trajectory, atol=TRAJ_ATOL[backend],
            err_msg=f"{kind}/{backend}/B={B} request {b}")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["dense", "lazy"])
def test_batched_ragged_k(kind, backend):
    """Per-request k ≤ scan length: request b freezes after ks[b] rounds
    and gets exactly the unbatched k=ks[b] result; ks[b]=0 slots (bucket
    padding) are inert."""
    ks = [5, 2, 0, 3, 1]
    distinct = _funcs(backend)
    fs = [distinct[b % N_DISTINCT] for b in range(len(ks))]
    res = run_selection_batch(fs, kind=kind, k=max(ks), ks=ks,
                              counter_key=f"test_ragged_{kind}")
    for b, kb in enumerate(ks):
        if kb == 0:
            assert res[b].indices == [] and res[b].evaluations == 0
            continue
        ref = _ref(fs[b], kind, kb, b)
        assert res[b].indices == ref.indices, (kind, backend, b)
        assert res[b].evaluations == ref.evaluations, (kind, backend, b)
        np.testing.assert_allclose(
            res[b].trajectory, ref.trajectory, atol=TRAJ_ATOL[backend])


def test_batched_celf_per_request_eval_counts():
    """Lazy-CELF carries per-request bound state: tenants with different
    data do different amounts of re-scoring, and each request's evaluation
    count must equal its own unbatched CELF run — not a batch-wide
    maximum. (The counts differing ACROSS tenants is what makes this a
    real per-request test.)"""
    distinct = _funcs("jnp")
    # a narrow re-score width (top_b=8) over more rounds makes per-tenant
    # certification behavior actually diverge at this problem size
    res = run_selection_batch(distinct, kind="lazy", k=5, top_b=8,
                              counter_key="test_celf_counts")
    counts = [r.evaluations for r in res]
    refs = [run_selection(f, kind="lazy", k=5, top_b=8,
                          counter_key="test_celf_counts_ref")
            for f in distinct]
    assert counts == [r.evaluations for r in refs]
    assert len(set(counts)) > 1, (
        "test data degenerated: every tenant re-scored identically, so "
        "per-request bound state is not actually exercised")


def test_batched_function_axis():
    """The zoo stays batch-transparent: graph_cut's scalar aux and
    saturated_coverage's per-row caps ride the batch axis unchanged."""
    for fname, params in (("graph_cut", {"lam": 0.5}),
                          ("saturated_coverage", {"sat": 0.25})):
        cfg = EvalConfig(distance="rbf")
        fs = [FUNCTIONS[fname](
            jnp.asarray(blobs(N, D, centers=4, seed=70 + t)[0]) / 10.0,
            cfg, **params) for t in range(4)]
        res = run_selection_batch(fs, kind="dense", k=K,
                                  counter_key=f"test_zoo_{fname}")
        for b, f in enumerate(fs):
            ref = run_selection(
                f, kind="dense", k=K,
                cand_rounds=np.arange(N, dtype=np.int32)[None, :],
                counter_key=f"test_zoo_ref_{fname}")
            assert res[b].indices == ref.indices, (fname, b)
            assert res[b].evaluations == ref.evaluations, (fname, b)
            np.testing.assert_allclose(
                res[b].trajectory, ref.trajectory, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: the gain-tile autotuner must account for the batch axis
# ---------------------------------------------------------------------------


def test_device_block_m_scales_with_batch(monkeypatch):
    """The live batched footprint is (B·n, B·m): a B=1024 bucket sized as
    if B=1 would over-commit memory 1024× (the forced-host failure mode of
    PR 5, now on the batch axis)."""
    monkeypatch.setattr(eng, "_GAIN_TILE_CAP_ELEMS", 1 << 25)
    # fits unbatched: cap 2^25 elems, tile 2^20 × 64 = 2^26 → block 32
    assert eng._device_block_m(1 << 20, 64) == 32
    # the same per-request shape under B=8 must shrink 8× further (floor 8)
    assert eng._device_block_m(1 << 20, 64, n_batch=8) == 8
    # a serving-sized bucket: n=1024, m=1024 fits alone (2^20 ≤ 2^25) ...
    assert eng._device_block_m(1024, 1024) == 1024
    # ... but B=64 tenants make rows = 2^16 → 2^25 // 2^16 = 512
    assert eng._device_block_m(1024, 1024, n_batch=64) == 512
    # degenerate n_batch values behave like B=1
    assert eng._device_block_m(1024, 1024, n_batch=0) == 1024


def test_run_selection_batch_sizes_tiles_for_batch(monkeypatch):
    """run_selection_batch must pass n_batch=B into the autotuner — a
    sizing spy, so a future refactor that drops the argument fails here
    rather than OOMing at B=1024 in production."""
    calls = []
    real = eng._device_block_m

    def spy(n, m, tiles_per_memory=1, n_batch=1):
        calls.append({"n": n, "m": m, "n_batch": n_batch})
        return real(n, m, tiles_per_memory, n_batch)

    monkeypatch.setattr(eng, "_device_block_m", spy)
    fs = _funcs("jnp")[:4]
    run_selection_batch(fs, kind="dense", k=2, counter_key="test_spy")
    assert calls and calls[-1]["n_batch"] == 4 and calls[-1]["n"] == N


# ---------------------------------------------------------------------------
# Satellite: donated scan carry — warm-bucket serving must not churn
# ---------------------------------------------------------------------------


def test_dispatch_donates_seed_and_preserves_function_state():
    """Both jitted dispatches donate the cache seed (it aliases the final
    folded cache output). The donated buffer must be a COPY: the
    function's resident cache_seed / d_e0 stay alive, and repeated
    same-signature calls (warm-bucket serving) return identical results."""
    f = _funcs("jnp")[0]
    cand = np.arange(N, dtype=np.int32)[None, :]
    r1 = run_selection(f, kind="dense", k=K, cand_rounds=cand,
                       counter_key="test_donate")
    assert not f.cache_seed.is_deleted()
    assert not f.d_e0.is_deleted()
    r2 = run_selection(f, kind="dense", k=K, cand_rounds=cand,
                       counter_key="test_donate")
    assert r1.indices == r2.indices and r1.trajectory == r2.trajectory

    fs = _funcs("jnp")[:4]
    b1 = run_selection_batch(fs, kind="dense", k=K,
                             counter_key="test_donate_b")
    b2 = run_selection_batch(fs, kind="dense", k=K,
                             counter_key="test_donate_b")
    assert all(not g.cache_seed.is_deleted() for g in fs)
    assert all(x.indices == y.indices for x, y in zip(b1, b2))


def test_batched_dispatch_consumes_its_seed():
    """The donation is real: the freshly-stacked seed buffer handed to the
    batched dispatch is deleted after the call (aliased onto the final
    cache output), not silently copied."""
    fs = _funcs("jnp")[:2]
    seed_b = jnp.asarray(
        np.stack([np.asarray(g.cache_seed, np.float32) for g in fs]))
    V_b = jnp.asarray(np.stack([np.asarray(g.V) for g in fs]))
    aux_b = jnp.asarray(np.stack([np.asarray(g.row_aux) for g in fs]))
    w0_b = jnp.zeros((2, D), V_b.dtype)
    cand = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None, None, :],
                            (2, 1, N))
    eng._select_scan_batched(
        V_b, seed_b, aux_b, cand, w0_b, jnp.asarray([K, K], jnp.int32),
        fn=fs[0].spec, kind="dense", k=K, top_b=0,
        distance=fs[0].cfg.distance, policy_name="fp32", block_m=N,
        backend="jnp", rbf_gamma=None, counter_key="test_donate_direct")
    assert seed_b.is_deleted()
    assert not V_b.is_deleted()


# ---------------------------------------------------------------------------
# One-dispatch property: B requests must not multiply traces
# ---------------------------------------------------------------------------


def test_batched_is_one_trace_per_signature():
    """Two same-signature batched calls = one trace; the second call hits
    the warm jit cache (the serving layer's whole reason for bucketing)."""
    key = "test_trace_count_batched"
    fs = _funcs("jnp")[:4]
    run_selection_batch(fs, kind="dense", k=K, counter_key=key)
    assert eng.DEVICE_TRACE_COUNTS[key] == 1
    run_selection_batch(fs, kind="dense", k=K, counter_key=key)
    assert eng.DEVICE_TRACE_COUNTS[key] == 1
    # a different B is a different signature — exactly one more trace
    run_selection_batch(fs[:2], kind="dense", k=K, counter_key=key)
    assert eng.DEVICE_TRACE_COUNTS[key] == 2


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------


def test_batched_rejects_mixed_signatures():
    fs = _funcs("jnp")
    other_shape = FUNCTIONS["exemplar"](
        jnp.asarray(blobs(N * 2, D, centers=4, seed=1)[0]))
    with pytest.raises(ValueError, match="payload shape"):
        run_selection_batch([fs[0], other_shape], kind="dense", k=2,
                            counter_key="test_guard")
    other_cfg = FUNCTIONS["exemplar"](
        fs[0].V, EvalConfig(backend="pallas_interpret"))
    with pytest.raises(ValueError, match="EvalConfig"):
        run_selection_batch([fs[0], other_cfg], kind="dense", k=2,
                            counter_key="test_guard")
    gc = FUNCTIONS["graph_cut"](fs[0].V)
    with pytest.raises(ValueError, match="function spec"):
        run_selection_batch([fs[0], gc], kind="dense", k=2,
                            counter_key="test_guard")


def test_batched_rejects_bad_ks():
    fs = _funcs("jnp")[:2]
    with pytest.raises(ValueError, match="ks has"):
        run_selection_batch(fs, kind="dense", k=2, ks=[2],
                            counter_key="test_guard")
    with pytest.raises(ValueError, match=r"\[0, 2\]"):
        run_selection_batch(fs, kind="dense", k=2, ks=[2, 3],
                            counter_key="test_guard")
    assert run_selection_batch(fs, kind="dense", k=2, ks=[0, 0],
                               counter_key="test_guard") \
        == [eng.OptResult([], 0.0, [], 0)] * 2
