"""Fault tolerance: atomic checkpointing, resume, crash simulation."""
import json
import os
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager, config_hash


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((8, 8)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_bitwise(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False)
    st = _state()
    cm.save(3, st, cfg_hash="abc")
    restored, manifest = cm.restore(_state(seed=1), cfg_hash="abc")
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_save(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=True)
    cm.save(1, _state())
    cm.wait()
    assert cm.latest_step() == 1


def test_latest_and_gc(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False, keep_last=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _state())
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_incomplete_write_ignored(tmp_path):
    """A crash mid-write (tmp dir, no rename) must be invisible to restore."""
    cm = CheckpointManager(tmp_path, async_write=False)
    cm.save(5, _state())
    # simulate a crash: a stale .tmp directory and a step dir w/o manifest
    (tmp_path / "step_9.tmp").mkdir()
    (tmp_path / "step_8").mkdir()
    assert cm.latest_step() == 5
    cm.clean_incomplete()
    assert not (tmp_path / "step_9.tmp").exists()


def test_config_hash_mismatch_rejected(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False)
    cm.save(1, _state(), cfg_hash="abc")
    with pytest.raises(ValueError, match="hash"):
        cm.restore(_state(), cfg_hash="different")


def test_restore_missing_leaf_rejected(tmp_path):
    cm = CheckpointManager(tmp_path, async_write=False)
    cm.save(1, _state())
    bigger = {**_state(), "extra": jnp.zeros((2,))}
    with pytest.raises(KeyError):
        cm.restore(bigger)


def test_crash_resume_equivalence(tmp_path):
    """Interrupted-and-resumed training == uninterrupted (bitwise params)."""
    from repro.configs import get_reduced_config
    from repro.data.pipeline import token_batches
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import TrainConfig, train

    cfg = get_reduced_config("qwen3-0.6b")
    opt = OptimizerConfig(warmup_steps=2, total_steps=8, lr=1e-3)

    def batches():
        return token_batches(cfg.vocab_size, 2, 16, steps=8, seed=5)

    # uninterrupted 8 steps
    s_full, _ = train(cfg, TrainConfig(steps=8, ckpt_every=100,
                                       log_every=100), opt, batches())
    # interrupted at 4, resumed to 8
    d = str(tmp_path / "ck")
    train(cfg, TrainConfig(steps=4, ckpt_every=4, ckpt_dir=d,
                           log_every=100), opt, batches())
    it = batches()
    for _ in range(4):  # data pipeline replay: skip consumed batches
        next(it)
    s_res, _ = train(cfg, TrainConfig(steps=8, ckpt_every=4, ckpt_dir=d,
                                      resume="auto", log_every=100), opt, it)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_res["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_restore(tmp_path, rng):
    """Save unsharded, restore onto an 8-device mesh (subprocess)."""
    from tests.conftest import run_with_devices
    out = run_with_devices(f"""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import CheckpointManager
        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        cm = CheckpointManager(r"{tmp_path}", async_write=False)
        cm.save(1, state)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        restored, _ = cm.restore(state, shardings=sh)
        assert restored["w"].sharding.spec == P("data", "model")
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out
