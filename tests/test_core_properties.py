"""Property-based tests of the submodular-function invariants (hypothesis)."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test extra; pip install .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import EvalConfig, ExemplarClustering, greedy

SETTINGS = dict(max_examples=25, deadline=None)


def _f(n=24, d=4, seed=0, **kw):
    rng = np.random.default_rng(seed)
    V = (rng.normal(size=(n, d)) + 1.5).astype(np.float32)
    return ExemplarClustering(jnp.asarray(V), EvalConfig(**kw)), V


@given(seed=st.integers(0, 50),
       idx=st.lists(st.integers(0, 23), min_size=1, max_size=6, unique=True),
       extra=st.integers(0, 23))
@settings(**SETTINGS)
def test_monotone(seed, idx, extra):
    """f(A) ≤ f(A ∪ {e}) — Definition 3."""
    f, V = _f(seed=seed)
    A = V[np.array(idx)]
    Ae = V[np.array(list(set(idx) | {extra}))]
    assert f.value(A) <= f.value(Ae) + 1e-5


@given(seed=st.integers(0, 50),
       a_idx=st.lists(st.integers(0, 23), min_size=1, max_size=4, unique=True),
       b_extra=st.lists(st.integers(0, 23), min_size=1, max_size=4,
                        unique=True),
       e=st.integers(0, 23))
@settings(**SETTINGS)
def test_diminishing_returns(seed, a_idx, b_extra, e):
    """Δ(e|A) ≥ Δ(e|B) for A ⊆ B, e ∉ B — Definition 2 (submodularity)."""
    f, V = _f(seed=seed)
    a_set = set(a_idx)
    b_set = a_set | set(b_extra)
    if e in b_set:
        b_set.discard(e)
        a_set.discard(e)
        if not a_set:
            a_set = {(e + 1) % 24}
            b_set |= a_set
    A = V[np.array(sorted(a_set))]
    B = V[np.array(sorted(b_set))]
    ev = V[np.array([e])]
    dA = f.value(np.concatenate([A, ev])) - f.value(A)
    dB = f.value(np.concatenate([B, ev])) - f.value(B)
    assert dA >= dB - 1e-4


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_empty_set_is_zero(seed):
    f, V = _f(seed=seed)
    assert f.value(np.zeros((0, 4), np.float32)) == 0.0


@given(seed=st.integers(0, 20), k=st.integers(2, 3))
@settings(max_examples=8, deadline=None)
def test_greedy_guarantee(seed, k):
    """Greedy ≥ (1 − 1/e)·OPT on brute-forceable instances (Nemhauser)."""
    f, V = _f(n=12, seed=seed)
    res = greedy(f, k)
    opt = max(
        f.value(V[np.array(c)])
        for c in itertools.combinations(range(12), k)
    )
    assert res.value >= (1 - 1 / np.e) * opt - 1e-5


@given(seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_mincache_matches_direct_value(seed):
    """Incremental value tracking equals direct evaluation (beyond-paper path)."""
    f, V = _f(seed=seed)
    cache = f.init_mincache()
    chosen = []
    rng = np.random.default_rng(seed)
    for j in rng.choice(24, size=5, replace=False):
        chosen.append(int(j))
        cache = f.update_mincache(cache, f.V[int(j)])
        direct = f.value(V[np.array(chosen)])
        assert abs(f.value_from_mincache(cache) - direct) < 1e-4


@given(seed=st.integers(0, 20),
       dist=st.sampled_from(["sqeuclidean", "manhattan", "cosine", "rbf"]))
@settings(max_examples=16, deadline=None)
def test_nonnegative_all_distances(seed, dist):
    """f ≥ 0 and monotone for every supported dissimilarity (paper §IV)."""
    f, V = _f(seed=seed, distance=dist)
    s = f.value(V[:3])
    assert s >= -1e-6
    assert f.value(V[:5]) >= s - 1e-5


@given(seed=st.integers(0, 40), k=st.integers(2, 3),
       eps=st.sampled_from([0.1, 0.2]),
       mode=st.sampled_from(["host", "device"]))
@settings(max_examples=20, deadline=None)
def test_streamed_value_within_sieve_bound(seed, k, eps, mode):
    """SieveStreaming ≥ (1/2 − ε)·OPT ≥ (1/2 − ε)·greedy for any stream
    order (Badanidiyuru et al.) — on both execution plans."""
    from repro.core.optimizers import sieve_streaming

    f, V = _f(seed=seed)
    base = greedy(f, k)
    res = sieve_streaming(f, k, eps=eps, seed=seed, mode=mode)
    assert len(res.indices) <= k
    assert res.value >= (0.5 - eps) * base.value - 1e-5
