"""Data pipeline, curation signal, trainer loop, straggler monitor."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig
from repro.data.pipeline import (CurationConfig, Curator, hashed_embedding,
                                 token_batches)
from repro.data.synthetic import TopicTokenStream, blobs, uniform_problem
from repro.train.monitor import StepMonitor


def test_pipeline_deterministic():
    a = list(token_batches(512, 2, 16, steps=4, seed=3))
    b = list(token_batches(512, 2, 16, steps=4, seed=3))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x["tokens"]),
                                      np.asarray(y["tokens"]))


def test_labels_are_shifted_tokens():
    (batch,) = list(token_batches(512, 2, 16, steps=1, seed=1))
    assert batch["tokens"].shape == (2, 16)
    assert batch["labels"].shape == (2, 16)


def test_curation_selects_topic_diverse_exemplars():
    """Selection covers more topics than a random window prefix."""
    stream = TopicTokenStream(512, n_topics=8, seed=2)
    pool, topics = stream.sample(128, 32, topic_skew=6.0)  # skewed/redundant
    cur = Curator(CurationConfig(window=128, select=16), vocab=512)
    idx = cur.select(pool)
    sel_topics = len(set(topics[idx]))
    prefix_topics = len(set(topics[:16]))
    assert sel_topics >= prefix_topics
    assert cur.last_value > 0


def test_curated_batches_flow():
    ccfg = CurationConfig(window=32, select=8)
    batches = list(token_batches(256, 4, 16, steps=3, curation=ccfg, seed=5))
    assert len(batches) == 3
    for b in batches:
        assert b["tokens"].shape == (4, 16)


def test_hashed_embedding_shape_and_determinism():
    toks = np.random.default_rng(0).integers(0, 100, size=(5, 12))
    e1 = hashed_embedding(toks, dim=16, vocab=100)
    e2 = hashed_embedding(toks, dim=16, vocab=100)
    np.testing.assert_array_equal(e1, e2)
    assert e1.shape == (5, 16)


def test_trainer_loss_decreases():
    from repro.configs import get_reduced_config
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import TrainConfig, train

    cfg = get_reduced_config("qwen3-0.6b")
    batches = token_batches(cfg.vocab_size, 4, 32, steps=30, seed=7,
                            topic_skew=1.0)
    _, hist = train(cfg, TrainConfig(steps=30, log_every=5),
                    OptimizerConfig(lr=3e-3, warmup_steps=5,
                                    total_steps=30), batches)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first, (first, last)


def test_trainer_microbatch_equivalence():
    """Gradient accumulation over microbatches ≈ full-batch step."""
    import jax
    from repro.configs import get_reduced_config
    from repro.train.optimizer import OptimizerConfig
    from repro.train.step import init_train_state, make_train_step

    cfg = get_reduced_config("qwen3-0.6b")
    opt = OptimizerConfig(warmup_steps=1, total_steps=5)
    state1, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    state2, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    (batch,) = list(token_batches(cfg.vocab_size, 8, 16, steps=1, seed=9))
    s1, m1 = jax.jit(make_train_step(cfg, opt, None))(state1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, None,
                                     microbatches=4))(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_straggler_monitor_flags_outliers():
    mon = StepMonitor(k_sigma=3.0, min_samples=4)
    for i in range(20):
        mon.observe(i, 1.0 + 0.01 * (i % 3))
    ev = mon.observe(20, 5.0)  # injected straggler
    assert ev is not None and ev.step == 20
    assert mon.straggler_fraction > 0
    # baseline not poisoned by the outlier
    assert mon.mean < 1.5


def test_blobs_and_uniform_generators():
    X, labels = blobs(100, 8, centers=4, seed=0)
    assert X.shape == (100, 8) and len(set(labels)) <= 4
    U = uniform_problem(50, 8)
    assert U.min() >= 0 and U.max() <= 1
