"""Device-resident selection engine: dispatch accounting + boundary/edge
behavior. Cross-plan parity (host/device/device_sharded × strategies ×
backends) lives in the test_plan_parity.py matrix."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig, ExemplarClustering
from repro.core.engine import validate_candidates
from repro.core.optimizers import (DEVICE_TRACE_COUNTS, greedy, lazy_greedy,
                                   sieve_streaming, stochastic_greedy)
from repro.data.synthetic import blobs


@pytest.fixture(scope="module")
def f():
    X, _ = blobs(300, 16, centers=8, seed=1)
    return ExemplarClustering(jnp.asarray(X))


def test_device_greedy_single_trace(f):
    """All k rounds run in ONE jitted dispatch: the engine traces once per
    (shape, statics) signature and never re-traces on repeat runs."""
    before = DEVICE_TRACE_COUNTS["greedy"]
    first = greedy(f, 5, mode="device")
    mid = DEVICE_TRACE_COUNTS["greedy"]
    again = greedy(f, 5, mode="device")
    after = DEVICE_TRACE_COUNTS["greedy"]
    assert mid <= before + 1  # at most one fresh trace for this signature
    assert after == mid       # second identical run: zero re-traces
    assert first.indices == again.indices


def test_device_greedy_candidate_subset(f):
    cand = np.arange(0, 300, 3)
    host = greedy(f, 5, mode="host", candidates=cand)
    dev = greedy(f, 5, mode="device", candidates=cand)
    assert host.indices == dev.indices
    assert all(i in set(cand.tolist()) for i in dev.indices)


def test_device_greedy_blocked_candidates(f):
    """Candidate blocking (bounded gain tile) must not change selections."""
    full = greedy(f, 5, mode="device")
    blocked = greedy(f, 5, mode="device", block_m=64)  # 300 → 5 ragged blocks
    assert full.indices == blocked.indices


@pytest.mark.parametrize("batch", [1, 2, 4, 300])
def test_device_lazy_fallback_still_exact(f, batch):
    """Tiny top-B forces multi-iteration rescore rounds → selections must
    stay exactly greedy-optimal and host/device evaluation counts must agree
    (both run the same rescore policy)."""
    base = greedy(f, 6, mode="host")
    host = lazy_greedy(f, 6, batch=batch, mode="host")
    dev = lazy_greedy(f, 6, batch=batch, mode="device")
    assert dev.indices == base.indices == host.indices
    assert dev.evaluations == host.evaluations
    # B=1 re-scores can't certify early rounds: extra iterations accrue
    assert dev.evaluations >= f.n + 6


def test_device_lazy_single_trace(f):
    before = DEVICE_TRACE_COUNTS["lazy_greedy"]
    first = lazy_greedy(f, 5, mode="device")
    mid = DEVICE_TRACE_COUNTS["lazy_greedy"]
    again = lazy_greedy(f, 5, mode="device")
    assert mid <= before + 1
    assert DEVICE_TRACE_COUNTS["lazy_greedy"] == mid
    assert first.indices == again.indices


def test_candidate_validation_rejects_and_dedupes(f):
    with pytest.raises(ValueError):
        validate_candidates([0, 5, 300], 300)  # out of range
    with pytest.raises(ValueError):
        validate_candidates([-1, 5], 300)
    assert validate_candidates([7, 3, 7, 3, 9], 300).tolist() == [7, 3, 9]


def test_k_exceeding_candidates_raises(f):
    """Exhausting the candidate pool must raise, not silently re-select."""
    from repro.core.optimizers import lazy_greedy as lg

    for mode in ("host", "device"):
        with pytest.raises(ValueError, match="distinct"):
            greedy(f, 5, mode=mode, candidates=[3, 7])
        with pytest.raises(ValueError, match="k="):
            lg(f, 301, mode=mode)
    with pytest.raises(ValueError, match="k="):
        stochastic_greedy(f, 301)


def test_device_greedy_duplicate_candidates_deduped(f):
    """A duplicated candidate index must not be scored twice nor selected
    twice; host and device agree after boundary dedupe."""
    cand = np.concatenate([np.arange(0, 300, 3), np.arange(0, 300, 3)])
    clean = greedy(f, 5, mode="device", candidates=np.arange(0, 300, 3))
    dup_dev = greedy(f, 5, mode="device", candidates=cand)
    dup_host = greedy(f, 5, mode="host", candidates=cand)
    assert dup_dev.indices == clean.indices == dup_host.indices
    assert len(set(dup_dev.indices)) == 5
    assert dup_dev.evaluations == clean.evaluations  # dupes don't count


def test_exhausted_round_raises_not_duplicates(f):
    """A sample row fully taken by earlier rounds must raise, not silently
    re-select; k=0 and batch=0 degenerate inputs behave identically across
    modes."""
    from repro.core import run_selection

    cand_rounds = np.array([[0, 1], [0, 1], [0, 1], [2, 3]])
    with pytest.raises(ValueError, match="no untaken candidate"):
        run_selection(f, kind="stochastic", k=4, cand_rounds=cand_rounds,
                      plan="device", counter_key="exhausted_test")
    for mode in ("host", "device"):
        r = lazy_greedy(f, 0, mode=mode)
        assert (r.indices, r.value, r.evaluations) == ([], 0.0, 0)
        with pytest.raises(ValueError, match="batch"):
            lazy_greedy(f, 4, batch=0, mode=mode)
    s = stochastic_greedy(f, 0)
    assert (s.indices, s.evaluations) == ([], 0)


def test_stochastic_evaluations_comparable(f):
    """Overdraw correction: both modes report actually-scored candidates."""
    host = stochastic_greedy(f, 6, eps=0.05, seed=3, mode="host")
    dev = stochastic_greedy(f, 6, eps=0.05, seed=3, mode="device")
    assert host.evaluations == dev.evaluations


def test_rbf_pallas_marginal_gains_match_jnp():
    """rbf on a pallas backend must score rbf gains, not raw sqeuclidean."""
    X, _ = blobs(64, 8, centers=4, seed=9)
    fj = ExemplarClustering(jnp.asarray(X), EvalConfig(distance="rbf"))
    fp = ExemplarClustering(jnp.asarray(X), EvalConfig(
        distance="rbf", backend="pallas_interpret"))
    cache = fj.init_mincache()
    gj = np.asarray(fj.marginal_gains(fj.V[:8], cache))
    gp = np.asarray(fp.marginal_gains(fp.V[:8], cache))
    np.testing.assert_allclose(gp, gj, atol=1e-5)
    host = greedy(fp, 3, mode="host")
    dev = greedy(fp, 3, mode="device")
    assert host.indices == dev.indices


def test_fused_gain_update_kernel_matches_reference():
    """gain_update_eval: fold winner into cache + score, vs plain numpy."""
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    n, m, d = 133, 41, 21
    V = (rng.normal(size=(n, d)) + 1.5).astype(np.float32)
    C = (rng.normal(size=(m, d)) + 1.5).astype(np.float32)
    cache = rng.uniform(1.0, 5.0, size=n).astype(np.float32)
    w = (rng.normal(size=d) + 1.5).astype(np.float32)

    def sqd(X, Y):
        return np.maximum(
            (X ** 2).sum(1)[:, None] + (Y ** 2).sum(1)[None, :] - 2 * X @ Y.T, 0)

    nc_ref = np.minimum(cache, sqd(V, w[None, :])[:, 0])
    g_ref = np.maximum(nc_ref[:, None] - sqd(V, C), 0).sum(0) / n

    g, nc = ops.fused_gain_update(
        jnp.asarray(V), jnp.asarray(C), jnp.asarray(cache), jnp.asarray(w),
        interpret=True)
    np.testing.assert_allclose(np.asarray(nc), nc_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g), g_ref, atol=1e-5)


def test_blocked_streaming_matches_unblocked(f):
    """Batched streaming is a pure dispatch optimization: block size must not
    change which elements the sieves accept."""
    r1 = sieve_streaming(f, 5, eps=0.1, seed=2, block_size=1)
    r64 = sieve_streaming(f, 5, eps=0.1, seed=2, block_size=64)
    r300 = sieve_streaming(f, 5, eps=0.1, seed=2, block_size=300)
    assert r1.indices == r64.indices == r300.indices
    assert r1.evaluations == r64.evaluations == r300.evaluations
    assert abs(r1.value - r64.value) < 1e-6


def test_point_distances_block_matches_single(f):
    idx = np.array([3, 17, 99, 250])
    block = np.asarray(f.point_distances_block(f.V[idx]))
    for b, i in enumerate(idx):
        single = np.asarray(f.point_distances(f.V[i]))
        np.testing.assert_allclose(block[b], single, atol=1e-5)
