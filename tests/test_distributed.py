"""Distributed correctness on an 8-device host mesh (subprocess tests).

Covers: sharded multiset evaluation == single-device, distributed greedy ==
local greedy, error-feedback int8 psum, bf16 psum, and the sharding-rule
fallback logic.
"""
import numpy as np
import pytest

from tests.conftest import run_with_devices


def test_distributed_eval_matches_local():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import EvalConfig, evaluate_multiset, pack_sets
        from repro.core.distributed import (make_distributed_eval,
                                            shard_ground_set)
        from repro.core.evaluator import e0_distances
        from jax.sharding import NamedSharding, PartitionSpec as P

        rng = np.random.default_rng(0)
        V = jnp.asarray((rng.normal(size=(256, 32)) + 2).astype(np.float32))
        sets = [np.asarray(V[rng.choice(256, size=5, replace=False)])
                for _ in range(17)]
        pk = pack_sets(sets)
        local = np.asarray(evaluate_multiset(V, pk, EvalConfig()))

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        V_sh = shard_ground_set(V, mesh)
        d_e0 = e0_distances(V, None, "sqeuclidean")
        d_e0_sh = jax.device_put(d_e0, NamedSharding(mesh, P("data")))
        fn = make_distributed_eval(mesh, EvalConfig())
        dist = np.asarray(fn(V_sh, pk.data, pk.lengths, d_e0_sh))
        np.testing.assert_allclose(dist, local, atol=1e-5)
        print("DIST_EVAL_OK")
    """)
    assert "DIST_EVAL_OK" in out


def test_distributed_greedy_matches_local():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import EvalConfig, ExemplarClustering, greedy
        from repro.core.distributed import distributed_greedy

        rng = np.random.default_rng(1)
        V = jnp.asarray((rng.normal(size=(128, 16)) + 2).astype(np.float32))
        local = greedy(ExemplarClustering(V), 5)
        mesh = jax.make_mesh((8,), ("data",))
        idx, val = distributed_greedy(mesh, V, 5)
        assert idx == local.indices, (idx, local.indices)
        assert abs(val - local.value) < 1e-4
        print("DIST_GREEDY_OK")
    """)
    assert "DIST_GREEDY_OK" in out


def test_ef_int8_psum_error_feedback():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import ef_int8_psum, bf16_psum

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(8, 64)).astype(np.float32))
        exact = np.asarray(x).sum(0)

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")), check_rep=False)
        def reduce_once(xs, err):
            y, e = ef_int8_psum(xs[0], err[0], "data")
            return y[None], e[None]

        err = jnp.zeros_like(x)
        y, err = reduce_once(x, err)
        got1 = np.asarray(y)[0]
        rel1 = np.abs(got1 - exact).max() / np.abs(exact).max()
        assert rel1 < 0.05, rel1  # one-shot int8 error is bounded

        # error feedback: repeated reduction of the SAME x converges —
        # average of T steps approaches the exact sum
        acc = np.zeros_like(exact)
        err = jnp.zeros_like(x)
        for t in range(20):
            y, err = reduce_once(x, err)
            acc += np.asarray(y)[0]
        relT = np.abs(acc / 20 - exact).max() / np.abs(exact).max()
        assert relT < rel1 / 2, (relT, rel1)

        @partial(shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=P("data"), check_rep=False)
        def reduce_bf16(xs):
            return bf16_psum(xs[0], "data")[None]

        yb = np.asarray(reduce_bf16(x))[0]
        assert np.abs(yb - exact).max() / np.abs(exact).max() < 0.02
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_sharding_rules_fallback():
    out = run_with_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import MeshRules

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = MeshRules.for_mesh(mesh)
        # kv heads=3 not divisible by model=2 → falls through to head_dim
        spec = rules.spec((8, 128, 3, 64),
                          (("batch",), (None,), ("tp",), (None, "tp")))
        assert spec == P(("pod", "data"), None, None, "model"), spec
        # divisible case: heads take the model axis, head_dim stays unsharded
        spec2 = rules.spec((8, 128, 4, 64),
                           (("batch",), (None,), ("tp",), (None, "tp")))
        assert spec2 == P(("pod", "data"), None, "model", None), spec2
        # batch=1 (long-context decode): seq grabs the data axes instead
        spec3 = rules.spec((1, 4096, 512),
                           (("batch",), ("sp",), (None,)))
        assert spec3 == P(None, "data", None), spec3
        # vocab not divisible → embedding falls back to d_model sharding
        spec4 = rules.spec((49155, 1536), (("tp",), ("fsdp",)))
        assert spec4 == P(None, ("pod", "data")), spec4
        print("RULES_OK")
    """)
    assert "RULES_OK" in out


def test_multipod_mesh_shapes():
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.shape == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        print("MESH_OK")
    """, n_devices=512)
    assert "MESH_OK" in out


def test_moe_ep_matches_dense():
    """shard_map a2a expert parallelism == dense MoE (no-drop capacity)."""
    out = run_with_devices("""
        import dataclasses
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_reduced_config
        from repro.distributed.sharding import MeshRules
        from repro.models import layers as L
        from repro.models.moe_ep import moe_ep, ep_applicable

        cfg = get_reduced_config("qwen3-moe-30b-a3b")
        cfg = dataclasses.replace(cfg, moe_capacity=8.0)
        p_leaf = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        p = jax.tree.map(lambda l: l.value, p_leaf,
                         is_leaf=lambda x: hasattr(x, "dims"))
        B, S = 8, 16
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
        dense = L.moe(p, cfg, x, cfg.act, rules=None)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = MeshRules.for_mesh(mesh)
        assert ep_applicable(cfg, rules, B, S)
        ep = jax.jit(lambda x: moe_ep(p, cfg, x, cfg.act, rules))(x)
        err = float(jnp.max(jnp.abs(dense - ep)))
        assert err < 1e-4, err
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out


def test_flash_decoding_matches_full_forward():
    """Seq-sharded KV decode (flash-decoding) == full forward, ring caches."""
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_reduced_config
        from repro.distributed.sharding import MeshRules
        from repro.models.model import init_model, forward

        cfg = get_reduced_config("gemma3-1b")  # kv=1 → seqshard on 4-way TP
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = MeshRules.for_mesh(mesh)
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        B, S, PRE = 2, 24, 4   # window 16 → ring wraps during decode
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
        full, _ = forward(params, cfg, {"tokens": tokens}, mode="train",
                          remat=False)
        _, caches = forward(params, cfg, {"tokens": tokens[:, :PRE]},
                            mode="prefill", cache_len=S, remat=False)

        @jax.jit   # ONE compile: pos is traced
        def step(tok, caches, pos):
            return forward(params, cfg, {"tokens": tok}, mode="decode",
                           caches=caches, pos_offset=pos, rules=rules,
                           remat=False)

        with mesh:
            errs = []
            for pos in range(PRE, S):
                lg, caches = step(tokens[:, pos:pos+1], caches,
                                  jnp.asarray(pos, jnp.int32))
                errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, pos]))))
        assert max(errs) < 5e-4, max(errs)
        print("FLASHDEC_OK")
    """)
    assert "FLASHDEC_OK" in out
