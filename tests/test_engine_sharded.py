"""Mesh-sharded selection engine (plan ``device_sharded``), subprocess tests.

The whole k-round scan runs inside shard_map with V and the min-distance
cache row-sharded over a forced-host-device mesh; selections must match the
single-device engine for every strategy, with exactly one trace.
"""
from tests.conftest import run_with_devices


def test_sharded_engine_matches_single_device_all_strategies():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import ExemplarClustering, greedy, lazy_greedy, \\
            stochastic_greedy
        from repro.core.optimizers import DEVICE_TRACE_COUNTS
        from repro.data.synthetic import blobs

        assert jax.device_count() == 8
        # n = 300 is not a multiple of 8 → exercises the zero-row padding
        X, _ = blobs(300, 16, centers=8, seed=1)
        f = ExemplarClustering(jnp.asarray(X))

        pairs = [
            ("greedy", lambda m: greedy(f, 6, mode=m)),
            ("stochastic_greedy",
             lambda m: stochastic_greedy(f, 6, eps=0.05, seed=3, mode=m)),
            ("lazy_greedy", lambda m: lazy_greedy(f, 6, mode=m)),
        ]
        for name, fn in pairs:
            single = fn("device")
            sharded = fn("device_sharded")
            assert single.indices == sharded.indices, (
                name, single.indices, sharded.indices)
            np.testing.assert_allclose(
                single.trajectory, sharded.trajectory, atol=1e-5)
            assert single.evaluations == sharded.evaluations, name
            # exactly one trace per signature; a repeat run must not retrace
            key = name + "_sharded"
            assert DEVICE_TRACE_COUNTS[key] == 1, (key, DEVICE_TRACE_COUNTS)
            again = fn("device_sharded")
            assert DEVICE_TRACE_COUNTS[key] == 1, (key, DEVICE_TRACE_COUNTS)
            assert again.indices == sharded.indices
        print("SHARDED_ENGINE_OK")
    """)
    assert "SHARDED_ENGINE_OK" in out


def test_sharded_pallas_kernels_match_device_plan():
    """Tentpole certification at real mesh width: with V + cache row-sharded
    over 8 forced host devices, every strategy scores through the Pallas
    kernels inside the shard_map scan body (interpret on CPU) and must
    reproduce the single-device kernel plan's selections and — for the
    deterministic strategies — evaluation counts; CELF counts stay equal
    too because the bound state is replicated post-psum."""
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import EvalConfig, ExemplarClustering, greedy, \\
            lazy_greedy, stochastic_greedy
        from repro.data.synthetic import blobs

        assert jax.device_count() == 8
        # n = 300 is not a multiple of 8 → zero-row padding through the
        # kernel path (padded rows have cache 0 → exact zero gain partials)
        X, _ = blobs(300, 16, centers=8, seed=1)
        f = ExemplarClustering(
            jnp.asarray(X), EvalConfig(backend="pallas_interpret"))

        pairs = [
            ("greedy", lambda m: greedy(f, 6, mode=m)),
            ("stochastic_greedy",
             lambda m: stochastic_greedy(f, 6, eps=0.05, seed=3, mode=m)),
            ("lazy_greedy", lambda m: lazy_greedy(f, 6, mode=m)),
        ]
        for name, fn in pairs:
            single = fn("device")
            sharded = fn("device_sharded")
            assert single.indices == sharded.indices, (
                name, single.indices, sharded.indices)
            np.testing.assert_allclose(
                single.trajectory, sharded.trajectory, atol=1e-4)
            assert single.evaluations == sharded.evaluations, name
        print("SHARDED_PALLAS_OK")
    """)
    assert "SHARDED_PALLAS_OK" in out


def test_sharded_pool_matches_single_device_all_strategies():
    """Tentpole certification at real mesh width: with the candidate payload
    row-sharded too (pool = V's own shard, O(n/p·d) resident per device),
    every strategy — including CELF's blocked ub0 seeding and top-B takes —
    must reproduce the single-device selections AND evaluation counts. Also
    pins the memory plan itself: the sharded-pool run must not build the
    replicated pool placement."""
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import ExemplarClustering, greedy, lazy_greedy, \\
            stochastic_greedy
        from repro.data.synthetic import blobs

        assert jax.device_count() == 8
        # n = 300 is not a multiple of 8 → zero-row padding inside take()
        X, _ = blobs(300, 16, centers=8, seed=1)
        f = ExemplarClustering(jnp.asarray(X))

        pairs = [
            ("greedy", lambda m: greedy(f, 6, mode=m)),
            ("stochastic_greedy",
             lambda m: stochastic_greedy(f, 6, eps=0.05, seed=3, mode=m)),
            ("lazy_greedy", lambda m: lazy_greedy(f, 6, mode=m)),
        ]
        for name, fn in pairs:
            single = fn("device")
            sharded = fn("device_sharded_pool")
            assert single.indices == sharded.indices, (
                name, single.indices, sharded.indices)
            np.testing.assert_allclose(
                single.trajectory, sharded.trajectory, atol=1e-5)
            assert single.evaluations == sharded.evaluations, name
        # the O(n·d) replicated pool was never placed
        entry = f._sharded_placement_cache[1]
        assert "pool" not in entry, sorted(entry)
        print("SHARDED_POOL_OK")
    """)
    assert "SHARDED_POOL_OK" in out


def test_batched_sharded_8_devices():
    """Batched × sharded composition at real mesh width: B=3 tenants with
    DISTINCT data and ragged ks laid out as (B, n/p) over 8 forced devices,
    one dispatch per (plan, strategy) signature. Every demuxed tenant must
    reproduce its own unbatched sharded run — selections, trajectories, AND
    evaluation counts — on both sharded plans, with exactly one trace per
    signature (a repeat batch must not retrace)."""
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import ExemplarClustering, greedy, lazy_greedy, \\
            run_selection_batch
        from repro.core.distributed import DEVICE_TRACE_COUNTS
        from repro.data.synthetic import blobs

        assert jax.device_count() == 8
        # n = 300 is not a multiple of 8 → (B, n_pad/8) zero-row padding on
        # every shard; ragged ks → the k_eff freeze mask on two tenants
        fs = [ExemplarClustering(
                  jnp.asarray(blobs(300, 16, centers=8, seed=30 + t)[0]))
              for t in range(3)]
        ks = [6, 2, 4]

        for plan in ("device_sharded", "device_sharded_pool"):
            mode = plan
            for kind, ref in (
                    ("dense", lambda f, kk: greedy(f, kk, mode=mode)),
                    ("lazy", lambda f, kk: lazy_greedy(f, kk, mode=mode))):
                key = f"bsh8_{plan}_{kind}"
                results = run_selection_batch(
                    fs, kind=kind, k=max(ks), ks=ks, counter_key=key,
                    plan=plan)
                assert DEVICE_TRACE_COUNTS[key] == 1, (
                    key, DEVICE_TRACE_COUNTS)
                for t, (f, res) in enumerate(zip(fs, results)):
                    single = ref(f, ks[t])
                    assert res.indices == single.indices, (
                        plan, kind, t, res.indices, single.indices)
                    assert res.evaluations == single.evaluations, (
                        plan, kind, t)
                    np.testing.assert_allclose(
                        res.trajectory, single.trajectory, atol=1e-5)
                # repeat batch: same signature, no retrace
                again = run_selection_batch(
                    fs, kind=kind, k=max(ks), ks=ks, counter_key=key,
                    plan=plan)
                assert DEVICE_TRACE_COUNTS[key] == 1, (
                    key, DEVICE_TRACE_COUNTS)
                assert [r.indices for r in again] == \\
                    [r.indices for r in results]
        print("BATCHED_SHARDED_OK")
    """)
    assert "BATCHED_SHARDED_OK" in out


def test_greedi_partition_merge_8_devices():
    """GreeDi at real mesh width: 8 partitions solved independently, the
    8·k partials merged under the sharded cache. Certify the (1−1/e)²
    empirical floor against centralized greedy (the proven guarantee,
    (1−1/e)/min(√k, p), is looser — see test_plan_parity.py), the exact
    two-phase evaluation accounting, and that the merged answer is a valid
    exemplar set."""
    out = run_with_devices("""
        import jax, math, numpy as np
        import jax.numpy as jnp
        from repro.core import ExemplarClustering, greedy
        from repro.data.synthetic import blobs

        assert jax.device_count() == 8
        k = 5
        X, _ = blobs(512, 16, centers=8, seed=2)
        f = ExemplarClustering(jnp.asarray(X))
        base = greedy(f, k, mode="host")
        res = greedy(f, k, mode="greedi")
        assert len(set(res.indices)) == k
        assert all(0 <= i < 512 for i in res.indices)
        assert res.value >= (1 - 1 / math.e) ** 2 * base.value, (
            res.value, base.value)
        n_loc = 512 // 8
        expect = 8 * sum(n_loc - t for t in range(k)) \\
            + sum(8 * k - t for t in range(k)) + 8 * k
        assert res.evaluations == expect, (res.evaluations, expect)
        # k larger than a partition must refuse, not underflow the argmax
        try:
            greedy(f, 65, mode="greedi")
        except ValueError as e:
            assert "fewer than k" in str(e), e
        else:
            raise AssertionError("expected the partition-size guard")
        print("GREEDI_OK")
    """)
    assert "GREEDI_OK" in out


def test_sharded_sieve_8_devices():
    """Mesh-sharded sieve table at real mesh width (with the sieve-gain
    kernel in the scan body): members/values/eval counts must match the
    single-device engine, on both scoring backends."""
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import EvalConfig, ExemplarClustering
        from repro.core.optimizers import salsa, sieve_streaming

        assert jax.device_count() == 8
        from repro.data.synthetic import blobs
        X, _ = blobs(300, 16, centers=8, seed=1)
        for backend in ("jnp", "pallas_interpret"):
            f = ExemplarClustering(
                jnp.asarray(X), EvalConfig(backend=backend))
            for alg in (sieve_streaming, salsa):
                dev = alg(f, 6, eps=0.1, seed=2, mode="device")
                sh = alg(f, 6, eps=0.1, seed=2, mode="device_sharded")
                assert sh.indices == dev.indices, (backend, alg.__name__)
                assert sh.evaluations == dev.evaluations, (
                    backend, alg.__name__)
                np.testing.assert_allclose(sh.value, dev.value, atol=1e-6)
        print("SHARDED_SIEVE_OK")
    """)
    assert "SHARDED_SIEVE_OK" in out


def test_sharded_candidate_subset_and_host_parity():
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import ExemplarClustering, greedy
        from repro.data.synthetic import blobs

        X, _ = blobs(256, 16, centers=8, seed=2)
        f = ExemplarClustering(jnp.asarray(X))
        cand = np.arange(0, 256, 3)
        host = greedy(f, 5, mode="host", candidates=cand)
        sharded = greedy(f, 5, mode="device_sharded", candidates=cand)
        assert host.indices == sharded.indices, (host.indices, sharded.indices)
        assert all(i in set(cand.tolist()) for i in sharded.indices)
        print("SHARDED_SUBSET_OK")
    """)
    assert "SHARDED_SUBSET_OK" in out


def test_init_mincache_sharding_feeds_distributed_gains():
    """init_mincache(sharding=...) places the cache where V's rows live —
    the entry point for driving the standalone distributed evaluators."""
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import EvalConfig, ExemplarClustering
        from repro.core.distributed import (make_distributed_gains,
                                            shard_ground_set)

        rng = np.random.default_rng(6)
        V = jnp.asarray((rng.normal(size=(128, 16)) + 2).astype(np.float32))
        f = ExemplarClustering(V)
        mesh = jax.make_mesh((8,), ("data",))
        sharding = NamedSharding(mesh, P("data"))
        cache = f.init_mincache(sharding=sharding)
        assert cache.sharding == sharding, cache.sharding
        V_sh = shard_ground_set(V, mesh)
        gains_fn = make_distributed_gains(mesh, EvalConfig())
        dist = np.asarray(gains_fn(V_sh, V[:16], cache))
        local = np.asarray(f.marginal_gains(V[:16], f.init_mincache()))
        np.testing.assert_allclose(dist, local, atol=1e-5)
        print("MINCACHE_SHARDING_OK")
    """)
    assert "MINCACHE_SHARDING_OK" in out


def test_distributed_greedy_accepts_pallas_cfg():
    """The wrapper preserves the old contract: kernel backends normalize to
    jnp scoring instead of being rejected."""
    out = run_with_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import EvalConfig, ExemplarClustering, greedy
        from repro.core.distributed import distributed_greedy

        rng = np.random.default_rng(5)
        V = jnp.asarray((rng.normal(size=(64, 16)) + 2).astype(np.float32))
        local = greedy(ExemplarClustering(V), 4)
        mesh = jax.make_mesh((8,), ("data",))
        idx, val = distributed_greedy(
            mesh, V, 4, cfg=EvalConfig(backend="pallas_interpret"))
        assert idx == local.indices, (idx, local.indices)
        print("DIST_PALLAS_CFG_OK")
    """)
    assert "DIST_PALLAS_CFG_OK" in out


def test_sharded_explicit_mesh_axes():
    """A caller-provided 2-D mesh: V shards over the named data axis only."""
    out = run_with_devices("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import ExemplarClustering, greedy
        from repro.data.synthetic import blobs

        X, _ = blobs(128, 16, centers=8, seed=4)
        f = ExemplarClustering(jnp.asarray(X))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        single = greedy(f, 5, mode="device")
        sharded = greedy(f, 5, mode="device_sharded", mesh=mesh)
        assert single.indices == sharded.indices
        print("SHARDED_MESH_OK")
    """)
    assert "SHARDED_MESH_OK" in out
