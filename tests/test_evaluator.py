"""Evaluation-engine equivalence: modes × backends × chunking × precision."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChunkingError, EvalConfig, bytes_per_set,
                        evaluate_multiset, pack_sets, plan_chunks,
                        work_matrix)
from repro.core.precision import FP16_STRICT, FP32


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(3)
    V = jnp.asarray((rng.normal(size=(257, 33)) + 2.0).astype(np.float32))
    sets = [np.asarray(V[rng.choice(257, size=rng.integers(1, 9),
                                    replace=False)]) for _ in range(19)]
    return V, pack_sets(sets)


def _vals(V, pk, **kw):
    return np.asarray(evaluate_multiset(V, pk, EvalConfig(**kw)))


def test_fused_equals_two_pass(problem):
    V, pk = problem
    np.testing.assert_allclose(_vals(V, pk, mode="fused"),
                               _vals(V, pk, mode="two_pass"), atol=1e-5)


def test_fused_equals_naive_alg2(problem):
    """The engine reproduces paper Algorithm 2 exactly."""
    V, pk = problem
    np.testing.assert_allclose(_vals(V, pk), _vals(V, pk, backend="naive"),
                               atol=1e-5)


def test_chunked_equals_unchunked(problem):
    V, pk = problem
    mu = bytes_per_set(V.shape[0], pk.k_max, pk.dim, FP32, "fused")
    for budget in (mu * 3, mu * 7, mu * 100):
        np.testing.assert_allclose(
            _vals(V, pk, memory_budget_bytes=int(budget)), _vals(V, pk),
            atol=1e-5)


def test_chunk_plan_formula(problem):
    """n_chunks = ⌈l / ⌊φ/μ_s⌋⌉ (paper §IV-B-3)."""
    V, pk = problem
    mu = bytes_per_set(V.shape[0], pk.k_max, pk.dim, FP32, "fused")
    chunks = plan_chunks(19, V.shape[0], pk.k_max, pk.dim, FP32, "fused",
                         mu * 5)
    assert len(chunks) == int(np.ceil(19 / 5))
    assert chunks[0] == (0, 5) and chunks[-1][1] == 19


def test_chunking_failure_raises(problem):
    V, pk = problem
    with pytest.raises(ChunkingError, match="lower floating-point"):
        plan_chunks(19, V.shape[0], pk.k_max, pk.dim, FP32, "fused", 10)


def test_auto_budget_resolves_via_probe(problem, monkeypatch):
    """"auto" budget goes through the free-memory probe; probeless backends
    (CPU) degrade to unchunked, a probed value yields the probed chunking."""
    from repro.core import evaluator as ev

    V, pk = problem
    monkeypatch.setattr(ev, "_AUTO_BUDGET_BYTES", False)
    monkeypatch.setattr(ev, "free_memory_bytes", lambda device=None: None)
    assert plan_chunks(19, V.shape[0], pk.k_max, pk.dim, FP32, "fused",
                       "auto") == [(0, 19)]
    mu = bytes_per_set(V.shape[0], pk.k_max, pk.dim, FP32, "fused")
    monkeypatch.setattr(ev, "free_memory_bytes",
                        lambda device=None: int(4 * mu / ev.AUTO_BUDGET_FRACTION))
    # probe frozen at first use: a changed probe must NOT move the chunking
    assert plan_chunks(19, V.shape[0], pk.k_max, pk.dim, FP32, "fused",
                       "auto") == [(0, 19)]
    monkeypatch.setattr(ev, "_AUTO_BUDGET_BYTES", False)  # re-probe
    chunks = plan_chunks(19, V.shape[0], pk.k_max, pk.dim, FP32, "fused",
                         "auto")
    assert chunks == plan_chunks(19, V.shape[0], pk.k_max, pk.dim, FP32,
                                 "fused", 4 * mu)
    assert len(chunks) == 5  # ⌈19/4⌉


def test_device_block_m_uses_probe(monkeypatch):
    """The engine's candidate block size derives from the same probe; the
    probed cap freezes at first use so jit statics can't churn per call."""
    from repro.core import engine as eng

    monkeypatch.setattr(eng, "_GAIN_TILE_CAP_ELEMS", None)
    monkeypatch.setattr(eng, "free_memory_bytes", lambda device=None: None)
    assert eng._device_block_m(1 << 20, 64) == 32  # 128 MiB fallback cap
    monkeypatch.setattr(eng, "free_memory_bytes",
                        lambda device=None: 1 << 30)  # 1 GiB free
    # cap already frozen: a changed probe must NOT change the block size
    assert eng._device_block_m(1 << 20, 64) == 32
    monkeypatch.setattr(eng, "_GAIN_TILE_CAP_ELEMS", None)
    assert eng._device_block_m(1 << 20, 64) == 64  # re-probed: tile fits

    from repro.core import evaluator as ev
    monkeypatch.setattr(ev, "_AUTO_BUDGET_BYTES", False)
    monkeypatch.setattr(ev, "free_memory_bytes", lambda device=None: 0)
    assert ev.resolve_memory_budget("auto") == 0  # 0 free ≠ "no budget"


def test_device_block_m_mesh_aware_sizing(monkeypatch):
    """Regression (sharded autotuning): the gain tile must be sized from
    the LOCAL shard height n/p — sizing from global n under-fills every
    shard p× — and, when p shards' tiles coexist in one physical memory
    space (forced host devices share the allocator the probe measured),
    the cap must divide by p or the shards jointly over-commit it."""
    import jax

    from repro.core import engine as eng

    monkeypatch.setattr(eng, "_GAIN_TILE_CAP_ELEMS", None)
    monkeypatch.setattr(eng, "free_memory_bytes", lambda device=None: None)
    # one tile per memory: 2^25 fallback cap over a 2^20-row tile → 32 wide
    assert eng._device_block_m(1 << 20, 64) == 32
    # 4 coexisting tiles: each gets a quarter of the cap → 8 wide
    assert eng._device_block_m(1 << 20, 64, tiles_per_memory=4) == 8
    # …but sized from the LOCAL height n/4, the same global problem fits
    # the exact same 32-wide tile per shard — under-filling fixed
    assert eng._device_block_m((1 << 20) // 4, 64, tiles_per_memory=4) == 32

    # forced host devices share one memory space; a real accelerator mesh
    # reports 1 tile per device memory
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    expected = jax.device_count() \
        if jax.local_devices()[0].platform == "cpu" else 1
    assert eng.mesh_tiles_per_memory(mesh) == expected


def test_sharded_selection_sizes_tile_from_local_height(monkeypatch):
    """End to end: run_sharded_selection must hand the autotuner the local
    shard height (n_pad/p) and the mesh's tiles-per-memory count."""
    import jax
    import jax.numpy as jnp

    from repro.core import distributed as dist
    from repro.core.functions import ExemplarClustering
    from repro.core.optimizers import greedy

    calls = []
    real = dist._device_block_m

    def spy(n, m, tiles=1):
        calls.append((n, m, tiles))
        return real(n, m, tiles)

    monkeypatch.setattr(dist, "_device_block_m", spy)
    rng = np.random.default_rng(3)
    V = jnp.asarray((rng.normal(size=(250, 8)) + 2).astype(np.float32))
    f = ExemplarClustering(V)
    greedy(f, 3, mode="device_sharded")
    ndev = jax.device_count()
    n_loc = -(-250 // ndev)
    assert calls == [(n_loc, 250, ndev)], calls


def test_device_block_m_batch_mesh_composition(monkeypatch):
    """Regression (batched × sharded autotuning): when BOTH factors apply,
    the tile must be sized from B·n_loc rows — the (B, n/p) slab a shard
    actually scores — with the shared-memory-space cap divided ONCE by the
    coexisting-tile count. Sizing from B·n GLOBAL rows (or dividing the cap
    again per factor) under-fills every shard p×."""
    from repro.core import engine as eng

    monkeypatch.setattr(eng, "_GAIN_TILE_CAP_ELEMS", None)
    monkeypatch.setattr(eng, "free_memory_bytes", lambda device=None: None)
    # fallback cap 2^25 elems, 4 coexisting tiles → 2^23 each; B·n_loc =
    # 4·2^16 = 2^18 rows → a 32-wide tile fits exactly
    assert eng._device_block_m(1 << 16, 64, tiles_per_memory=4,
                               n_batch=4) == 32
    # the regression shapes: sized from B·n GLOBAL (n = n_loc·p = 2^18)
    # the same problem collapses to an 8-wide tile — 4× under-filled
    assert eng._device_block_m((1 << 16) * 4, 64, tiles_per_memory=4,
                               n_batch=4) == 8
    # each factor alone reduces to the already-pinned single-axis behavior
    assert eng._device_block_m(1 << 18, 64, n_batch=4) == \
        eng._device_block_m(1 << 20, 64)
    assert eng._device_block_m(1 << 16, 64, tiles_per_memory=4) == 64


def test_batched_sharded_selection_sizes_tile_from_local_height(monkeypatch):
    """End to end: run_selection_batch on a sharded plan must hand the
    autotuner (n_loc, m_widest, tiles_per_memory, B) — local shard height
    AND batch width, cap split once by the mesh."""
    import jax
    import jax.numpy as jnp

    from repro.core import distributed as dist
    from repro.core.engine import run_selection_batch
    from repro.core.functions import ExemplarClustering

    calls = []
    real = dist._device_block_m

    def spy(n, m, tiles=1, n_batch=1):
        calls.append((n, m, tiles, n_batch))
        return real(n, m, tiles, n_batch=n_batch)

    monkeypatch.setattr(dist, "_device_block_m", spy)
    rng = np.random.default_rng(3)
    fs = [ExemplarClustering(
              jnp.asarray((rng.normal(size=(250, 8)) + 2).astype(np.float32)))
          for _ in range(3)]
    run_selection_batch(fs, kind="dense", k=3, plan="device_sharded",
                        counter_key="eval_spy_bsh")
    ndev = jax.device_count()
    n_loc = -(-250 // ndev)
    assert calls == [(n_loc, 250, ndev, 3)], calls


def test_fp16_strict_reduces_mu():
    """The paper's remediation: FP16 shrinks the per-set footprint."""
    assert bytes_per_set(1000, 10, 100, FP16_STRICT, "fused") < \
        bytes_per_set(1000, 10, 100, FP32, "fused")


def test_nblock_streaming_equals(problem):
    V, pk = problem
    np.testing.assert_allclose(_vals(V, pk, n_block=64), _vals(V, pk),
                               atol=1e-5)


def test_work_matrix_shape_and_reduction(problem):
    """W (l, n) row-reduces to the same values (paper eq. 7)."""
    V, pk = problem
    W = work_matrix(V, pk)
    assert W.shape == (19, 257)
    np.testing.assert_allclose(np.asarray(W.sum(axis=1)), _vals(V, pk),
                               atol=1e-5)


@pytest.mark.parametrize("policy,tol", [("bf16", 2e-2), ("fp16", 5e-3),
                                        ("fp16_strict", 5e-2)])
def test_low_precision_drift_bounded(problem, policy, tol):
    V, pk = problem
    ref = _vals(V, pk)
    got = _vals(V, pk, policy=policy)
    rel = np.max(np.abs(got - ref) / np.maximum(np.abs(ref), 1e-6))
    assert rel < tol


@pytest.mark.parametrize("distance", ["sqeuclidean", "manhattan", "cosine",
                                      "rbf"])
def test_distances_match_naive(problem, distance):
    V, pk = problem
    np.testing.assert_allclose(
        _vals(V, pk, distance=distance),
        _vals(V, pk, distance=distance, backend="naive"), atol=1e-4)


# ---------------------------------------------------------------------------
# Construction-time validation: a bad config or function parameter must fail
# when it is built, not deep inside the first traced dispatch.
# ---------------------------------------------------------------------------


def test_evalconfig_validates_at_construction():
    with pytest.raises(ValueError, match="unknown distance"):
        EvalConfig(distance="hamming")
    with pytest.raises(ValueError, match="mode"):
        EvalConfig(mode="three_pass")
    with pytest.raises(ValueError, match="unknown backend"):
        EvalConfig(backend="tpu")
    with pytest.raises(ValueError, match="kernel_variant"):
        EvalConfig(kernel_variant="nested")
    with pytest.raises(ValueError, match="policy"):
        EvalConfig(policy="fp8")
    with pytest.raises(ValueError, match="memory_budget_bytes"):
        EvalConfig(memory_budget_bytes="lots")


def test_function_parameters_validate_at_construction():
    """Graph cut's λ and saturated coverage's cap fraction gate the zoo's
    monotonicity/submodularity guarantees — out-of-range values must refuse
    before any cache exists."""
    from repro.core import GraphCut, SaturatedCoverage

    rng = np.random.default_rng(0)
    V = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    for lam in (0.0, -0.1, 0.6, 2.0):
        with pytest.raises(ValueError, match="lam"):
            GraphCut(V, lam=lam)
    for sat in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="sat"):
            SaturatedCoverage(V, sat=sat)
    assert GraphCut(V, lam=0.5).spec.lam == 0.5
    assert SaturatedCoverage(V, sat=1.0).spec.sat == 1.0
