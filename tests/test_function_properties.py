"""Submodularity property suite over the registered function zoo.

Every function in ``FUNCTIONS`` must be a monotone submodular set function
under the cache-semantics protocol — the greedy (1−1/e) guarantee, the lazy
upper-bound invariant, and the sieve threshold rules all assume exactly
monotonicity + diminishing returns, so a zoo entry that silently violates
either would corrupt every optimizer built on the protocol. The checks run
the protocol itself (``init_cache`` / ``gains_from_cache`` /
``fold_winner``/ ``value_from_cache``) at fp32 on hypothesis-drawn ground
sets:

* **monotonicity**: every candidate's marginal gain vs every prefix cache
  is ≥ 0 (up to fp32 reduction noise);
* **diminishing returns**: for a FIXED held-out candidate c, Δ(c | S_t) is
  non-increasing along a greedy chain S_0 ⊂ S_1 ⊂ … (the submodularity
  instance the cache update must preserve);
* **value consistency**: f(S) from ``value_from_cache`` equals f(∅) plus
  the telescoped sum of the accepted gains (the trajectory identity every
  engine's ``value_of`` relies on).

Graph cut is certified at λ = 0.5 — the monotonicity boundary its
constructor enforces; saturated coverage at its default cap fraction.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test extra; pip install .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import EvalConfig
from repro.core.functions import FUNCTIONS

#: fp32 mean-reductions over ≤ 48 rows: gains are exact to ~1e-6 of the
#: O(1) similarity scale; the slack absorbs non-associative sum noise.
TOL = 1e-5

ZOO = sorted(FUNCTIONS)


def _make_function(name: str, n: int, d: int, seed: int):
    rng = np.random.default_rng(seed)
    V = jnp.asarray((rng.normal(size=(n, d)) * 0.4).astype(np.float32))
    # rbf keeps the similarity dense so the coverage-style objectives see a
    # non-degenerate problem (raw sqeuclidean at unit scale saturates
    # s = relu(1 − d/2) to 0 and every property holds vacuously)
    return FUNCTIONS[name](V, EvalConfig(distance="rbf"))


@pytest.mark.parametrize("name", ZOO)
@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 48), d=st.integers(2, 8),
       seed=st.integers(0, 2**31 - 1), data=st.data())
def test_monotone_and_diminishing_returns(name, n, d, seed, data):
    f = _make_function(name, n, d, seed)
    k = min(5, n - 1)
    held_out = data.draw(st.integers(0, n - 1), label="held-out candidate")
    order = data.draw(st.permutations(range(n)), label="greedy tie order")

    cache = f.init_cache()
    all_idx = jnp.arange(n, dtype=jnp.int32)
    held_gains = []
    for _ in range(k):
        gains = np.asarray(f.gains_from_cache(cache, all_idx), np.float64)
        # monotonicity: every marginal gain of every candidate vs S_t
        assert gains.min() >= -TOL, (name, gains.min())
        held_gains.append(gains[held_out])
        # fold a (drawn-order) near-argmax winner to advance the chain; the
        # drawn order only breaks exact ties, so this stays a greedy chain
        j = max(order, key=lambda i: gains[i])
        cache = f.fold_winner(cache, jnp.int32(j))
    # diminishing returns for the fixed candidate along the chain
    for a, b in zip(held_gains, held_gains[1:]):
        assert b <= a + TOL, (name, held_gains)


@pytest.mark.parametrize("name", ZOO)
@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 48), d=st.integers(2, 8),
       seed=st.integers(0, 2**31 - 1))
def test_value_telescopes_from_gains(name, n, d, seed):
    f = _make_function(name, n, d, seed)
    k = min(5, n - 1)
    cache = f.init_cache()
    total = float(np.asarray(f.value_from_cache(cache)))  # f(∅), 0 for all
    assert abs(total) <= TOL
    for t in range(k):
        j = (seed + 7 * t) % n  # arbitrary (not greedy) chain — must still hold
        total += float(np.asarray(
            f.gains_from_cache(cache, jnp.asarray([j], jnp.int32)))[0])
        cache = f.fold_winner(cache, jnp.int32(j))
    np.testing.assert_allclose(float(np.asarray(f.value_from_cache(cache))),
                               total, atol=5e-5)
