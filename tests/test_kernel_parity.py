"""Pallas-vs-oracle parity on shapes that exercise the padding path, plus
the precision-policy sweep over the gain kernels.

Unlike test_kernels.py (hypothesis shape sweeps, skipped when the optional
dep is absent), these run unconditionally: ragged ``lengths`` with n, l, d
all *not* divisible by the kernel block sizes, so every pad/mask branch in
``kernels/ops.py`` is hit. The precision sweep runs every kernel parity
check at fp32/bf16/fp16 with per-dtype tolerances, so the half-precision
speedup path (paper §V-B) is exercised in CI instead of only fp32.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig, ExemplarClustering, evaluate_multiset
from repro.core.multiset import PackedMultiset
from repro.core.optimizers import greedy, lazy_greedy
from repro.data.synthetic import blobs

# n, l, k, d chosen indivisible by LANE(128)/SUBLANE(8)/block_n/block_l
RAGGED_SHAPES = [(137, 13, 5, 19), (257, 21, 7, 33), (65, 9, 3, 129)]

# Per-policy tolerance for kernel-vs-jnp AT THE SAME POLICY (both sides
# round inputs/products identically; only reduction/tiling order differs, so
# the band scales with the compute dtype's eps × the blobs problem scale) and
# for policy-vs-fp32 (the paper's §V-B precision-study question: how much
# does half-precision evaluation move the objective?). bf16 keeps 8 mantissa
# bits (eps ≈ 7.8e-3), fp16 has 11 (eps ≈ 9.8e-4), both accumulate fp32.
POLICY_TOLS = {
    "fp32": {"kernel_atol": 1e-5, "vs_fp32_atol": 1e-5},
    "bf16": {"kernel_atol": 5e-2, "vs_fp32_atol": 2e-1},
    "fp16": {"kernel_atol": 1e-2, "vs_fp32_atol": 3e-2},
}


def _ragged_problem(n, l, k, d, seed):
    rng = np.random.default_rng(seed)
    V = jnp.asarray((rng.normal(size=(n, d)) + 2.0).astype(np.float32))
    S = jnp.asarray((rng.normal(size=(l, k, d)) + 2.0).astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, k + 1, size=l).astype(np.int32))
    return V, PackedMultiset(S, lengths)


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
def test_two_pass_pallas_matches_jnp_oracle(shape):
    V, pk = _ragged_problem(*shape, seed=11)
    oracle = np.asarray(evaluate_multiset(V, pk, EvalConfig(mode="two_pass")))
    got = np.asarray(evaluate_multiset(
        V, pk, EvalConfig(mode="two_pass", backend="pallas_interpret")))
    np.testing.assert_allclose(got, oracle, atol=1e-4)


@pytest.mark.parametrize("variant", ["flat", "loop"])
def test_fused_pallas_matches_jnp_oracle_ragged(variant):
    V, pk = _ragged_problem(137, 13, 5, 19, seed=12)
    oracle = np.asarray(evaluate_multiset(V, pk, EvalConfig(mode="fused")))
    got = np.asarray(evaluate_multiset(
        V, pk, EvalConfig(mode="fused", backend="pallas_interpret",
                          kernel_variant=variant)))
    np.testing.assert_allclose(got, oracle, atol=1e-4)


@pytest.mark.parametrize("policy", sorted(POLICY_TOLS))
def test_gain_kernels_precision_sweep(policy):
    """marginal_gain + fused_gain_update at each PrecisionPolicy: the kernel
    must match the jnp path run at the SAME policy within the dtype band,
    and the policy itself must stay within the precision-study band of the
    fp32 oracle (non-vacuous: distances are computed in the low precision)."""
    from repro.core import distances as dist_mod
    from repro.core.precision import resolve as resolve_policy
    from repro.kernels import ops

    tol = POLICY_TOLS[policy]
    rng = np.random.default_rng(17)
    n, m, d = 133, 41, 21
    V = jnp.asarray((rng.normal(size=(n, d)) + 1.5).astype(np.float32))
    C = V[:m]
    cache = jnp.asarray(rng.uniform(1.0, 5.0, size=n).astype(np.float32))
    w = V[n // 2]
    pol = resolve_policy(policy)
    pair = dist_mod.resolve_pairwise("sqeuclidean")

    def jnp_gains(at):
        D = pair(V, C, at)
        return np.asarray(jnp.sum(
            jnp.maximum(cache[:, None] - D, 0.0), axis=0) / n)

    got = np.asarray(ops.marginal_gain(V, C, cache, policy=pol,
                                       interpret=True))
    np.testing.assert_allclose(got, jnp_gains(pol), atol=tol["kernel_atol"])
    np.testing.assert_allclose(got, jnp_gains(resolve_policy("fp32")),
                               atol=tol["vs_fp32_atol"])

    # fused fold-and-score vs explicit jnp fold + score at the same policy
    dw = pair(V, w[None, :], pol)[:, 0]
    cache_f = jnp.minimum(cache, dw.astype(jnp.float32))
    D = pair(V, C, pol)
    g_ref = np.asarray(jnp.sum(
        jnp.maximum(cache_f[:, None] - D, 0.0), axis=0) / n)
    g, nc = ops.fused_gain_update(V, C, cache, w, policy=pol, interpret=True)
    np.testing.assert_allclose(np.asarray(nc), np.asarray(cache_f),
                               atol=tol["kernel_atol"])
    np.testing.assert_allclose(np.asarray(g), g_ref, atol=tol["kernel_atol"])


@pytest.mark.parametrize("policy", sorted(POLICY_TOLS))
def test_max_template_gain_kernels_precision_sweep(policy):
    """The max-cache template (facility location / graph cut scoring):
    ``relu((α + β·d) − cache)`` with the max fold ``cache ← max(cache, s)``,
    at each PrecisionPolicy. Same dtype bands as the min template — the
    kernel shares one tile loop parameterized by fold direction, so a
    regression in the flipped reduction shows up here and not in the
    exemplar sweep."""
    from repro.core import distances as dist_mod
    from repro.core.functions import SIM_ALPHA, SIM_BETA
    from repro.core.precision import resolve as resolve_policy
    from repro.kernels import ops

    tol = POLICY_TOLS[policy]
    rng = np.random.default_rng(17)
    n, m, d = 133, 41, 21
    # rbf distances keep similarity s = relu(1 − d/2) dense (raw blobs-scale
    # sqeuclidean saturates it to 0 and the max template has nothing to do)
    V = jnp.asarray((rng.normal(size=(n, d)) * 0.3).astype(np.float32))
    C = V[:m]
    cache = jnp.asarray(rng.uniform(0.0, 0.8, size=n).astype(np.float32))
    w = V[n // 2]
    pol = resolve_policy(policy)
    pair = dist_mod.resolve_pairwise("rbf")
    affine = (SIM_ALPHA, SIM_BETA)

    def jnp_gains(cv, at):
        D = pair(V, C, at)
        return np.asarray(jnp.sum(
            jnp.maximum((SIM_ALPHA + SIM_BETA * D) - cv[:, None], 0.0),
            axis=0) / n)

    got = np.asarray(ops.marginal_gain(
        V, C, cache, policy=pol, rbf_gamma=dist_mod.RBF_GAMMA,
        fold="max", score_affine=affine, interpret=True))
    np.testing.assert_allclose(got, jnp_gains(cache, pol),
                               atol=tol["kernel_atol"])
    np.testing.assert_allclose(got, jnp_gains(cache, resolve_policy("fp32")),
                               atol=tol["vs_fp32_atol"])

    # fused max fold-and-score vs explicit jnp fold + score at the policy
    dw = pair(V, w[None, :], pol)[:, 0].astype(jnp.float32)
    cache_f = jnp.maximum(cache, jnp.maximum(SIM_ALPHA + SIM_BETA * dw, 0.0))
    g, nc = ops.fused_gain_update(
        V, C, cache, w, policy=pol, rbf_gamma=dist_mod.RBF_GAMMA,
        fold="max", score_affine=affine, interpret=True)
    np.testing.assert_allclose(np.asarray(nc), np.asarray(cache_f),
                               atol=tol["kernel_atol"])
    np.testing.assert_allclose(np.asarray(g), jnp_gains(cache_f, pol),
                               atol=tol["kernel_atol"])


def test_sieve_gains_max_template_matches_jnp():
    """The sieve table × element kernel under the max template (facility
    location streaming): per-row gains vs the protocol's jnp form, on a
    ragged (r, n) shape that forces the +inf column/row padding — a zero
    pad would score relu(α − t) > 0 against finite rows."""
    from repro.core.functions import FnSpec, sieve_gain_rows
    from repro.kernels import ops

    rng = np.random.default_rng(23)
    r, n = 13, 205
    table = jnp.asarray(rng.uniform(0.0, 1.0, size=(r, n)).astype(np.float32))
    dvec = jnp.asarray(rng.uniform(0.0, 4.0, size=n).astype(np.float32))
    fl = FnSpec(name="facility_location")
    ref = np.asarray(jnp.mean(
        sieve_gain_rows(fl, table, dvec, jnp.zeros(n, jnp.float32)), axis=-1))
    got = np.asarray(ops.sieve_gains(table, dvec, fold="max",
                                     score_affine=(1.0, -0.5),
                                     interpret=True))
    np.testing.assert_allclose(got, ref, atol=1e-6)


@pytest.mark.parametrize("policy", sorted(POLICY_TOLS))
def test_engine_selection_precision_sweep(policy):
    """End-to-end half-precision engine runs: host and device plans must
    still pick identical exemplars at each policy (same kernel scoring, same
    rounding), and the achieved value must sit within the precision-study
    band of the fp32 run."""
    X, _ = blobs(96, 8, centers=4, seed=7)
    fp = ExemplarClustering(
        jnp.asarray(X), EvalConfig(policy=policy, backend="pallas_interpret"))
    f32 = ExemplarClustering(jnp.asarray(X))
    ref = greedy(f32, 4, mode="device")
    host = greedy(fp, 4, mode="host")
    dev = greedy(fp, 4, mode="device")
    assert host.indices == dev.indices
    np.testing.assert_allclose(
        dev.value, ref.value, atol=POLICY_TOLS[policy]["vs_fp32_atol"])
    lh = lazy_greedy(fp, 4, mode="host")
    ld = lazy_greedy(fp, 4, mode="device")
    assert lh.indices == ld.indices
    assert lh.evaluations == ld.evaluations


def test_two_pass_pallas_all_singleton_lengths():
    """Degenerate raggedness: every set has length 1 inside a k=6 buffer."""
    rng = np.random.default_rng(13)
    V = jnp.asarray((rng.normal(size=(97, 17)) + 2.0).astype(np.float32))
    S = jnp.asarray((rng.normal(size=(11, 6, 17)) + 2.0).astype(np.float32))
    pk = PackedMultiset(S, jnp.ones((11,), jnp.int32))
    oracle = np.asarray(evaluate_multiset(V, pk, EvalConfig(mode="two_pass")))
    got = np.asarray(evaluate_multiset(
        V, pk, EvalConfig(mode="two_pass", backend="pallas_interpret")))
    np.testing.assert_allclose(got, oracle, atol=1e-4)
