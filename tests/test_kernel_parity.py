"""Pallas-vs-oracle parity on shapes that exercise the padding path.

Unlike test_kernels.py (hypothesis shape sweeps, skipped when the optional
dep is absent), these run unconditionally: ragged ``lengths`` with n, l, d
all *not* divisible by the kernel block sizes, so every pad/mask branch in
``kernels/ops.py`` is hit.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig, evaluate_multiset
from repro.core.multiset import PackedMultiset

# n, l, k, d chosen indivisible by LANE(128)/SUBLANE(8)/block_n/block_l
RAGGED_SHAPES = [(137, 13, 5, 19), (257, 21, 7, 33), (65, 9, 3, 129)]


def _ragged_problem(n, l, k, d, seed):
    rng = np.random.default_rng(seed)
    V = jnp.asarray((rng.normal(size=(n, d)) + 2.0).astype(np.float32))
    S = jnp.asarray((rng.normal(size=(l, k, d)) + 2.0).astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, k + 1, size=l).astype(np.int32))
    return V, PackedMultiset(S, lengths)


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
def test_two_pass_pallas_matches_jnp_oracle(shape):
    V, pk = _ragged_problem(*shape, seed=11)
    oracle = np.asarray(evaluate_multiset(V, pk, EvalConfig(mode="two_pass")))
    got = np.asarray(evaluate_multiset(
        V, pk, EvalConfig(mode="two_pass", backend="pallas_interpret")))
    np.testing.assert_allclose(got, oracle, atol=1e-4)


@pytest.mark.parametrize("variant", ["flat", "loop"])
def test_fused_pallas_matches_jnp_oracle_ragged(variant):
    V, pk = _ragged_problem(137, 13, 5, 19, seed=12)
    oracle = np.asarray(evaluate_multiset(V, pk, EvalConfig(mode="fused")))
    got = np.asarray(evaluate_multiset(
        V, pk, EvalConfig(mode="fused", backend="pallas_interpret",
                          kernel_variant=variant)))
    np.testing.assert_allclose(got, oracle, atol=1e-4)


def test_two_pass_pallas_all_singleton_lengths():
    """Degenerate raggedness: every set has length 1 inside a k=6 buffer."""
    rng = np.random.default_rng(13)
    V = jnp.asarray((rng.normal(size=(97, 17)) + 2.0).astype(np.float32))
    S = jnp.asarray((rng.normal(size=(11, 6, 17)) + 2.0).astype(np.float32))
    pk = PackedMultiset(S, jnp.ones((11,), jnp.int32))
    oracle = np.asarray(evaluate_multiset(V, pk, EvalConfig(mode="two_pass")))
    got = np.asarray(evaluate_multiset(
        V, pk, EvalConfig(mode="two_pass", backend="pallas_interpret")))
    np.testing.assert_allclose(got, oracle, atol=1e-4)
