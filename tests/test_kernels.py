"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Every kernel runs in interpret mode (bit-accurate Python execution of the
kernel body) against ref.py across problem shapes, layouts, modes, dtypes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test extra; pip install .[test]")
from hypothesis import given, settings, strategies as st

from repro.core.precision import BF16, FP16, FP16_STRICT, FP32
from repro.kernels import ops, ref

POLICIES = {"fp32": FP32, "bf16": BF16, "fp16": FP16,
            "fp16_strict": FP16_STRICT}


def _problem(n, l, k, d, seed=0):
    rng = np.random.default_rng(seed)
    V = jnp.asarray((rng.normal(size=(n, d)) + 2.0).astype(np.float32))
    S = jnp.asarray((rng.normal(size=(l, k, d)) + 2.0).astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, k + 1, size=l).astype(np.int32))
    d_e0 = jnp.sum(V.astype(jnp.float32) ** 2, axis=1)
    return V, S, lengths, d_e0


SHAPES = [(64, 8, 3, 16), (257, 19, 7, 33), (512, 64, 10, 100),
          (100, 5, 1, 128), (96, 24, 16, 200)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("variant", ["flat", "loop"])
def test_fused_vs_oracle(shape, variant):
    V, S, lengths, d_e0 = _problem(*shape)
    want = ref.exemplar_eval_ref(V, S, lengths, d_e0)
    got = ops.exemplar_eval(V, S, lengths, d_e0, mode="fused",
                            variant=variant, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_two_pass_vs_oracle(shape):
    V, S, lengths, d_e0 = _problem(*shape)
    want = ref.exemplar_eval_ref(V, S, lengths, d_e0)
    got = ops.exemplar_eval(V, S, lengths, d_e0, mode="two_pass",
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("pname", list(POLICIES))
def test_dtype_sweep_matches_matching_policy_oracle(pname):
    policy = POLICIES[pname]
    V, S, lengths, d_e0 = _problem(128, 16, 5, 48, seed=4)
    want = ref.exemplar_eval_ref(V, S, lengths, d_e0, policy=policy)
    got = ops.exemplar_eval(V, S, lengths, d_e0, policy=policy,
                            interpret=True)
    tol = 1e-5 if pname == "fp32" else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_rbf_kernel_distance():
    V, S, lengths, d_e0 = _problem(96, 12, 4, 32, seed=5)
    d_e0r = 2.0 * (1.0 - jnp.exp(-d_e0))
    want = ref.exemplar_eval_ref(V, S, lengths, d_e0r, rbf_gamma=1.0)
    got = ops.exemplar_eval(V, S, lengths, d_e0r, rbf_gamma=1.0,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_kernel_equals_unchunked():
    V, S, lengths, d_e0 = _problem(128, 32, 6, 40, seed=6)
    full = ops.exemplar_eval(V, S, lengths, d_e0, interpret=True)
    chunked = ops.exemplar_eval(V, S, lengths, d_e0, interpret=True,
                                memory_budget_bytes=400_000)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=1e-6)


@given(n=st.integers(9, 150), m=st.integers(1, 40), d=st.integers(1, 70))
@settings(max_examples=12, deadline=None)
def test_marginal_gain_property_shapes(n, m, d):
    rng = np.random.default_rng(n * 1000 + m * 10 + d)
    V = jnp.asarray((rng.normal(size=(n, d)) + 1.0).astype(np.float32))
    C = jnp.asarray((rng.normal(size=(m, d)) + 1.0).astype(np.float32))
    cache = jnp.asarray(rng.uniform(0.5, 4.0, size=n).astype(np.float32))
    want = ref.marginal_gain_ref(V, C, cache)
    got = ops.marginal_gain(V, C, cache, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    assert np.all(np.asarray(got) >= -1e-6)  # gains are non-negative


def test_kernel_config_respects_budget():
    """The b_x/b_y analogue: S tile obeys the VMEM budget (paper eq. for b_x)."""
    from repro.kernels.ops import VMEM_S_BUDGET, kernel_config
    for k, d_pad in [(1, 128), (10, 128), (500, 128), (500, 256)]:
        cfgk = kernel_config(k, d_pad, FP32, l=100_000, n=100_000)
        assert cfgk.block_l * k * d_pad * 4 <= max(
            VMEM_S_BUDGET, 8 * k * d_pad * 4)  # ≥ SUBLANE rows always allowed
        assert cfgk.block_l % 8 == 0 and cfgk.block_n % 8 == 0


def test_grid_covers_problem():
    """Paper eq. 8: the grid tiles the whole work matrix."""
    from repro.kernels.ops import kernel_config, _round_up
    cfgk = kernel_config(10, 128, FP32, l=1000, n=50_000)
    l_pad = _round_up(1000, cfgk.block_l)
    n_pad = _round_up(50_000, cfgk.block_n)
    gl, gn = cfgk.grid(n_pad, l_pad)
    assert gl * cfgk.block_l >= 1000 and gn * cfgk.block_n >= 50_000
