"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Each assigned architecture instantiates a reduced same-family config, runs a
forward and a full train step (grad + AdamW), asserts output shapes and
finiteness, and checks prefill+decode equals the full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models.model import cache_specs, forward, init_model, lm_loss
from repro.models.params import count_params
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family in ("encdec", "vlm"):
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_finite(arch):
    cfg = get_reduced_config(arch)
    params, dims = init_model(cfg, KEY)
    batch = _batch(cfg)
    logits, _ = forward(params, cfg, batch, mode="train", remat=False)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_reduced_config(arch)
    state, dims = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, OptimizerConfig(warmup_steps=1,
                                                        total_steps=10),
                                   rules=None))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_reduced_config(arch)
    params, _ = init_model(cfg, KEY)
    B, S, PRE = 2, 16, 8
    batch = _batch(cfg, B, S)
    P = cfg.frontend_len if cfg.family == "vlm" else 0
    full, _ = forward(params, cfg, batch, mode="train", remat=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :PRE]
    pre.pop("labels")
    _, caches = forward(params, cfg, pre, mode="prefill", cache_len=P + S,
                        remat=False)
    errs = []
    for pos in range(PRE, S):
        lg, caches = forward(
            params, cfg, {"tokens": batch["tokens"][:, pos:pos + 1]},
            mode="decode", caches=caches,
            pos_offset=P + pos if cfg.family == "vlm" else pos, remat=False)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, pos]))))
    assert max(errs) < 5e-4, f"{arch}: decode diverges {max(errs)}"


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: ring cache must stay exact (gemma/hymba)."""
    cfg = get_reduced_config("gemma3-1b")
    params, _ = init_model(cfg, KEY)
    B, S = 1, 40  # window is 16 → decode spans 2.5 windows
    batch = _batch(cfg, B, S)
    full, _ = forward(params, cfg, batch, mode="train", remat=False)
    pre = {"tokens": batch["tokens"][:, :8]}
    _, caches = forward(params, cfg, pre, mode="prefill", cache_len=S,
                        remat=False)
    for pos in range(8, S):
        lg, caches = forward(params, cfg,
                             {"tokens": batch["tokens"][:, pos:pos + 1]},
                             mode="decode", caches=caches, pos_offset=pos,
                             remat=False)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, pos])))
        assert err < 5e-4, f"pos {pos}: {err}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_formula(arch):
    """Analytic approx_params matches actual init within 2% (reduced cfg)."""
    cfg = get_reduced_config(arch)
    params, _ = init_model(cfg, KEY)
    actual = count_params(params)
    approx = cfg.approx_params()
    assert abs(actual - approx) / actual < 0.02, (arch, actual, approx)


def test_full_config_param_counts_sane():
    """Full configs land in the advertised parameter range."""
    expect = {
        "qwen3-32b": (30e9, 35e9),
        "qwen3-0.6b": (0.4e9, 0.8e9),
        "gemma3-1b": (0.7e9, 1.3e9),
        "stablelm-12b": (11e9, 13.5e9),
        "pixtral-12b": (11e9, 13.5e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "xlstm-1.3b": (1.0e9, 1.7e9),
        "hymba-1.5b": (1.2e9, 1.9e9),
        "whisper-small": (0.2e9, 0.35e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).approx_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_cache_specs_structure_matches_prefill():
    cfg = get_reduced_config("hymba-1.5b")
    params, _ = init_model(cfg, KEY)
    B, S = 2, 16
    batch = {"tokens": _batch(cfg, B, S)["tokens"]}
    _, caches = forward(params, cfg, batch, mode="prefill", cache_len=S,
                        remat=False)
    specs = cache_specs(cfg, B, S)
    got = jax.tree.structure(caches)
    want = jax.tree.structure(specs)
    assert got == want
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(specs)):
        assert a.shape == b.shape, (a.shape, b.shape)
