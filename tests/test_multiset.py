"""Packed multiset representation (paper §IV-B-2) properties."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test extra; pip install .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import pack_base_plus_candidates, pack_sets


@given(sizes=st.lists(st.integers(1, 9), min_size=1, max_size=12))
@settings(max_examples=25, deadline=None)
def test_pack_roundtrip(sizes):
    rng = np.random.default_rng(sum(sizes))
    sets = [rng.normal(size=(k, 5)).astype(np.float32) for k in sizes]
    pk = pack_sets(sets)
    assert pk.num_sets == len(sizes)
    assert pk.k_max == max(sizes)
    mask = np.asarray(pk.mask())
    for j, s in enumerate(sets):
        np.testing.assert_array_equal(
            np.asarray(pk.data[j, :sizes[j]]), s)
        assert mask[j].sum() == sizes[j]
        # padding slots are zero (blank fields, paper Fig. 2)
        assert np.all(np.asarray(pk.data[j, sizes[j]:]) == 0)


@given(sizes=st.lists(st.integers(1, 9), min_size=1, max_size=12))
@settings(max_examples=25, deadline=None)
def test_pad_fraction_accounting(sizes):
    rng = np.random.default_rng(1)
    sets = [rng.normal(size=(k, 3)).astype(np.float32) for k in sizes]
    pk = pack_sets(sets)
    want = 1.0 - sum(sizes) / (len(sizes) * max(sizes))
    assert abs(pk.pad_fraction() - want) < 1e-6


def test_equal_sizes_no_padding():
    """Greedy's equal-size sets → zero blank fields (paper observation)."""
    sets = [np.ones((4, 3), np.float32) for _ in range(7)]
    assert pack_sets(sets).pad_fraction() == 0.0


def test_base_plus_candidates():
    rng = np.random.default_rng(2)
    base = jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32))
    cands = jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))
    pk = pack_base_plus_candidates(base, cands)
    assert pk.num_sets == 6 and pk.k_max == 4
    for j in range(6):
        np.testing.assert_array_equal(np.asarray(pk.data[j, :3]),
                                      np.asarray(base))
        np.testing.assert_array_equal(np.asarray(pk.data[j, 3]),
                                      np.asarray(cands[j]))
    assert np.all(np.asarray(pk.lengths) == 4)


def test_empty_base_plus_candidates():
    cands = jnp.ones((4, 3), jnp.float32)
    pk = pack_base_plus_candidates(jnp.zeros((0, 3), jnp.float32), cands)
    assert pk.k_max == 1 and np.all(np.asarray(pk.lengths) == 1)


def test_slice_sets_chunk_view():
    sets = [np.full((2, 3), i, np.float32) for i in range(10)]
    pk = pack_sets(sets)
    sub = pk.slice_sets(4, 7)
    assert sub.num_sets == 3
    np.testing.assert_array_equal(np.asarray(sub.data[0]),
                                  np.full((2, 3), 4, np.float32))


def test_inconsistent_dims_rejected():
    with pytest.raises(ValueError, match="inconsistent"):
        pack_sets([np.ones((2, 3), np.float32), np.ones((2, 4), np.float32)])


def test_empty_multiset_rejected():
    with pytest.raises(ValueError):
        pack_sets([])
