"""Batched multi-stream sieve engine and its serving surface: per-partition
parity with standalone engines, donation, and the two-tier merge's certified
(1/2−ε)-composed bound."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig, ExemplarClustering, greedy
from repro.core.engine import DEVICE_TRACE_COUNTS
from repro.core.service import MultiStreamIngestionService
from repro.core.streaming import (make_batched_sieve_engine,
                                  make_sieve_engine)
from repro.data.synthetic import blobs

P = 3


@pytest.fixture(scope="module")
def f():
    X, _ = blobs(240, 12, centers=8, seed=4)
    return ExemplarClustering(jnp.asarray(X))


def _split_stream(f, n=90, seed=9):
    """A synthetic stream round-robined into P partition runs."""
    rng = np.random.default_rng(seed)
    base = np.asarray(f.V)[rng.choice(f.n, size=n)]
    stream = (base + 0.03 * rng.normal(size=base.shape)).astype(np.float32)
    ids = np.arange(n)
    parts = [(ids[p::P], stream[p::P]) for p in range(P)]
    return stream, parts


@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
def test_batched_matches_standalone_engines(f, backend):
    """Each partition of the batched engine is bit-identical to a standalone
    DeviceSieveEngine fed the same sub-stream: accept masks, members,
    values, and evaluation counts — on the jnp path AND through the
    grid-over-P fused kernel."""
    _, parts = _split_stream(f)
    eng = make_batched_sieve_engine(f, 4, 0.15, P, block_size=8,
                                    backend=backend)
    masks = eng.offer([i for i, _ in parts], [x for _, x in parts])
    bests = eng.best_all()
    for p, (ids, X) in enumerate(parts):
        ref = make_sieve_engine(f, 4, 0.15, mode="device", block_size=8,
                                backend=backend)
        ref_mask = ref.offer(ids, X)
        np.testing.assert_array_equal(masks[p], ref_mask)
        members, value = bests[p]
        r_members, r_value = ref.best()
        assert members == r_members
        assert value == r_value
        assert eng.evaluations(p) == ref.evaluations()


def test_batched_engine_donates_and_reuses_trace(f):
    """The (P, …)-batched carry is donated (pre-call buffers consumed) and a
    second same-shape block re-dispatches the one traced executable."""
    _, parts = _split_stream(f, n=60)
    eng = make_batched_sieve_engine(f, 4, 0.15, P, block_size=8)
    old = eng.states
    before = DEVICE_TRACE_COUNTS["sieve_sieve_batched"]
    eng.offer([i for i, _ in parts], [x for _, x in parts])
    jax.block_until_ready(eng.states)
    assert old.caches.is_deleted()
    assert not eng.states.caches.is_deleted()
    _, parts2 = _split_stream(f, n=60, seed=10)
    eng.offer([i + 60 for i, _ in parts2], [x for _, x in parts2])
    assert DEVICE_TRACE_COUNTS["sieve_sieve_batched"] - before <= 1


def test_batched_ragged_and_empty_partitions(f):
    """Ragged per-partition runs (including empty) ride shared blocks as
    padding without perturbing the other partitions."""
    rng = np.random.default_rng(12)
    X = np.asarray(f.V)
    idxs = [np.arange(11), np.arange(100, 103), np.zeros(0, np.int64)]
    Xs = [X[:11], X[20:23], np.zeros((0, f.dim), np.float32)]
    eng = make_batched_sieve_engine(f, 3, 0.2, P, block_size=4)
    masks = eng.offer(idxs, Xs)
    assert [len(m) for m in masks] == [11, 3, 0]
    ref = make_sieve_engine(f, 3, 0.2, mode="device", block_size=4)
    np.testing.assert_array_equal(masks[0], ref.offer(idxs[0], Xs[0]))
    assert eng.best_all()[2] == ([], 0.0)
    assert eng.evaluations(2) == 0


def test_multistream_service_certified_merge(f):
    """End-to-end: P logical streams through one service; the snapshot's
    two-tier merge carries the runtime certificate
    value ≥ (1/2−ε)·max_p stream value, and the composed guarantee
    value ≥ ((1/2−ε)²/P)·OPT holds against the greedy reference when the
    stream is exactly V's rows."""
    eps = 0.1
    order = np.random.default_rng(13).permutation(f.n)
    X = np.asarray(f.V)[order]

    async def main():
        async with MultiStreamIngestionService(
                f, k=5, n_streams=P, eps=eps, block_size=8) as svc:
            for j, x in enumerate(X):
                await svc.offer(x, stream=j % P)
            await svc.drain()
            return await svc.snapshot()

    snap = asyncio.run(main())
    assert snap.n_offered == snap.n_ingested == f.n
    assert snap.certified
    assert snap.value >= snap.bound - 1e-5
    assert len(snap.stream_values) == len(snap.stream_members) == P
    assert all(v > 0 for v in snap.stream_values)
    assert 1 <= len(snap.indices) <= 5
    assert snap.exemplars.shape == (len(snap.indices), f.dim)
    # merged members come from the per-partition exemplar sets
    union = {i for m in snap.stream_members for i in m}
    assert set(snap.indices) <= union
    # composed bound vs the greedy proxy for OPT (greedy ≤ OPT)
    ref = greedy(f, 5)
    assert snap.value >= (0.5 - eps) ** 2 / P * ref.value


def test_multistream_round_robin_and_validation(f):
    """Default routing round-robins by id; bad stream indices raise."""
    X = np.asarray(f.V)

    async def main():
        async with MultiStreamIngestionService(
                f, k=3, n_streams=P, block_size=4) as svc:
            ids = [await svc.offer(X[j]) for j in range(12)]
            with pytest.raises(ValueError, match="stream"):
                await svc.offer(X[0], stream=P)
            await svc.drain()
            snap = await svc.snapshot()
            return ids, snap

    ids, snap = asyncio.run(main())
    assert ids == list(range(12))
    assert snap.n_ingested == 12
    # every partition saw 12/P elements (round-robin)
    assert sum(len(m) > 0 for m in snap.stream_members) == P
