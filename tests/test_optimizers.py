"""Optimizer behaviour: agreement, guarantees, streaming sanity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig, ExemplarClustering
from repro.core.optimizers import (OPTIMIZERS, greedy, lazy_greedy,
                                   sieve_streaming, sieve_streaming_pp,
                                   stochastic_greedy, three_sieves)
from repro.data.synthetic import blobs


@pytest.fixture(scope="module")
def f():
    X, _ = blobs(300, 16, centers=8, seed=1)
    return ExemplarClustering(jnp.asarray(X))


def test_greedy_modes_agree(f):
    a = greedy(f, 6, mode="mincache")
    b = greedy(f, 6, mode="multiset")
    assert a.indices == b.indices
    assert abs(a.value - b.value) < 1e-4


def test_lazy_greedy_matches_greedy(f):
    """CELF returns the same set (ties aside) — submodularity exploited."""
    a = greedy(f, 6)
    b = lazy_greedy(f, 6)
    assert a.indices == b.indices


def test_greedy_trajectory_monotone(f):
    res = greedy(f, 8)
    assert all(b >= a - 1e-6 for a, b in zip(res.trajectory,
                                             res.trajectory[1:]))


def test_stochastic_greedy_close(f):
    base = greedy(f, 6)
    res = stochastic_greedy(f, 6, eps=0.01, seed=0)
    assert res.value >= 0.85 * base.value


@pytest.mark.parametrize("alg", [sieve_streaming, sieve_streaming_pp])
def test_sieves_half_guarantee(f, alg):
    """(1/2 − ε) of greedy value (greedy ≈ OPT proxy on easy blobs)."""
    base = greedy(f, 6)
    res = alg(f, 6, eps=0.1, seed=2)
    assert len(res.indices) <= 6
    assert res.value >= 0.45 * base.value


def test_three_sieves_returns_valid_set(f):
    res = three_sieves(f, 6, eps=0.1, T=10, seed=3)
    assert len(res.indices) <= 6
    assert res.value >= 0.0
    # with a patient threshold schedule it should find something useful
    res2 = three_sieves(f, 6, eps=0.25, T=5, seed=3)
    assert res2.value > 0


def test_salsa_returns_valid_set(f):
    res = OPTIMIZERS["salsa"](f, 6, seed=4)
    base = greedy(f, 6)
    assert len(res.indices) <= 6
    assert res.value >= 0.4 * base.value


def test_streaming_order_independence_of_api(f):
    """Different stream orders → possibly different sets, but valid ones."""
    r1 = sieve_streaming(f, 5, order=np.arange(300))
    r2 = sieve_streaming(f, 5, order=np.arange(299, -1, -1))
    for r in (r1, r2):
        assert len(r.indices) <= 5
        assert r.value > 0


def test_evaluations_accounting(f):
    """``evaluations`` counts actually-scored candidates: already-selected
    ones are masked out of the argmax and do not count, identically in host
    and device modes."""
    res = greedy(f, 4)
    assert res.evaluations == 300 + 299 + 298 + 297
