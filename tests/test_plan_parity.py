"""Cross-plan parity test matrix — the engine's central certification.

ONE parametrized suite asserts identical selections, trajectories, values,
and evaluation counts across the full product

    functions {exemplar, facility_location, graph_cut, saturated_coverage}
  × plans {host, device, device_sharded, device_sharded_pool}
  × candidate strategies {dense, stochastic, lazy}
  × evaluation backends {jnp, pallas_interpret}
  × n ∈ {1024, 8192} (exemplar; the zoo axis runs at n = 1024)
  × batch B ∈ {1, 4} (the batched device plan: B per-tenant-distinct
    requests in one dispatch, each compared against ITS OWN host run)
  × batched-sharded B ∈ {1, 4} × {device_sharded, device_sharded_pool}
    (the (B, n/p) mesh composition: each demuxed tenant compared against
    ITS OWN unbatched sharded run — selections and eval counts exact)

replacing the ad-hoc per-plan parity tests previously scattered across
test_device_optimizers.py / test_engine_sharded.py. Every cell runs all
exact plans and compares them against the host reference — so a regression
in any plan × strategy × backend wiring (including the Pallas kernels inside
the shard_map scan body, the fused fold-and-score step, and the sharded
pool's psum-materialized candidate blocks) fails a named cell, not a smoke
test. GreeDi is certified separately below: its selections are *allowed* to
differ from centralized greedy, so its cell asserts the partition bound and
the exact evaluation accounting instead of equality.

The sharded plans use the default mesh over all local devices: a 1-device
mesh under plain pytest (shard_map semantics, no collective traffic), 2
devices in the CI pallas-interpret job, and 8 in the subprocess tests of
test_engine_sharded.py — the wiring under test is identical.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EvalConfig, ExemplarClustering
from repro.core.functions import FUNCTIONS, kernel_template
from repro.core.optimizers import greedy, lazy_greedy, stochastic_greedy
from repro.data.synthetic import blobs

K = 6
NS = (1024, 8192)
PLANS = ("host", "device", "device_sharded", "device_sharded_pool")
BACKENDS = ("jnp", "pallas_interpret")
#: jnp plans share every reduction; kernel plans may differ from the host
#: fold in the last ulp (see kernels/marginal_gain.py), hence the wider band.
TRAJ_ATOL = {"jnp": 1e-5, "pallas_interpret": 1e-4}

STRATEGIES = {
    "dense": lambda f, plan: greedy(f, K, mode=plan),
    "stochastic": lambda f, plan: stochastic_greedy(
        f, K, eps=0.05, seed=3, mode=plan),
    "lazy": lambda f, plan: lazy_greedy(f, K, mode=plan),
}

_FUNCS: dict = {}


def _func(n: int, backend: str) -> ExemplarClustering:
    """One ExemplarClustering per (n, backend), shared across the matrix so
    the sharded placement / trace caches amortize over cells."""
    key = (n, backend)
    if key not in _FUNCS:
        X, _ = blobs(n, 24, centers=12, seed=13)
        _FUNCS[key] = ExemplarClustering(
            jnp.asarray(X), EvalConfig(backend=backend))
    return _FUNCS[key]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("n", NS)
def test_plan_parity_matrix(n, strategy, backend):
    f = _func(n, backend)
    run = STRATEGIES[strategy]
    results = {plan: run(f, plan) for plan in PLANS}
    ref = results["host"]
    assert len(ref.indices) == K and len(set(ref.indices)) == K
    assert ref.evaluations > 0
    for plan, res in results.items():
        assert res.indices == ref.indices, (
            f"{plan} selections diverge from host under "
            f"{strategy}/{backend}/n={n}: {res.indices} != {ref.indices}")
        assert res.evaluations == ref.evaluations, (
            f"{plan} evaluation count diverges under "
            f"{strategy}/{backend}/n={n}")
        np.testing.assert_allclose(
            res.trajectory, ref.trajectory, atol=TRAJ_ATOL[backend],
            err_msg=f"{plan} trajectory under {strategy}/{backend}/n={n}")
        np.testing.assert_allclose(
            res.value, ref.value, atol=TRAJ_ATOL[backend])


# ---------------------------------------------------------------------------
# Function axis: the zoo runs the SAME matrix. Raw sqeuclidean blobs saturate
# the similarity s = relu(1 − d/2) to 0 for the coverage-style objectives
# (every selection degenerates to index tie-breaking), so the zoo cells use
# the rbf distance on down-scaled blobs — a dense, non-degenerate similarity
# where selections actually discriminate. facility_location and graph_cut
# score through the shared max-template Pallas kernel in the kernel cells;
# saturated_coverage has no kernel form and certifies the silent jnp route.
# ---------------------------------------------------------------------------

ZOO = ("facility_location", "graph_cut", "saturated_coverage")
N_ZOO = 1024


def _zoo_func(name: str, backend: str):
    key = (name, backend)
    if key not in _FUNCS:
        X, _ = blobs(N_ZOO, 24, centers=12, seed=13)
        _FUNCS[key] = FUNCTIONS[name](
            jnp.asarray(X) / 10.0, EvalConfig(distance="rbf", backend=backend))
    return _FUNCS[key]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("fname", ZOO)
def test_plan_parity_matrix_function_axis(fname, strategy, backend):
    f = _zoo_func(fname, backend)
    # the template routing under test: FL/GC hit the shared min/max kernel,
    # saturated coverage must certify the no-kernel-form jnp path
    assert (kernel_template(f.spec) is not None) == (
        fname in ("facility_location", "graph_cut"))
    run = STRATEGIES[strategy]
    results = {plan: run(f, plan) for plan in PLANS}
    ref = results["host"]
    assert len(ref.indices) == K and len(set(ref.indices)) == K
    assert ref.evaluations > 0
    assert ref.value > 0
    for plan, res in results.items():
        assert res.indices == ref.indices, (
            f"{plan} selections diverge from host under "
            f"{fname}/{strategy}/{backend}: {res.indices} != {ref.indices}")
        assert res.evaluations == ref.evaluations, (
            f"{plan} evaluation count diverges under "
            f"{fname}/{strategy}/{backend}")
        np.testing.assert_allclose(
            res.trajectory, ref.trajectory, atol=TRAJ_ATOL[backend],
            err_msg=f"{plan} trajectory under {fname}/{strategy}/{backend}")
        np.testing.assert_allclose(
            res.value, ref.value, atol=TRAJ_ATOL[backend])


# ---------------------------------------------------------------------------
# Batch axis: run_selection_batch is the device plan's multi-tenant form —
# B requests with DISTINCT per-tenant data in one dispatch, each demuxed
# result compared against that tenant's own host reference. This is the
# serving layer's correctness contract (batching changes throughput, not
# output); the fine-grained B × ragged-k × eval-count matrix lives in
# test_batched_engine.py.
# ---------------------------------------------------------------------------

BATCH_N = 1024
_BATCH_FUNCS: dict = {}


def _batch_funcs(backend: str, b: int):
    key = (backend, b)
    if key not in _BATCH_FUNCS:
        cfg = EvalConfig(backend=backend)
        _BATCH_FUNCS[key] = [
            ExemplarClustering(
                jnp.asarray(blobs(BATCH_N, 24, centers=12, seed=40 + t)[0]),
                cfg)
            for t in range(b)]
    return _BATCH_FUNCS[key]


HOST_REF = {
    "dense": lambda f, seed: greedy(f, K, mode="host"),
    "stochastic": lambda f, seed: stochastic_greedy(
        f, K, eps=0.05, seed=seed, mode="host"),
    "lazy": lambda f, seed: lazy_greedy(f, K, mode="host"),
}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("b", (1, 4))
def test_plan_parity_matrix_batch_axis(b, strategy, backend):
    from repro.core import run_selection_batch
    from repro.core.service import _stochastic_samples

    fs = _batch_funcs(backend, b)
    cand = None
    if strategy == "stochastic":
        cand = np.stack([_stochastic_samples(BATCH_N, K, 0.05, seed=t)
                         for t in range(b)])
    results = run_selection_batch(
        fs, kind=strategy, k=K, cand_rounds=cand,
        counter_key=f"parity_batch_{strategy}")
    assert len(results) == b
    for t, (f, res) in enumerate(zip(fs, results)):
        ref = HOST_REF[strategy](f, t)
        assert res.indices == ref.indices, (
            f"batched request {t} diverges from host under "
            f"{strategy}/{backend}/B={b}: {res.indices} != {ref.indices}")
        assert res.evaluations == ref.evaluations, (
            f"batched request {t} evaluation count diverges under "
            f"{strategy}/{backend}/B={b}")
        np.testing.assert_allclose(
            res.trajectory, ref.trajectory, atol=TRAJ_ATOL[backend],
            err_msg=f"batched request {t} trajectory under "
                    f"{strategy}/{backend}/B={b}")


# ---------------------------------------------------------------------------
# Batched × sharded composition: the same B-tenant dispatch laid out as
# (B, n/p) across the mesh. Each tenant's column rides the SAME per-round
# psum (one O(B·m) collective, not B), so every demuxed result must be
# bit-identical — selections AND eval counts — to that tenant's own
# unbatched sharded run. Under plain pytest this is a 1-device mesh; the CI
# pallas-interpret job re-runs it on 2 forced devices and the 8-device
# subprocess case lives in test_engine_sharded.py.
# ---------------------------------------------------------------------------

SHARDED_REF = {
    "dense": lambda f, seed, plan: greedy(f, K, mode=plan),
    "stochastic": lambda f, seed, plan: stochastic_greedy(
        f, K, eps=0.05, seed=seed, mode=plan),
    "lazy": lambda f, seed, plan: lazy_greedy(f, K, mode=plan),
}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
@pytest.mark.parametrize("plan", ("device_sharded", "device_sharded_pool"))
@pytest.mark.parametrize("b", (1, 4))
def test_plan_parity_matrix_batched_sharded(b, plan, strategy, backend):
    from repro.core import run_selection_batch
    from repro.core.service import _stochastic_samples

    fs = _batch_funcs(backend, b)
    cand = None
    if strategy == "stochastic":
        cand = np.stack([_stochastic_samples(BATCH_N, K, 0.05, seed=t)
                         for t in range(b)])
    results = run_selection_batch(
        fs, kind=strategy, k=K, cand_rounds=cand, plan=plan,
        counter_key=f"parity_bsh_{plan}_{strategy}")
    assert len(results) == b
    for t, (f, res) in enumerate(zip(fs, results)):
        ref = SHARDED_REF[strategy](f, t, plan)
        assert res.indices == ref.indices, (
            f"batched {plan} request {t} diverges from unbatched under "
            f"{strategy}/{backend}/B={b}: {res.indices} != {ref.indices}")
        assert res.evaluations == ref.evaluations, (
            f"batched {plan} request {t} evaluation count diverges under "
            f"{strategy}/{backend}/B={b}")
        np.testing.assert_allclose(
            res.trajectory, ref.trajectory, atol=TRAJ_ATOL[backend],
            err_msg=f"batched {plan} request {t} trajectory under "
                    f"{strategy}/{backend}/B={b}")


def test_feature_based_runs_host_plans_only():
    """feature_based keeps a (d,)-shaped accumulator cache — no n-aligned
    vec to shard or scan over, so the host plans work and every device plan
    refuses with a pointed message."""
    X, _ = blobs(256, 16, centers=6, seed=5)
    f = FUNCTIONS["feature_based"](jnp.asarray(X) / 10.0)
    res = greedy(f, K, mode="host")
    assert len(res.indices) == K and res.value > 0
    with pytest.raises(ValueError, match="host execution plans"):
        greedy(f, K, mode="device")


def test_backends_agree_on_selections():
    """The two backends are different arithmetic, not different algorithms:
    on well-separated data every (plan, strategy) cell picks the same
    exemplars regardless of backend."""
    n = 1024
    for strategy, run in STRATEGIES.items():
        picks = {b: run(_func(n, b), "device").indices for b in BACKENDS}
        assert picks["jnp"] == picks["pallas_interpret"], strategy


# ---------------------------------------------------------------------------
# GreeDi: partition-then-merge is a *different algorithm* with a guarantee,
# not an exact plan — certified against a (1−1/e)²-style floor on this
# synthetic data plus exact evaluation accounting (partition + merge
# rounds). Note the floor asserted here is EMPIRICAL: the proven GreeDi
# guarantee is (1−1/e)/min(√k, p) of optimal (Mirzasoleiman et al.), which
# is weaker; well-separated blobs sit far above both, so the tighter floor
# is a meaningful regression tripwire without overclaiming theory.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", NS)
def test_greedi_partition_bound_and_accounting(n, backend):
    f = _func(n, backend)
    base = greedy(f, K, mode="host")
    res = greedy(f, K, mode="greedi")
    assert len(res.indices) == K and len(set(res.indices)) == K
    assert all(0 <= i < n for i in res.indices)
    # empirical floor on this data (see module note); the proven guarantee
    # (1−1/e)/min(√k, p) is looser and also implied
    assert res.value >= (1.0 - 1.0 / math.e) ** 2 * base.value
    # trajectory is the *global* f(S_t) of the merge round: monotone, ends
    # at the reported value
    assert res.trajectory == sorted(res.trajectory)
    np.testing.assert_allclose(res.trajectory[-1], res.value, atol=1e-6)
    # exact accounting: p partitions of n/p candidates run k dense rounds
    # (round t scores n/p − t live candidates), then the merge round scores
    # the p·k gathered candidates (round t scores p·k − t), then best-of-both
    # re-evaluates each of the p local solutions globally (p·k folds)
    p = jax.device_count()
    assert n % p == 0, "blobs sizes divide the forced device counts"
    n_loc = n // p
    expect = p * sum(n_loc - t for t in range(K)) \
        + sum(p * K - t for t in range(K)) + p * K
    assert res.evaluations == expect


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("fname", ZOO)
def test_greedi_function_axis(fname, backend):
    """GreeDi over the zoo: same partition floor and exact accounting.
    Phase 1 runs each partition under LOCAL normalizers (graph cut's penalty
    normalizer must match its gain normalizer inside the local argmax);
    phase 2 re-normalizes globally and takes the better of the merged
    solution and the best locally-greedy solution evaluated globally."""
    f = _zoo_func(fname, backend)
    base = greedy(f, K, mode="host")
    res = greedy(f, K, mode="greedi")
    assert len(res.indices) == K and len(set(res.indices)) == K
    assert res.value >= (1.0 - 1.0 / math.e) ** 2 * base.value
    assert res.trajectory == sorted(res.trajectory)
    np.testing.assert_allclose(res.trajectory[-1], res.value, atol=1e-6)
    p = jax.device_count()
    n_loc = N_ZOO // p
    expect = p * sum(n_loc - t for t in range(K)) \
        + sum(p * K - t for t in range(K)) + p * K
    assert res.evaluations == expect


def test_greedi_rejects_unsupported_shapes():
    f = _func(1024, "jnp")
    with pytest.raises(ValueError, match="greedi"):
        lazy_greedy(f, K, mode="greedi")
    with pytest.raises(ValueError, match="subset"):
        greedy(f, K, mode="greedi", candidates=np.arange(0, 1024, 2))
    with pytest.raises(ValueError, match="stochastic"):
        stochastic_greedy(f, K, mode="greedi")
