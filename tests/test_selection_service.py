"""SelectionService: the multi-tenant front end over run_selection_batch.

Certifies the serving pipeline end to end — submit → signature bucket →
padded batched dispatch → per-request demux — returns exactly what direct
engine calls return, amortizes dispatches as promised by the bucketing
policy, isolates bucket failures, and applies backpressure.
"""
import asyncio

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import EvalConfig, SelectionService, run_selection
from repro.core.functions import FUNCTIONS
from repro.core.optimizers import stochastic_greedy
from repro.core.service import _SelectionRequest, _next_pow2
from repro.data.synthetic import blobs

N, D, K = 48, 8, 3


def _tenants(count, n=N, seed0=200):
    return [blobs(n, D, centers=4, seed=seed0 + t)[0] for t in range(count)]


def _ref(X, kind, k, seed=0, **kw):
    f = FUNCTIONS["exemplar"](jnp.asarray(X))
    if kind == "stochastic":
        return stochastic_greedy(f, k, eps=kw.get("eps", 0.05), seed=seed,
                                 mode="device")
    cand = np.arange(X.shape[0], dtype=np.int32)[None, :] \
        if kind == "dense" else None
    return run_selection(f, kind=kind, k=k, cand_rounds=cand,
                         top_b=kw.get("top_b", 0), counter_key="svc_ref")


def test_served_results_match_direct_engine_calls():
    """Mixed kinds, ragged k, per-request stochastic seeds — every tenant
    gets exactly its direct-call result."""
    Xs = _tenants(9)
    kinds = [["dense", "lazy", "stochastic"][i % 3] for i in range(9)]
    ks = [2 + i % 3 for i in range(9)]

    async def main():
        async with SelectionService(max_batch=8) as svc:
            res = await asyncio.gather(*[
                svc.submit(X, k=kb, kind=kind, seed=i, top_b=16)
                for i, (X, kind, kb) in enumerate(zip(Xs, kinds, ks))])
            return res, dict(svc.stats)

    res, stats = asyncio.run(main())
    for i, (X, kind, kb) in enumerate(zip(Xs, kinds, ks)):
        ref = _ref(X, kind, kb, seed=i, top_b=16)
        assert res[i].indices == ref.indices, (i, kind)
        assert res[i].evaluations == ref.evaluations, (i, kind)
        np.testing.assert_allclose(res[i].trajectory, ref.trajectory,
                                   atol=1e-5)
    assert stats["requests"] == 9


def test_bucketing_amortizes_dispatches():
    """16 same-signature tenants submitted concurrently ride few batched
    dispatches (1 when the burst lands in one worker drain), never 16."""
    Xs = _tenants(16)

    async def main():
        async with SelectionService(max_batch=16) as svc:
            res = await asyncio.gather(*[svc.submit(X, k=K) for X in Xs])
            return res, dict(svc.stats)

    res, stats = asyncio.run(main())
    assert stats["batched_requests"] == 16
    assert stats["dispatches"] < 16 / 2, stats
    for X, r in zip(Xs, res):
        assert r.indices == _ref(X, "dense", K).indices


def test_bucket_signature_policy():
    """Dense/lazy pool k up to the next power of two (ragged masking makes
    them exact); stochastic buckets by exact (k, eps) because the sample
    width enters the dispatch shape; seeds stay out of the signature."""
    X = _tenants(1)[0]
    fut = None  # signature() never touches the future

    def sig(**kw):
        base = dict(X=X, k=3, fn="exemplar", params=(), kind="dense",
                    seed=0, eps=0.05, top_b=0, future=fut)
        return _SelectionRequest(**{**base, **kw}).signature()

    assert sig(k=3) == sig(k=4)                      # pow2 pooling
    assert sig(k=4) != sig(k=5)
    assert sig() != sig(kind="lazy")
    assert sig() != sig(fn="graph_cut")
    assert sig() != sig(params=(("lam", 0.25),))
    assert sig(kind="stochastic", k=3) != sig(kind="stochastic", k=4)
    assert sig(kind="stochastic", eps=0.05) != sig(kind="stochastic",
                                                   eps=0.2)
    assert sig(kind="stochastic", seed=1) == sig(kind="stochastic", seed=2)
    assert _next_pow2(1) == 1 and _next_pow2(5) == 8


def test_padding_slots_are_accounted_and_inert():
    """A 3-tenant bucket pads to B=4 with one k_eff=0 slot; the padding is
    visible in stats and invisible in results."""
    Xs = _tenants(3)

    async def main():
        async with SelectionService(max_batch=8) as svc:
            res = await asyncio.gather(*[svc.submit(X, k=K) for X in Xs])
            return res, dict(svc.stats)

    res, stats = asyncio.run(main())
    assert len(res) == 3
    assert stats["padded_slots"] >= 1
    for X, r in zip(Xs, res):
        assert r.indices == _ref(X, "dense", K).indices


def test_bucket_error_isolated_and_service_survives():
    """A bad request fails ITS bucket's future with the real error; other
    buckets and later submissions are unaffected."""
    Xs = _tenants(2)

    async def main():
        async with SelectionService(max_batch=8) as svc:
            good = svc.submit(Xs[0], k=K)
            bad = svc.submit(Xs[0], k=K, fn="feature_based")  # host-only fn
            g = await good
            with pytest.raises(ValueError, match="host execution plans"):
                await bad
            g2 = await svc.submit(Xs[1], k=K)
            return g, g2

    g, g2 = asyncio.run(main())
    assert g.indices == _ref(Xs[0], "dense", K).indices
    assert g2.indices == _ref(Xs[1], "dense", K).indices


def test_submit_validates_before_queueing():
    X = _tenants(1)[0]

    async def main():
        async with SelectionService() as svc:
            with pytest.raises(ValueError, match="unknown strategy"):
                await svc.submit(X, k=2, kind="eager")
            with pytest.raises(ValueError, match="unknown function"):
                await svc.submit(X, k=2, fn="nope")
            with pytest.raises(ValueError, match="cannot select"):
                await svc.submit(X, k=N + 1)
            # k=0 short-circuits without a dispatch
            r = await svc.submit(X, k=0)
            return r, dict(svc.stats)

    r, stats = asyncio.run(main())
    assert r.indices == [] and r.evaluations == 0
    assert stats["dispatches"] == 0 and stats["requests"] == 1


def test_backpressure_bounded_queue():
    """More in-flight submissions than max_pending: producers block on the
    queue instead of buffering without bound, and everything still gets
    served."""
    Xs = _tenants(10)

    async def main():
        async with SelectionService(max_batch=4, max_pending=2) as svc:
            res = await asyncio.gather(
                *[svc.submit(Xs[i], k=2) for i in range(10)])
            return res, dict(svc.stats)

    res, stats = asyncio.run(main())
    assert len(res) == 10 and stats["requests"] == 10
    ref = _ref(Xs[0], "dense", 2)
    assert res[0].indices == ref.indices


def test_unstarted_service_refuses():
    svc = SelectionService()

    async def main():
        with pytest.raises(RuntimeError, match="not started"):
            await svc.submit(_tenants(1)[0], k=2)

    asyncio.run(main())
