"""Hypothesis property test: the Pallas sieve-scan body is bit-identical to
the jnp scan body.

The sieve engine's ``_element_step`` is ONE definition with two scoring
paths — the plain jnp (S_max, n) relu-mean and the fused
:func:`repro.kernels.ops.sieve_gains` kernel. This suite drives random
streams through BOTH paths (kernel in interpret mode on CPU) and asserts the
resulting sieve tables are *bit-identical*: caches, threshold exponents,
active masks, sizes, member slots, and evaluation counts.

To make bitwise equality a theorem rather than luck, stream vectors are
drawn from a dyadic grid (multiples of 1/32 in [0, 4]) with n a power of
two: every distance, relu, sum, and mean both paths compute is then *exact*
in float32, so any divergence is a structural bug in the kernel wiring
(tiling, padding, the claim/single post-rebuild override), not reduction-
order rounding. Streams are shaped to hit the interesting edges: prefix
maxima trigger grid rebuilds, and salsa under a squeezed ``s_max`` exercises
the capacity-eviction rule.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test extra; pip install .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import ExemplarClustering
from repro.core.streaming import (VARIANTS, default_capacity,
                                  make_sieve_engine)

SETTINGS = dict(max_examples=20, deadline=None)
N, D = 64, 6  # n a power of two → the /n mean is exact on dyadic sums


def _grid_ground_set(seed: int) -> np.ndarray:
    """(N, D) vectors on the 1/32 grid in [0, 4] — exact f32 arithmetic."""
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 129, size=(N, D)) / 32.0).astype(np.float32)


def _state_tuple(eng):
    st_ = eng.state
    return {name: np.asarray(getattr(st_, name))
            for name in ("caches", "slot_exp", "active", "sizes", "members",
                         "m_seen", "lb")}


def _run_both(V, order, k, eps, variant, s_max, block_size):
    f = ExemplarClustering(jnp.asarray(V))
    engines = {}
    for backend in ("jnp", "pallas_interpret"):
        eng = make_sieve_engine(f, k, eps, variant=variant, mode="device",
                                s_max=s_max, block_size=block_size,
                                backend=backend)
        eng.offer(order, V[order])
        engines[backend] = eng
    return engines["jnp"], engines["pallas_interpret"]


@given(seed=st.integers(0, 1000),
       k=st.integers(1, 4),
       eps=st.sampled_from([0.1, 0.25, 0.5]),
       variant=st.sampled_from(sorted(VARIANTS)),
       block_size=st.sampled_from([1, 17, 64]))
@settings(**SETTINGS)
def test_sieve_scan_kernel_bit_identical(seed, k, eps, variant, block_size):
    V = _grid_ground_set(seed)
    order = np.random.default_rng(seed + 1).permutation(N).astype(np.int32)
    ej, ep = _run_both(V, order, k, eps, variant, None, block_size)
    assert ej.evaluations() == ep.evaluations()
    assert ej.best() == ep.best()
    sj, sp = _state_tuple(ej), _state_tuple(ep)
    for name in sj:
        np.testing.assert_array_equal(sj[name], sp[name], err_msg=name)


@given(seed=st.integers(0, 1000), k=st.integers(2, 4))
@settings(**SETTINGS)
def test_sieve_scan_kernel_bit_identical_under_eviction(seed, k):
    """Capacity edge: salsa's grow-only grid squeezed into a sieve-sized
    table forces the lowest-exponent eviction rule — identically on both
    scoring paths (rebuild claims flow through the ``single`` override)."""
    V = _grid_ground_set(seed)
    # ascending-norm order maximizes rebuild count (every new max re-derives
    # the window); eviction then fires as the window climbs past s_max slots
    order = np.argsort((V ** 2).sum(axis=1)).astype(np.int32)
    cap = default_capacity(k, 0.1, "sieve")  # too small for salsa's grid
    ej, ep = _run_both(V, order, k, 0.1, "salsa", cap, 32)
    assert ej.evaluations() == ep.evaluations()
    sj, sp = _state_tuple(ej), _state_tuple(ep)
    for name in sj:
        np.testing.assert_array_equal(sj[name], sp[name], err_msg=name)
