"""Mesh-sharded sieve engine: parity vs the single-device engine.

Mirrors test_streaming_engine.py's host/device parity suite one level up:
the (S_max, n) sieve cache table (plus the d_e0 seed and every element's
distance row) column-shards over a mesh, and the sharded engine must
reproduce the single-device device plan's members, values, AND evaluation
counts — the scan body is the identical ``_element_step`` with its two
ground-set reductions psum'd, so divergence means the sharding wiring (not
the sieve logic) regressed.

Under plain pytest this runs on a 1-device mesh (shard_map semantics, no
collective traffic); the CI pallas-interpret job forces 2 host devices so
the psums reduce across real shards, and test_engine_sharded.py runs the
8-device subprocess variant.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EvalConfig, ExemplarClustering,
                        StreamIngestionService)
from repro.core.engine import DEVICE_TRACE_COUNTS
from repro.core.optimizers import salsa, sieve_streaming, sieve_streaming_pp
from repro.data.synthetic import blobs

ALGS = {"sieve_streaming": sieve_streaming, "salsa": salsa,
        "pp": sieve_streaming_pp}


@pytest.fixture(scope="module")
def f():
    X, _ = blobs(300, 16, centers=8, seed=1)
    return ExemplarClustering(jnp.asarray(X))


@pytest.mark.parametrize("alg", sorted(ALGS))
def test_sharded_sieve_matches_single_device(f, alg):
    """n = 300 is not a device-count multiple → exercises the zero padding
    (pad rows contribute exactly 0 to every psum'd sum)."""
    dev = ALGS[alg](f, 6, eps=0.1, seed=2, mode="device")
    sh = ALGS[alg](f, 6, eps=0.1, seed=2, mode="device_sharded")
    assert sh.indices == dev.indices
    assert sh.evaluations == dev.evaluations
    np.testing.assert_allclose(sh.value, dev.value, atol=1e-6)


@pytest.mark.parametrize("alg", sorted(ALGS))
def test_sharded_sieve_kernel_backend(f, alg):
    """The fused sieve-gain kernel runs per shard with the global n_total
    normalizer, so per-shard table tiles psum exactly like selection
    gains."""
    fp = ExemplarClustering(f.V, EvalConfig(backend="pallas_interpret"))
    dev = ALGS[alg](fp, 6, eps=0.1, seed=2, mode="device")
    sh = ALGS[alg](fp, 6, eps=0.1, seed=2, mode="device_sharded")
    assert sh.indices == dev.indices
    assert sh.evaluations == dev.evaluations
    np.testing.assert_allclose(sh.value, dev.value, atol=1e-6)


@pytest.mark.parametrize("n", [1024, 8192])
def test_sharded_sieve_parity_at_scale(n):
    """Acceptance sizes: identical members and counts at n ∈ {1k, 8k}."""
    X, _ = blobs(n, 24, centers=12, seed=13)
    fn = ExemplarClustering(jnp.asarray(X))
    dev = sieve_streaming(fn, 8, seed=5, mode="device", block_size=128)
    sh = sieve_streaming(fn, 8, seed=5, mode="device_sharded",
                         block_size=128)
    assert sh.indices == dev.indices
    assert sh.evaluations == dev.evaluations
    np.testing.assert_allclose(sh.value, dev.value, atol=1e-6)


def test_sharded_sieve_block_size_invariance(f):
    """Blocking stays a pure dispatch optimization under the mesh."""
    runs = [sieve_streaming(f, 5, eps=0.1, seed=2, mode="device_sharded",
                            block_size=b) for b in (1, 64, 97)]
    ref = sieve_streaming(f, 5, eps=0.1, seed=2, mode="device",
                          block_size=64)
    assert all(r.indices == ref.indices for r in runs)
    assert all(r.evaluations == ref.evaluations for r in runs)


def test_sharded_sieve_single_trace(f):
    """One trace per (mesh, spec, shapes): repeat runs and the ragged tail
    block reuse the same sharded executable."""
    before = DEVICE_TRACE_COUNTS["sieve_sieve_sharded"]
    first = sieve_streaming(f, 5, eps=0.15, seed=4, mode="device_sharded",
                            block_size=77)
    mid = DEVICE_TRACE_COUNTS["sieve_sieve_sharded"]
    again = sieve_streaming(f, 5, eps=0.15, seed=4, mode="device_sharded",
                            block_size=77)
    assert mid <= before + 1
    assert DEVICE_TRACE_COUNTS["sieve_sieve_sharded"] == mid
    assert first.indices == again.indices


def test_sharded_engine_table_is_sharded(f):
    """The memory claim, structurally: the cache table's sharding really
    splits its columns over the mesh (each addressable shard holds
    S_max × n_pad/p entries), while member slots stay replicated — a
    snapshot reads them once, not per shard."""
    from repro.core.streaming import make_sieve_engine

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    eng = make_sieve_engine(f, 6, 0.1, mode="device", mesh=mesh)
    eng.offer(np.arange(64), np.asarray(f.V)[:64])
    p = jax.device_count()
    n_pad = -(-f.n // p) * p
    cshard = eng.state.caches.addressable_shards[0]
    assert cshard.data.shape == (eng.spec.s_max, n_pad // p)
    mshard = eng.state.members.addressable_shards[0]
    assert mshard.data.shape == eng.state.members.shape  # replicated


def test_service_snapshot_over_sharded_engine(f):
    """The ingestion service wraps the mesh-sharded engine transparently:
    block-aligned snapshots report the same members/values/counters as the
    single-device service run."""
    X = np.asarray(f.V)
    order = np.random.default_rng(7).permutation(f.n)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    async def main(mesh_arg):
        async with StreamIngestionService(f, k=6, mode="device",
                                          mesh=mesh_arg,
                                          block_size=32) as svc:
            await svc.offer_batch(X[order])
            await svc.drain()
            mid = await svc.snapshot()   # block-aligned, mid-lifecycle
            return mid

    snap_sh = asyncio.run(main(mesh))
    snap_1d = asyncio.run(main(None))
    assert snap_sh.indices == snap_1d.indices
    assert snap_sh.evaluations == snap_1d.evaluations
    assert snap_sh.n_ingested == snap_1d.n_ingested == f.n
    np.testing.assert_allclose(snap_sh.value, snap_1d.value, atol=1e-6)
    np.testing.assert_allclose(snap_sh.exemplars, snap_1d.exemplars, atol=0)


def test_host_mirror_rejects_mesh(f):
    from repro.core.streaming import make_sieve_engine

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with pytest.raises(ValueError, match="host mirror"):
        make_sieve_engine(f, 4, 0.1, mode="host", mesh=mesh)
