"""Streaming sieve engine: host/device parity, sieve-family regressions,
and the async ingestion service."""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EvalConfig, ExemplarClustering,
                        StreamIngestionService, greedy)
from repro.core.engine import DEVICE_TRACE_COUNTS
from repro.core.optimizers import (salsa, sieve_streaming,
                                   sieve_streaming_pp, three_sieves)
from repro.core.streaming import (SieveState, _element_step_jit,
                                  default_capacity, init_state,
                                  make_sieve_engine, make_spec)
from repro.data.synthetic import blobs


@pytest.fixture(scope="module")
def f():
    X, _ = blobs(300, 16, centers=8, seed=1)
    return ExemplarClustering(jnp.asarray(X))


ALGS = {"sieve_streaming": sieve_streaming, "salsa": salsa,
        "pp": sieve_streaming_pp}


@pytest.mark.parametrize("alg", sorted(ALGS))
def test_sieve_host_device_parity(f, alg):
    """Host mirror and device scan run the same element step: identical
    members, values, AND evaluation counts."""
    host = ALGS[alg](f, 6, eps=0.1, seed=2, mode="host")
    dev = ALGS[alg](f, 6, eps=0.1, seed=2, mode="device")
    assert host.indices == dev.indices
    assert host.evaluations == dev.evaluations
    np.testing.assert_allclose(host.value, dev.value, atol=1e-6)


@pytest.mark.parametrize("alg", sorted(ALGS))
def test_sieve_host_device_parity_kernel_backend(f, alg):
    """Same cross-plan parity with the element step scored through the fused
    Pallas sieve-gain kernel (interpret on CPU): both plans run the identical
    kernel path, so members AND counts still match — and on this easy data
    the kernel path picks the same members as the jnp path."""
    ref = ALGS[alg](f, 6, eps=0.1, seed=2, mode="host")
    fp = ExemplarClustering(f.V, EvalConfig(backend="pallas_interpret"))
    host = ALGS[alg](fp, 6, eps=0.1, seed=2, mode="host")
    dev = ALGS[alg](fp, 6, eps=0.1, seed=2, mode="device")
    assert host.indices == dev.indices == ref.indices
    assert host.evaluations == dev.evaluations == ref.evaluations
    np.testing.assert_allclose(host.value, dev.value, atol=1e-6)


@pytest.mark.parametrize("n", [1024, 8192])
@pytest.mark.parametrize("alg", ["sieve_streaming", "salsa"])
def test_sieve_parity_at_scale(n, alg):
    """Acceptance sizes: identical host/device selections and counts."""
    X, _ = blobs(n, 24, centers=12, seed=13)
    fn = ExemplarClustering(jnp.asarray(X))
    host = ALGS[alg](fn, 8, seed=5, mode="host", block_size=128)
    dev = ALGS[alg](fn, 8, seed=5, mode="device", block_size=128)
    assert host.indices == dev.indices
    assert host.evaluations == dev.evaluations
    np.testing.assert_allclose(host.value, dev.value, atol=1e-6)


def test_device_block_size_invariance(f):
    """Blocking is a pure dispatch optimization: block size (including a
    ragged tail) must not change decisions or accounting in either mode."""
    runs = [sieve_streaming(f, 5, eps=0.1, seed=2, mode="device",
                            block_size=b) for b in (1, 64, 97, 300)]
    runs.append(sieve_streaming(f, 5, eps=0.1, seed=2, mode="host",
                                block_size=41))
    assert all(r.indices == runs[0].indices for r in runs)
    assert all(r.evaluations == runs[0].evaluations for r in runs)


def test_device_sieve_single_trace(f):
    """One trace per (spec, shapes) signature: repeat runs and ragged tail
    blocks reuse the same executable (tail blocks are padded, not re-shaped)."""
    before = DEVICE_TRACE_COUNTS["sieve_sieve"]
    first = sieve_streaming(f, 5, eps=0.15, seed=4, mode="device",
                            block_size=77)  # 300 → 3 full + 1 ragged block
    mid = DEVICE_TRACE_COUNTS["sieve_sieve"]
    again = sieve_streaming(f, 5, eps=0.15, seed=4, mode="device",
                            block_size=77)
    assert mid <= before + 1
    assert DEVICE_TRACE_COUNTS["sieve_sieve"] == mid
    assert first.indices == again.indices


def test_salsa_k1_applies_early_rate(f):
    """Regression: the dense schedule's early 1/2 rate must apply to the
    first ⌈k/2⌉ members — for k=1, sieves were jumping straight to the late
    1/(2e) rate (``sizes < k // 2`` is never true at k=1)."""
    n, d = 6, 3
    V = np.full((n, d), 2.0, np.float32)
    fn = ExemplarClustering(jnp.asarray(V))
    spec = make_spec(1, 0.1, "salsa")
    state = init_state(n, spec)
    # one armed sieve at τ = (1+ε)^0 = 1, fresh cache, grid frozen (m_seen
    # high so no rebuild); an element with gain 0.3 sits between the late
    # rate 1/(2e)·τ ≈ 0.18 (buggy accept) and the early rate τ/2 (reject)
    state = SieveState(
        caches=jnp.asarray(fn.d_e0, jnp.float32)[None, :].repeat(
            spec.s_max, 0),
        slot_exp=state.slot_exp.at[0].set(0),
        active=state.active.at[0].set(True),
        sizes=state.sizes, members=state.members,
        m_seen=jnp.float32(100.0), lb=state.lb, evals=state.evals)
    dvec = jnp.asarray(fn.d_e0, jnp.float32) - 0.3
    new, accepted = _element_step_jit(state, fn.d_e0, jnp.int32(0), dvec,
                                      True, spec=spec)
    assert not bool(accepted)
    assert int(new.sizes[0]) == 0
    # and a gain past τ/2 is accepted
    _, accepted = _element_step_jit(state, fn.d_e0, jnp.int32(0),
                                    jnp.asarray(fn.d_e0) - 0.6, True,
                                    spec=spec)
    assert bool(accepted)


def test_salsa_k1_end_to_end(f):
    res = salsa(f, 1, seed=4)
    base = greedy(f, 1)
    assert len(res.indices) == 1
    assert res.value >= 0.5 * base.value


def test_three_sieves_counts_only_scored_elements(f):
    """Regression: once the sieve is full (or unarmed) elements are
    short-circuited before the gain — ``evaluations`` reflects work done."""
    res = three_sieves(f, 6, eps=0.1, T=10, seed=3)
    assert len(res.indices) <= 6
    assert res.evaluations >= len(res.indices)
    # the easy-blobs sieve fills well before the stream ends; the old
    # accounting charged one evaluation per arriving element (== n)
    assert res.evaluations < f.n


def test_capacity_validation():
    X, _ = blobs(64, 8, centers=4, seed=0)
    fn = ExemplarClustering(jnp.asarray(X))
    with pytest.raises(ValueError, match="s_max"):
        sieve_streaming(fn, 6, eps=0.1, s_max=2)
    with pytest.raises(ValueError, match="k >= 1"):
        sieve_streaming(fn, 0)
    with pytest.raises(ValueError, match="mode"):
        sieve_streaming(fn, 3, mode="sharded")
    assert default_capacity(6, 0.1, "salsa") > default_capacity(6, 0.1, "sieve")


def test_salsa_capacity_eviction_keeps_parity():
    """Under capacity pressure the grow-only salsa grid evicts the lowest
    exponent — identically in both modes (the rule lives in the shared
    element step)."""
    X, _ = blobs(200, 8, centers=6, seed=3)
    fn = ExemplarClustering(jnp.asarray(X))
    cap = default_capacity(4, 0.1, "sieve")  # too small for salsa's grid
    host = salsa(fn, 4, seed=6, mode="host", s_max=cap)
    dev = salsa(fn, 4, seed=6, mode="device", s_max=cap)
    assert host.indices == dev.indices
    assert host.evaluations == dev.evaluations
    assert host.value > 0


# ---------------------------------------------------------------------------
# Ingestion service
# ---------------------------------------------------------------------------


def test_service_matches_streaming_optimizer(f):
    """Offering V's rows in a fixed order through the service reproduces
    ``sieve_streaming`` exactly (ids map back through the order)."""
    X = np.asarray(f.V)
    order = np.random.default_rng(7).permutation(f.n)

    async def main():
        async with StreamIngestionService(f, k=6, mode="device",
                                          block_size=32) as svc:
            await svc.offer_batch(X[order])
            await svc.drain()
            return await svc.snapshot()

    snap = asyncio.run(main())
    ref = sieve_streaming(f, 6, order=order, mode="device")
    assert [int(order[i]) for i in snap.indices] == ref.indices
    assert snap.evaluations == ref.evaluations
    np.testing.assert_allclose(snap.value, ref.value, atol=1e-6)
    np.testing.assert_allclose(snap.exemplars, X[order[snap.indices]],
                               atol=0)
    assert snap.n_ingested == f.n
    assert snap.pending == 0


def test_service_backpressure_and_midstream_snapshot(f):
    """A tiny queue bound forces offer-side backpressure; snapshots taken
    mid-stream observe consistent, monotone state."""
    X = np.asarray(f.V)

    async def main():
        svc = StreamIngestionService(f, k=5, mode="host", block_size=8,
                                     max_pending=4)
        await svc.start()
        vals = []
        for j in range(120):
            await svc.offer(X[j])
            if j in (40, 80):
                await svc.drain()
                vals.append((await svc.snapshot()).value)
        await svc.stop()  # drains the tail
        snap = await svc.snapshot()
        return vals, snap

    vals, snap = asyncio.run(main())
    assert snap.n_offered == snap.n_ingested == 120
    assert all(v > 0 for v in vals)  # mid-stream snapshots see live sieves
    assert snap.value > 0


def test_service_accepts_external_vectors(f):
    """Stream elements need not be ground-set rows: arbitrary vectors are
    scored against V and returned as exemplar vectors."""
    rng = np.random.default_rng(11)
    base = np.asarray(f.V)[rng.choice(f.n, size=90)]
    stream = (base + 0.05 * rng.normal(size=base.shape)).astype(np.float32)

    async def main():
        async with StreamIngestionService(f, k=4, mode="device",
                                          block_size=16) as svc:
            ids = await svc.offer_batch(stream)
            await svc.drain()
            return ids, await svc.snapshot()

    ids, snap = asyncio.run(main())
    assert ids == list(range(90))
    assert 1 <= len(snap.indices) <= 4
    np.testing.assert_allclose(snap.exemplars, stream[snap.indices], atol=0)
    assert snap.n_accepted >= len(snap.indices)


# ---------------------------------------------------------------------------
# Donation + overlapped ingestion (PR 9)
# ---------------------------------------------------------------------------


def test_offer_scan_donates_carry(f):
    """The block scan consumes the pre-call SieveState buffers (donation):
    the engine's rebind is the only live table — no block-to-block copy."""
    eng = make_sieve_engine(f, 4, 0.2, mode="device", block_size=8)
    old = eng.state
    eng.offer(np.arange(8), np.asarray(f.V)[:8])
    import jax
    jax.block_until_ready(eng.state)
    assert old.caches.is_deleted()
    assert old.members.is_deleted()
    assert not eng.state.caches.is_deleted()


def test_overlap_parity_and_single_trace(f):
    """The overlapped pipeline is a free lunch: identical accepts, members,
    value and evaluation counts vs the serialized baseline, and no extra
    traces (both paths dispatch the one block-scan executable)."""
    rng = np.random.default_rng(21)
    stream = rng.standard_normal((70, 16)).astype(np.float32)
    before = DEVICE_TRACE_COUNTS["sieve_sieve"]
    runs = []
    for overlap in (False, True):
        eng = make_sieve_engine(f, 5, 0.1, mode="device", block_size=16,
                                overlap=overlap, max_in_flight=2)
        acc = eng.offer(np.arange(len(stream)), stream)
        runs.append((acc.tolist(), eng.best(), eng.evaluations()))
    assert DEVICE_TRACE_COUNTS["sieve_sieve"] - before <= 1
    assert runs[0] == runs[1]


def test_offer_rejects_int32_overflow(f):
    """Stream ids outside the int32 member table must raise, not wrap: the
    service's unbounded int64 counter can exceed int32 on long streams."""
    eng = make_sieve_engine(f, 3, 0.2, mode="device", block_size=4)
    X1 = np.asarray(f.V)[:1]
    i_max = np.iinfo(np.int32).max
    acc = eng.offer(np.array([i_max], np.int64), X1)   # boundary id: fine
    assert bool(acc[0]) and i_max in eng.member_ids()
    with pytest.raises(OverflowError):
        eng.offer(np.array([i_max + 1], np.int64), X1)
    with pytest.raises(OverflowError):
        eng.offer(np.array([np.iinfo(np.int32).min - 1], np.int64), X1)


# ---------------------------------------------------------------------------
# Service race regressions (PR 9)
# ---------------------------------------------------------------------------


def test_snapshot_survives_worker_cancel_mid_ingest(f):
    """Regression: cancelling the worker while an engine dispatch is in
    flight must not desync the engine from the retention map. The thread
    backing ``asyncio.to_thread`` runs to completion even when the awaiting
    task is cancelled, so the engine records accepts either way — if the
    ``_vecs`` writes live on the event-loop side of that await (the pre-fix
    code), they are skipped and the next snapshot's exemplar gather raises
    KeyError on an accepted-but-unretained id."""
    import threading
    import time

    X = np.asarray(f.V)
    started = threading.Event()
    finished = threading.Event()

    async def main():
        svc = StreamIngestionService(f, k=4, mode="device", block_size=8)
        await svc.start()
        orig = svc._engine.offer

        def slow_offer(ids, vecs):
            started.set()
            time.sleep(0.3)     # hold the dispatch so the cancel wins
            try:
                return orig(ids, vecs)
            finally:
                finished.set()

        svc._engine.offer = slow_offer
        for j in range(8):      # early elements: guaranteed accepts
            await svc.offer(X[j])
        await asyncio.to_thread(started.wait, 5.0)
        svc._task.cancel()
        await asyncio.gather(svc._task, return_exceptions=True)
        # the orphaned thread runs to completion: the engine HAS recorded
        # the block's accepts by the time the snapshot gathers exemplars.
        # Post-fix the retention writes ride the same thread — give them a
        # bounded window to land; pre-fix they never do.
        await asyncio.to_thread(finished.wait, 10.0)
        for _ in range(100):
            if svc._n_ingested >= 8:
                break
            await asyncio.sleep(0.01)
        return await svc.snapshot()

    snap = asyncio.run(main())      # pre-fix: KeyError
    assert snap.n_accepted == len(snap.indices) or snap.n_accepted >= 1
    assert snap.exemplars.shape[0] == len(snap.indices)


def test_cancelled_producer_leaks_no_id(f):
    """Regression: a producer cancelled while awaiting backpressure must
    not consume a stream id (pre-fix, the id was assigned BEFORE the
    blocking put, so the next snapshot undercounted assigned ids)."""
    import threading
    import time

    X = np.asarray(f.V)
    busy = threading.Event()

    async def main():
        svc = StreamIngestionService(f, k=3, mode="device", block_size=1,
                                     max_pending=1)
        await svc.start()
        orig = svc._engine.offer

        def slow_offer(ids, vecs):
            busy.set()
            time.sleep(0.3)
            return orig(ids, vecs)

        svc._engine.offer = slow_offer
        assert await svc.offer(X[0]) == 0
        await asyncio.to_thread(busy.wait, 5.0)
        assert await svc.offer(X[1]) == 1   # waits out block 0's dispatch
        blocked = asyncio.create_task(svc.offer(X[2]))
        await asyncio.sleep(0.05)           # let it park on backpressure
        blocked.cancel()
        await asyncio.gather(blocked, return_exceptions=True)
        await svc.drain()
        i = await svc.offer(X[3])           # pre-fix: 3 (id 2 leaked)
        await svc.drain()
        snap = await svc.snapshot()
        await svc.stop()
        return i, snap

    i, snap = asyncio.run(main())
    assert i == 2
    assert snap.n_offered == snap.n_ingested == 3


def test_snapshot_under_load_soak(f):
    """Producers and snapshot consumers race for many blocks: no KeyError,
    counters stay monotone, and every snapshot is internally consistent
    (exemplar rows match member ids, value from live sieves only)."""
    rng = np.random.default_rng(23)
    stream = np.asarray(f.V)[rng.choice(f.n, size=240)]
    stream = (stream + 0.02 * rng.normal(size=stream.shape)
              ).astype(np.float32)

    async def main():
        async with StreamIngestionService(f, k=5, mode="device",
                                          block_size=8,
                                          max_pending=16) as svc:
            done = asyncio.Event()
            seen: list[tuple] = []

            async def producer():
                for x in stream:
                    await svc.offer(x)
                await svc.drain()
                done.set()

            async def snapper():
                last = (0, 0, 0)
                while not done.is_set():
                    snap = await svc.snapshot()
                    cur = (snap.n_offered, snap.n_ingested, snap.n_accepted)
                    assert cur >= last       # monotone counters
                    assert snap.n_offered >= snap.n_ingested
                    assert len(snap.indices) <= 5
                    assert snap.exemplars.shape == (len(snap.indices),
                                                    f.dim)
                    last = cur
                    seen.append(cur)
                    await asyncio.sleep(0)

            await asyncio.gather(producer(), snapper(), snapper())
            return seen, await svc.snapshot()

    seen, snap = asyncio.run(main())
    assert len(seen) > 2
    assert snap.n_offered == snap.n_ingested == len(stream)
    assert snap.value > 0
