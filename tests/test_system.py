"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EvalConfig, ExemplarClustering,
                        fit_exemplar_clustering, greedy)
from repro.data.synthetic import blobs, uniform_problem


def test_paper_workload_end_to_end():
    """The paper's §V setup, scaled down: uniform data, greedy selection via
    the multiset engine, chunked under a memory budget, fp32 vs fp16."""
    V = uniform_problem(n=1500, dim=100, seed=0)
    f32 = ExemplarClustering(
        jnp.asarray(V), EvalConfig(memory_budget_bytes=32 * 2**20))
    res = greedy(f32, 10)
    assert len(res.indices) == 10
    assert res.value > 0
    # identical selection through the Pallas kernel path (interpret)
    fker = ExemplarClustering(jnp.asarray(V),
                              EvalConfig(backend="pallas_interpret"))
    res_k = greedy(fker, 10)
    assert res_k.indices == res.indices
    # paper's FP16 question: value parity
    f16 = ExemplarClustering(jnp.asarray(V), EvalConfig(policy="fp16"))
    res16 = greedy(f16, 10)
    assert abs(res16.value - res.value) / res.value < 5e-3


def test_clustering_recovers_blob_structure():
    X, labels = blobs(n=1200, dim=16, centers=6, spread=0.08, seed=4)
    model = fit_exemplar_clustering(X, k=6)
    got = model.assign(X)
    # purity: every found cluster dominated by one true blob
    purity = sum(np.bincount(labels[got == c]).max()
                 for c in range(6)) / len(X)
    assert purity > 0.95


def test_curated_training_runs_and_learns():
    """The full integration: LM training consuming exemplar-curated batches."""
    from repro.configs import get_reduced_config
    from repro.data.pipeline import CurationConfig, token_batches
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import TrainConfig, train

    cfg = get_reduced_config("qwen3-0.6b")
    batches = token_batches(cfg.vocab_size, 4, 32, steps=20,
                            curation=CurationConfig(window=16, select=4),
                            seed=11)
    _, hist = train(cfg, TrainConfig(steps=20, log_every=5),
                    OptimizerConfig(lr=3e-3, warmup_steps=3, total_steps=20),
                    batches)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_serve_roundtrip_all_step_builders():
    """prefill → decode loop through the same builders the dry-run lowers."""
    from repro.configs import get_reduced_config
    from repro.models.model import init_model
    from repro.train.step import make_prefill_step, make_serve_step

    cfg = get_reduced_config("gemma3-1b")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    B, P, N = 2, 12, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(cfg, None, cache_len=P + N))
    decode = jax.jit(make_serve_step(cfg, None))
    tok, caches = prefill(params, {"tokens": prompts})
    toks = [tok]
    for i in range(N - 1):
        tok, caches = decode(params, {"tokens": tok, "caches": caches,
                                      "pos": jnp.asarray(P + i, jnp.int32)})
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    assert gen.shape == (B, N)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
